"""Multi-host data-parallel training against one shared cluster.

Four training hosts, each owning a token-aware replica-skewed strip of one
global shuffle, consume batches in lockstep (one batch per host per step,
modelling synchronous data parallelism) while a fixed per-step compute time
emulates the GPU.  Midway, a coordinated checkpoint is taken, the cluster
"shrinks" — the run is restored onto TWO hosts (elastic N -> M resharding:
the unfinished epoch is reflowed into two strips, nothing skipped, nothing
repeated) — and a storage node is killed during the resized phase,
demonstrating that (a) the checkpoint captures a consistent batch boundary,
(b) the reflow preserves exactly-once delivery per epoch, and (c) hedged
requests + connection failover ride through the node failure.

Run: PYTHONPATH=src python examples/multihost_train.py
"""

from repro.core import KVStore, MultiHostConfig, MultiHostRun
from repro.data.datasets import SyntheticImageDataset, ingest

N_HOSTS = 4
RESIZED_HOSTS = 2
STEP_TIME = 0.05           # 50 ms of GPU compute per step
STEPS_PER_PHASE = 40


def _cfg(n_hosts: int) -> MultiHostConfig:
    return MultiHostConfig(n_hosts=n_hosts, batch_size=256,
                           prefetch_buffers=8, io_threads=8, route="high",
                           backend="scylla", n_nodes=4, replication_factor=2,
                           hedge_after=1.0, seed=4,
                           node_egress_bandwidth=1.25e9,
                           placement="token_aware")


def main() -> None:
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=60_000, seed=0))
    run = MultiHostRun(store, uuids, _cfg(N_HOSTS)).start()
    print(f"{run.describe()}; shard sizes {run.shard_sizes()}\n")

    rep = run.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"phase 1: {STEPS_PER_PHASE} steps x {N_HOSTS} hosts, "
          f"{rep['aggregate_Bps']/1e6:.0f} MB/s aggregate, "
          f"fairness {rep['fairness']:.2f}, "
          f"replica-local {rep['replica_local_hit_frac']:.0%}")

    ckpt = run.checkpoint()
    print(f"checkpoint at global step {ckpt['rounds']}: "
          + ", ".join(f"shard{i}=(e{s['epoch']},c{s['cursor']})"
                      for i, s in enumerate(ckpt["shards"])))

    # the cluster shrinks: restore the 4-host checkpoint onto 2 hosts
    # (elastic reshard) and lose a storage node mid-phase on top
    run2 = MultiHostRun(store, uuids, _cfg(RESIZED_HOSTS)).start(ckpt)
    print(f"\nelastic restore {N_HOSTS} -> {RESIZED_HOSTS} hosts; "
          f"shard sizes now {run2.shard_sizes()} "
          "(interrupted epoch reflowed, exactly-once preserved)")
    run2.inject_failure("node2", after=0.5)
    rep2 = run2.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"phase 2 (resized, node2 dark mid-phase): "
          f"{rep2['aggregate_Bps']/1e6:.0f} MB/s aggregate, "
          f"{rep2['failovers']} failovers, fairness {rep2['fairness']:.2f}")

    load = rep2["cluster_load"]
    print("\nper-node load after phase 2:")
    for name, v in load.items():
        mark = " (down)" if v["down"] else ""
        print(f"  {name}: {v['requests']:6.0f} reqs, "
              f"{v['egress_bytes']/1e9:5.2f} GB egress "
              f"({v['egress_share']:.0%} share){mark}")

    resumed = run2.checkpoint()   # raises if shards drifted out of lockstep
    print(f"\nresized run advanced {resumed['rounds']} steps "
          f"(global step {ckpt['rounds'] + resumed['rounds']}) — "
          "all shards at one consistent boundary")


if __name__ == "__main__":
    main()
