"""Multi-host data-parallel training against one shared cluster.

Four training hosts, each owning a token-aware replica-skewed strip of one
global shuffle, consume batches in lockstep (one batch per host per step,
modelling synchronous data parallelism) while a fixed per-step compute time
emulates the GPU.  Midway, a coordinated checkpoint is taken, the cluster
"shrinks" — the run is restored onto TWO hosts (elastic N -> M resharding:
the unfinished epoch is reflowed into two strips, nothing skipped, nothing
repeated) — and a storage node is killed during the resized phase,
demonstrating that (a) the checkpoint captures a consistent batch boundary,
(b) the reflow preserves exactly-once delivery per epoch, and (c) hedged
requests + connection failover ride through the node failure.

A final phase federates the same dataset across TWO storage clusters — one
local, one an intercontinental WAN hop away (the data stays where it was
produced) — with cluster-aware placement routing every key to its owning
cluster and a replica-local node inside it.  Mid-phase the overseas cluster
suffers a region outage and reads degrade to the surviving cluster.

Run: PYTHONPATH=src python examples/multihost_train.py
"""

from repro.core import ClusterSpec, KVStore, MultiHostConfig, build_stack
from repro.data.datasets import SyntheticImageDataset, ingest

N_HOSTS = 4
RESIZED_HOSTS = 2
STEP_TIME = 0.05           # 50 ms of GPU compute per step
STEPS_PER_PHASE = 40


def _cfg(n_hosts: int) -> MultiHostConfig:
    return MultiHostConfig(n_hosts=n_hosts, batch_size=256,
                           prefetch_buffers=8, io_threads=8, route="high",
                           backend="scylla", n_nodes=4, replication_factor=2,
                           hedge_after=1.0, seed=4,
                           node_egress_bandwidth=1.25e9,
                           placement="token_aware")


def main() -> None:
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=60_000, seed=0))
    run = build_stack(store=store, uuids=uuids, config=_cfg(N_HOSTS),
                      start=True).run
    print(f"{run.describe()}; shard sizes {run.shard_sizes()}\n")

    rep = run.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"phase 1: {STEPS_PER_PHASE} steps x {N_HOSTS} hosts, "
          f"{rep['aggregate_Bps']/1e6:.0f} MB/s aggregate, "
          f"fairness {rep['fairness']:.2f}, "
          f"replica-local {rep['replica_local_hit_frac']:.0%}")

    ckpt = run.checkpoint()
    print(f"checkpoint at global step {ckpt['rounds']}: "
          + ", ".join(f"shard{i}=(e{s['epoch']},c{s['cursor']})"
                      for i, s in enumerate(ckpt["shards"])))

    # the cluster shrinks: restore the 4-host checkpoint onto 2 hosts
    # (elastic reshard) and lose a storage node mid-phase on top
    # restore from a checkpoint: build unstarted, then start(ckpt)
    run2 = build_stack(store=store, uuids=uuids,
                       config=_cfg(RESIZED_HOSTS)).run.start(ckpt)
    print(f"\nelastic restore {N_HOSTS} -> {RESIZED_HOSTS} hosts; "
          f"shard sizes now {run2.shard_sizes()} "
          "(interrupted epoch reflowed, exactly-once preserved)")
    run2.inject_failure("node2", after=0.5)
    rep2 = run2.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"phase 2 (resized, node2 dark mid-phase): "
          f"{rep2['aggregate_Bps']/1e6:.0f} MB/s aggregate, "
          f"{rep2['failovers']} failovers, fairness {rep2['fairness']:.2f}")

    load = rep2["cluster_load"]
    print("\nper-node load after phase 2:")
    for name, v in load.items():
        mark = " (down)" if v["down"] else ""
        print(f"  {name}: {v['requests']:6.0f} reqs, "
              f"{v['egress_bytes']/1e9:5.2f} GB egress "
              f"({v['egress_share']:.0%} share){mark}")

    resumed = run2.checkpoint()   # raises if shards drifted out of lockstep
    print(f"\nresized run advanced {resumed['rounds']} steps "
          f"(global step {ckpt['rounds'] + resumed['rounds']}) — "
          "all shards at one consistent boundary")

    # phase 3: the same dataset federated across two storage clusters, one
    # of them an ocean away; deeper prefetch hides the WAN latency, and a
    # cluster-level outage degrades reads to the surviving cluster
    specs = (ClusterSpec("onprem", route="local", n_nodes=4,
                         replication_factor=2,
                         node_egress_bandwidth=1.25e9),
             ClusterSpec("overseas", route="high", n_nodes=4,
                         replication_factor=2,
                         node_egress_bandwidth=1.25e9))
    fed_cfg = MultiHostConfig(n_hosts=N_HOSTS, batch_size=256,
                              prefetch_buffers=24, io_threads=8,
                              ramp_every=1, hedge_after=1.0, seed=4,
                              placement="cluster_aware", clusters=specs)
    fed = build_stack(store=store, uuids=uuids, config=fed_cfg,
                      start=True).run
    print(f"\nphase 3 (federated): {fed.describe()}")
    own = fed.federation.ownership_counts(uuids)
    print(f"  ownership: " + ", ".join(f"{c}={n}" for c, n in own.items()))
    rep3 = fed.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"  {rep3['aggregate_Bps']/1e6:.0f} MB/s aggregate, WAN-bytes "
          f"share {rep3['wan_bytes_share']:.0%}, replica-local "
          f"{rep3['replica_local_hit_frac']:.0%}")
    fed.inject_cluster_outage("overseas", after=0.0)
    rep4 = fed.run(STEPS_PER_PHASE, step_time=STEP_TIME)
    print(f"  overseas region dark: {rep4['aggregate_Bps']/1e6:.0f} MB/s, "
          f"WAN-bytes share {rep4['wan_bytes_share']:.0%}, "
          f"{rep4['cluster_failovers']} cluster failovers — "
          "reads degraded to the surviving cluster, nothing lost")


if __name__ == "__main__":
    main()
