"""Quickstart: the paper's full data path in ~60 seconds on a laptop.

1. ingest a synthetic dataset (data + metadata, atomic inserts) into the
   Cassandra-model KV store;
2. create entity-independent train/val splits from metadata (Sec. 3.2);
3. load batches over a simulated 150 ms-RTT intercontinental link with
   out-of-order, incremental prefetching (Sec. 3.4);
4. feed a few train steps of a tiny LM through the JAX pipeline.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.base import ArchConfig
from repro.core import (KVStore, LoaderConfig, SplitSpec, build_stack,
                        create_splits)
from repro.data.datasets import SyntheticTokenDataset, ingest
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_state, make_train_step


def main() -> None:
    # 1. ingest ------------------------------------------------------------
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=2048, seq_len=64,
                                                vocab=2048, seed=0))
    print(f"ingested {len(uuids)} samples "
          f"({store.total_bytes() / 1e6:.1f} MB, data+metadata atomic)")

    # 2. automatic splits ----------------------------------------------------
    splits = create_splits(store.scan_metadata(),
                           SplitSpec(fractions=(0.9, 0.1), seed=0))
    print({k: len(v) for k, v in splits.items()}, "(entity-independent)")

    # 3+4a. one call builds the whole data stack: cluster -> pool -> loader
    #       -> DeviceFeed, over a simulated 150 ms RTT route with
    #       out-of-order + incremental prefetch
    stack = build_stack(store=store, uuids=splits["train"],
                        config=LoaderConfig(
                            batch_size=32, prefetch_buffers=8, io_threads=4,
                            route="high", out_of_order=True,
                            incremental_ramp=True, materialize=True, seed=0),
                        feed="device", seq_len=64)
    loader = stack.loader

    # 4. train a tiny LM from the stream ------------------------------------
    cfg = ArchConfig(name="quickstart-lm", family="dense", n_layers=2,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab=2048, head_dim=32, dtype="float32", remat=False)
    model = build_model(cfg)
    opt = OptimizerConfig(peak_lr=3e-3, warmup_steps=5, total_steps=40)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    feed = stack.feed
    for i in range(40):
        batch, _ = next(feed)
        state, metrics = step(state, {"tokens": batch["tokens"],
                                      "loss_mask": batch["loss_mask"]})
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d} loss {float(metrics['loss']):.4f} "
                  f"(loader: {loader.prefetcher.describe()})")
    st = loader.stats
    print(f"loader throughput {st.throughput(skip=2)/1e6:.1f} MB/s over a "
          f"simulated 150 ms-RTT link; batch-gap p99 "
          f"{1e3 * float(__import__('numpy').percentile(st.batch_times(1), 99)):.0f} ms")
    stack.close()


if __name__ == "__main__":
    main()
