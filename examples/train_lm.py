"""End-to-end training driver: LM trained from the network loader with
checkpoint/restart, OOO prefetching, and throughput accounting.

Default config is laptop-sized so the example finishes in ~2 minutes on CPU;
``--preset 100m --steps 300`` is the full-size run for real hardware
(a ~100M-param model; the loop/loader code is identical).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""

import argparse

from repro.configs.base import ArchConfig
from repro.core import KVStore, LoaderConfig
from repro.data.datasets import SyntheticTokenDataset, ingest
from repro.models import build_model
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import OptimizerConfig

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                 vocab=4096, head_dim=32, seq=64, batch=16),
    "20m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                vocab=16000, head_dim=32, seq=128, batch=16),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 d_ff=3072, vocab=32000, head_dim=64, seq=512, batch=32),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--route", default="high")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ArchConfig(name=f"lm-{args.preset}", family="dense",
                     n_layers=p["n_layers"], d_model=p["d_model"],
                     n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
                     d_ff=p["d_ff"], vocab=p["vocab"], head_dim=p["head_dim"],
                     dtype="float32", remat=False)
    model = build_model(cfg)
    from repro.models.params import count_params
    print(f"model: {count_params(model.param_specs())/1e6:.1f}M params")

    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(
        n_samples=4096, seq_len=p["seq"], vocab=p["vocab"], seed=0))
    loader_cfg = LoaderConfig(batch_size=p["batch"], prefetch_buffers=8,
                              io_threads=4, route=args.route,
                              materialize=True, seed=0)
    loop_cfg = TrainLoopConfig(total_steps=args.steps, seq_len=p["seq"],
                               log_every=10, checkpoint_every=50,
                               checkpoint_dir=args.checkpoint_dir)
    res = run_training(model, store, uuids, loader_cfg, loop_cfg,
                       OptimizerConfig(peak_lr=3e-3, warmup_steps=10,
                                       total_steps=args.steps),
                       on_metrics=lambda m: print(
                           f"step {m['step']:4d} loss {m['loss']:.4f} "
                           f"{m['sps']:.0f} samples/s", flush=True))
    h = res["history"]
    print(f"\nloss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}; checkpoints in "
          f"{args.checkpoint_dir} (restart resumes mid-epoch, batch-exact)")


if __name__ == "__main__":
    main()
