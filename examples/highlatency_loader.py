"""The paper's core result as a demo: out-of-order vs in-order prefetching
over a simulated intercontinental (150 ms RTT) link — Fig. 4 / Sec. 4.3.1.

Run: PYTHONPATH=src python examples/highlatency_loader.py
"""

import numpy as np

from repro.core import KVStore, LoaderConfig, build_stack, tight_loop
from repro.data.datasets import SyntheticImageDataset, ingest


def main() -> None:
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=120_000, seed=0))
    print(f"dataset: {len(uuids)} images, {store.total_bytes()/1e9:.1f} GB "
          "(ImageNet-1k statistics), stored in the Cassandra-model KV store\n")

    print(f"{'strategy':26s} {'throughput':>12s} {'batch gap p50/p99/max (ms)':>28s}")
    for ooo, ramp, flow, label in [
        (False, False, "static", "in-order, eager fill"),
        (False, True, "static", "in-order, incremental"),
        (True, True, "static", "OOO + incremental (paper)"),
        (True, True, "adaptive", "OOO + adaptive flow ctl"),
    ]:
        cfg = LoaderConfig(batch_size=512, prefetch_buffers=16, io_threads=16,
                           out_of_order=ooo, incremental_ramp=ramp,
                           route="high", backend="scylla", seed=2,
                           flow_control=flow)
        ld = build_stack(store=store, uuids=uuids, config=cfg).loader
        res = tight_loop(ld, n_batches=200)
        bt = res["batch_times"][20:] * 1e3
        extra = ""
        if ld.flow_controller is not None:
            peak = max(b for _, b in ld.flow_controller.budget_trace)
            extra = (f"   (BDP-driven window: peak {peak} samples, "
                     f"{ld.flow_controller.backoffs} congestion backoffs — "
                     "no hand-tuned k)")
        print(f"{label:26s} {res['throughput_Bps']/1e9:9.2f} GB/s "
              f"{np.percentile(bt,50):8.0f} /{np.percentile(bt,99):5.0f} "
              f"/{bt.max():5.0f}{extra}")
    print("\nOOO assembles batches from whichever samples arrive first, so a "
          "congested route never gates the pipeline (labels travel with "
          "features — any sample is self-contained).  The adaptive row "
          "measures the 150 ms route's bandwidth-delay product and sizes the "
          "in-flight window itself (core/flowctl.py).")


if __name__ == "__main__":
    main()
