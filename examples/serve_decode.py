"""Serving example: continuous-batching decode with prompts fetched from the
KV store over the network loader (the paper's Triton-inference analogue —
clients request inference on samples that live in a remote Cassandra).

Run: PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import KVStore, LoaderConfig, build_stack
from repro.data.datasets import SyntheticTokenDataset, decode_token_record, ingest
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine


def main() -> None:
    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=2,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab=2048, head_dim=32, dtype="float32", remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # prompts live in the remote store; fetch them with the OOO loader
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=256, seq_len=12,
                                                vocab=cfg.vocab, seed=1))
    loader = build_stack(store=store, uuids=uuids, config=LoaderConfig(
        batch_size=16, prefetch_buffers=2, io_threads=2, route="med",
        materialize=True, seed=1), start=True).loader
    batch = loader.next_batch()
    prompts = [decode_token_record(s.payload)[0] for s in batch.samples]

    engine = ServingEngine(model, params,
                           ServeConfig(batch_slots=8, max_seq=64,
                                       max_new_tokens=16))
    t0 = time.time()
    reqs = engine.run(prompts)
    dt = time.time() - t0
    n_tokens = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests / {n_tokens} tokens in {dt:.2f}s "
          f"({n_tokens/dt:.0f} tok/s on CPU) over {engine.steps} engine steps "
          f"(continuous batching, 8 slots)")
    r = reqs[0]
    print(f"request 0: prompt={list(prompts[0][:6])}... -> "
          f"out={r.out_tokens[:8]}...")
    loader.close()


if __name__ == "__main__":
    main()
