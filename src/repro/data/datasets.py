"""Synthetic datasets + ingestion into the KV store.

``SyntheticImageDataset`` mirrors ImageNet-1k statistics (Table 1: 1.28 M
images, mean 115 kB, lognormal-ish size spread) without materializing bytes —
used by the network benchmarks.

``SyntheticTokenDataset`` produces *real* payloads: token-sequence records
(features+label serialized together, as the paper requires for OOO assembly)
— used by the JAX training integration and the examples.

``ingest`` is the serial/parallel ingestion path (paper Sec. 4.1): rows are
inserted atomically (data+metadata) with seeded UUIDs.
"""

from __future__ import annotations

import struct
import uuid as _uuid
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.core.kvstore import DataRow, KVStore, MetaRow, make_uuid

IMAGENET_MEAN_BYTES = 115_000
IMAGENET_TRAIN_IMAGES = 1_281_167


@dataclass
class SyntheticImageDataset:
    """Size-only image blobs with entity/class metadata (lazy payloads)."""

    n_samples: int = 50_000
    n_classes: int = 1000
    n_entities: int = 2_000            # e.g. patients / photographers
    mean_bytes: int = IMAGENET_MEAN_BYTES
    seed: int = 0

    def rows(self) -> Iterator[Tuple[DataRow, MetaRow]]:
        rng = np.random.default_rng(self.seed)
        # lognormal around the ImageNet mean with a realistic spread
        mu = np.log(self.mean_bytes) - 0.5 * 0.45 ** 2
        for _ in range(self.n_samples):
            u = make_uuid(rng)
            size = int(np.clip(rng.lognormal(mu, 0.45), 5_000, 2_000_000))
            label = int(rng.integers(self.n_classes))
            entity = f"ent{int(rng.integers(self.n_entities)):06d}"
            yield (DataRow(u, label, size, payload=None),
                   MetaRow(u, entity, label, {"size": size}))


TOKEN_RECORD_MAGIC = b"TKRC"


def encode_token_record(tokens: np.ndarray, label: int) -> bytes:
    """features+label in ONE blob — the property OOO assembly relies on."""
    tok = np.ascontiguousarray(tokens, dtype=np.int32)
    header = TOKEN_RECORD_MAGIC + struct.pack("<ii", int(label), tok.size)
    return header + tok.tobytes()


def decode_token_record(blob: bytes) -> Tuple[np.ndarray, int]:
    if blob[:4] != TOKEN_RECORD_MAGIC:
        raise ValueError("not a token record")
    label, n = struct.unpack("<ii", blob[4:12])
    tokens = np.frombuffer(blob, dtype=np.int32, offset=12, count=n)
    return tokens, label


@dataclass
class SyntheticTokenDataset:
    """Real token-sequence payloads for end-to-end JAX training."""

    n_samples: int = 4096
    seq_len: int = 128
    vocab: int = 32000
    n_classes: int = 8
    n_entities: int = 64
    seed: int = 0

    def rows(self) -> Iterator[Tuple[DataRow, MetaRow]]:
        rng = np.random.default_rng(self.seed)
        for _ in range(self.n_samples):
            u = make_uuid(rng)
            # structured "language": a drifting Markov-ish walk is learnable
            start = int(rng.integers(self.vocab))
            steps = rng.integers(-32, 33, size=self.seq_len)
            tokens = (start + np.cumsum(steps)) % self.vocab
            label = int(rng.integers(self.n_classes))
            blob = encode_token_record(tokens.astype(np.int32), label)
            entity = f"ent{int(rng.integers(self.n_entities)):04d}"
            yield (DataRow(u, label, len(blob), payload=blob),
                   MetaRow(u, entity, label, {}))


def ingest(store: KVStore, dataset, parallel: int = 1) -> List[_uuid.UUID]:
    """Serial or chunked-parallel ingestion; returns inserted UUIDs in order.

    (The paper offers serial or Spark-parallel ingestion; here 'parallel'
    chunks the row stream — insertion is atomic per row either way.)
    """
    uuids: List[_uuid.UUID] = []
    rows = list(dataset.rows())
    if parallel > 1:
        import concurrent.futures as cf

        chunks = [rows[i::parallel] for i in range(parallel)]

        def insert_chunk(chunk):
            for data, meta in chunk:
                store.insert_atomic(data, meta)

        with cf.ThreadPoolExecutor(max_workers=parallel) as ex:
            list(ex.map(insert_chunk, chunks))
    else:
        for data, meta in rows:
            store.insert_atomic(data, meta)
    uuids.extend(r[0].uuid for r in rows)
    return uuids


__all__ = ["SyntheticImageDataset", "SyntheticTokenDataset", "ingest",
           "encode_token_record", "decode_token_record",
           "IMAGENET_MEAN_BYTES", "IMAGENET_TRAIN_IMAGES"]
