"""Synthetic datasets + ingestion into the KV store.

``SyntheticImageDataset`` mirrors ImageNet-1k statistics (Table 1: 1.28 M
images, mean 115 kB, lognormal-ish size spread) without materializing bytes —
used by the network benchmarks.

``SyntheticTokenDataset`` produces *real* payloads: token-sequence records
(features+label serialized together, as the paper requires for OOO assembly)
— used by the JAX training integration and the examples.

``ingest`` is the serial/parallel ingestion path (paper Sec. 4.1): rows are
inserted atomically (data+metadata) with seeded UUIDs.
"""

from __future__ import annotations

import struct
import uuid as _uuid
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.core.kvstore import DataRow, KVStore, MetaRow, make_uuid

IMAGENET_MEAN_BYTES = 115_000
IMAGENET_TRAIN_IMAGES = 1_281_167


@dataclass
class SyntheticImageDataset:
    """Size-only image blobs with entity/class metadata (lazy payloads)."""

    n_samples: int = 50_000
    n_classes: int = 1000
    n_entities: int = 2_000            # e.g. patients / photographers
    mean_bytes: int = IMAGENET_MEAN_BYTES
    seed: int = 0

    def rows(self) -> Iterator[Tuple[DataRow, MetaRow]]:
        rng = np.random.default_rng(self.seed)
        # lognormal around the ImageNet mean with a realistic spread
        mu = np.log(self.mean_bytes) - 0.5 * 0.45 ** 2
        for _ in range(self.n_samples):
            u = make_uuid(rng)
            size = int(np.clip(rng.lognormal(mu, 0.45), 5_000, 2_000_000))
            label = int(rng.integers(self.n_classes))
            entity = f"ent{int(rng.integers(self.n_entities)):06d}"
            yield (DataRow(u, label, size, payload=None),
                   MetaRow(u, entity, label, {"size": size}))


TOKEN_RECORD_MAGIC = b"TKRC"


def encode_token_record(tokens: np.ndarray, label: int) -> bytes:
    """features+label in ONE blob — the property OOO assembly relies on."""
    tok = np.ascontiguousarray(tokens, dtype=np.int32)
    header = TOKEN_RECORD_MAGIC + struct.pack("<ii", int(label), tok.size)
    return header + tok.tobytes()


def decode_token_record(blob) -> Tuple[np.ndarray, int]:
    """Accepts any byte buffer — including the zero-copy memoryviews an
    arena-backed batch serves from ``AssembledBatch.payloads()``."""
    if bytes(blob[:4]) != TOKEN_RECORD_MAGIC:
        raise ValueError("not a token record")
    label, n = struct.unpack("<ii", blob[4:12])
    tokens = np.frombuffer(blob, dtype=np.int32, offset=12, count=n)
    return tokens, label


@dataclass
class SyntheticTokenDataset:
    """Real token-sequence payloads for end-to-end JAX training."""

    n_samples: int = 4096
    seq_len: int = 128
    vocab: int = 32000
    n_classes: int = 8
    n_entities: int = 64
    seed: int = 0

    def rows(self) -> Iterator[Tuple[DataRow, MetaRow]]:
        rng = np.random.default_rng(self.seed)
        for _ in range(self.n_samples):
            u = make_uuid(rng)
            # structured "language": a drifting Markov-ish walk is learnable
            start = int(rng.integers(self.vocab))
            steps = rng.integers(-32, 33, size=self.seq_len)
            tokens = (start + np.cumsum(steps)) % self.vocab
            label = int(rng.integers(self.n_classes))
            blob = encode_token_record(tokens.astype(np.int32), label)
            entity = f"ent{int(rng.integers(self.n_entities)):04d}"
            yield (DataRow(u, label, len(blob), payload=blob),
                   MetaRow(u, entity, label, {}))


@dataclass
class SyntheticPixelDataset:
    """Real fixed-size pixel payloads: raw (h, w, c) uint8 frames.

    Every row is exactly ``h*w*c`` bytes with no per-record header — the
    shape IS the codec — so an arena slab sized to ``nbytes`` holds a whole
    batch as one contiguous (B, h, w, c) tensor and the device feed can
    upload it with a single ``device_put`` (see ``data.pipeline.ImageFeed``).

    Frames are piecewise-constant colour fields (smooth sinusoids quantized
    to 16 levels, one phase set per class): realistic-looking *compressible*
    bytes, so the ``byteshuffle`` wire codec gets the long runs real images
    give it — unlike the uniformly random ``DataRow.materialize`` payloads,
    which are incompressible by construction.
    """

    n_samples: int = 1024
    h: int = 32
    w: int = 32
    c: int = 3
    n_classes: int = 10
    n_entities: int = 64
    seed: int = 0

    @property
    def nbytes(self) -> int:
        """Bytes per frame (== the arena slot size for this dataset)."""
        return self.h * self.w * self.c

    def make_frame(self, rng: np.random.Generator, label: int) -> np.ndarray:
        # The sinusoid is sampled on a coarse grid and block-upsampled, so
        # frames are piecewise-constant in >= (h//8, w//8) blocks — real
        # byte runs for the byteshuffle codec's RLE stage, not just a claim.
        by, bx = max(1, self.h // 8), max(1, self.w // 8)
        gh, gw = -(-self.h // by), -(-self.w // bx)
        yy = np.linspace(0.0, 1.0, gh)[:, None]
        xx = np.linspace(0.0, 1.0, gw)[None, :]
        img = np.empty((self.h, self.w, self.c), dtype=np.uint8)
        for ch in range(self.c):
            fy = 1.0 + (label % 3)
            fx = 1.0 + ((label + ch) % 4)
            phase = rng.uniform(0.0, 2.0 * np.pi)
            field = 127.5 + 120.0 * np.sin(
                2.0 * np.pi * (yy * fy + xx * fx) + phase)
            coarse = (np.round(field / 16.0) * 16.0).clip(0, 255)
            full = np.repeat(np.repeat(coarse, by, axis=0), bx, axis=1)
            img[..., ch] = full[:self.h, :self.w]
        return img

    def rows(self) -> Iterator[Tuple[DataRow, MetaRow]]:
        rng = np.random.default_rng(self.seed)
        for _ in range(self.n_samples):
            u = make_uuid(rng)
            label = int(rng.integers(self.n_classes))
            blob = self.make_frame(rng, label).tobytes()
            entity = f"ent{int(rng.integers(self.n_entities)):04d}"
            yield (DataRow(u, label, len(blob), payload=blob),
                   MetaRow(u, entity, label, {"h": self.h, "w": self.w,
                                              "c": self.c}))


def ingest(store: KVStore, dataset, parallel: int = 1) -> List[_uuid.UUID]:
    """Serial or chunked-parallel ingestion; returns inserted UUIDs in order.

    (The paper offers serial or Spark-parallel ingestion; here 'parallel'
    chunks the row stream — insertion is atomic per row either way.)
    """
    uuids: List[_uuid.UUID] = []
    rows = list(dataset.rows())
    if parallel > 1:
        import concurrent.futures as cf

        chunks = [rows[i::parallel] for i in range(parallel)]

        def insert_chunk(chunk):
            for data, meta in chunk:
                store.insert_atomic(data, meta)

        with cf.ThreadPoolExecutor(max_workers=parallel) as ex:
            list(ex.map(insert_chunk, chunks))
    else:
        for data, meta in rows:
            store.insert_atomic(data, meta)
    uuids.extend(r[0].uuid for r in rows)
    return uuids


__all__ = ["SyntheticImageDataset", "SyntheticTokenDataset",
           "SyntheticPixelDataset", "ingest",
           "encode_token_record", "decode_token_record",
           "IMAGENET_MEAN_BYTES", "IMAGENET_TRAIN_IMAGES"]
