from .datasets import SyntheticImageDataset, SyntheticTokenDataset, ingest

__all__ = ["SyntheticImageDataset", "SyntheticTokenDataset", "ingest"]
