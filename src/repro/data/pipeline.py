"""Loader -> JAX device feed.

Bridges the paper's loader (AssembledBatch of token-record blobs) to jitted
train steps:
  * decodes token records on host (numpy),
  * assembles the per-host shard of the global batch,
  * forms jax.Arrays laid out for the mesh
    (``jax.make_array_from_process_local_data`` on multi-host,
    plain device_put on single-host),
  * keeps a device-side prefetch queue of depth 2 (double buffering) so
    H2D copy overlaps the train step — the on-device mirror of the paper's
    host-side prefetching.
"""

from __future__ import annotations

import collections
import time
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loader import CassandraLoader
from repro.core.stats import StepStats
from repro.data.datasets import decode_token_record


def batch_to_numpy(batch, seq_len: int, pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Decode an AssembledBatch of token records into dense arrays.

    Reads through ``batch.payloads()`` so arena-backed batches (whose
    per-sample ``payload`` refs were dropped at assembly) decode from
    zero-copy slab views, and legacy batches keep decoding their bytes.
    """
    B = len(batch.samples)
    tokens = np.full((B, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((B, seq_len), dtype=np.float32)
    labels = np.zeros((B,), dtype=np.int32)
    for i, payload in enumerate(batch.payloads()):
        if payload is None:
            raise ValueError("pipeline requires materialized payloads "
                             "(LoaderConfig.materialize=True)")
        toks, label = decode_token_record(payload)
        n = min(len(toks), seq_len)
        tokens[i, :n] = toks[:n]
        mask[i, :n] = 1.0
        labels[i] = label
    return {"tokens": tokens, "loss_mask": mask, "labels": labels}


class DeviceFeed:
    """Iterator of device-resident batches with double buffering.

    Beyond forming device arrays, the feed is the measurement point for
    per-step data-stall accounting: every ``__next__`` reports to
    ``step_stats`` (a ``core.stats.StepStats``) how long it blocked on the
    loader — on the *loader's* clock, so virtual-clock sims and wall-clock
    runs are both internally consistent — and whether the batch was served
    straight from an already-assembled buffer.  The training loop closes
    each step with ``step_stats.on_compute``.

    The feed also owns the *consumer-facing* checkpoint position:
    ``state()`` is the loader position rewound by the batches sitting in
    the device queue (pulled past the loader cursor but never handed to the
    trainer).  Checkpointing ``loader.state()`` directly would skip those
    in-flight batches on restore; checkpointing ``feed.state()`` makes
    restore exactly-once.
    """

    def __init__(self, loader: CassandraLoader, seq_len: int,
                 shardings: Optional[Dict] = None, mesh=None,
                 prefetch: int = 2,
                 step_stats: Optional[StepStats] = None) -> None:
        self.loader = loader
        self.seq_len = seq_len
        self.shardings = shardings
        self.mesh = mesh
        self.prefetch = prefetch
        self.step_stats = step_stats or StepStats(loader.clock)
        self._queue: collections.deque = collections.deque()
        self._started = False

    def _put(self, host_batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        out = {}
        for k, v in host_batch.items():
            sh = (self.shardings or {}).get(k)
            if sh is not None and jax.process_count() > 1:  # pragma: no cover
                out[k] = jax.make_array_from_process_local_data(sh, v)
            elif sh is not None:
                out[k] = jax.device_put(v, sh)
            else:
                out[k] = jax.device_put(v)
        return out

    def _pull_one(self) -> tuple:
        """Pull one batch from the loader onto the device queue.  Returns
        ``(wait_seconds, buffer_hit)`` on the loader's clock."""
        hit = self.loader.ready_batches > 0
        clk = self.loader.clock
        t0 = clk.now()
        batch = self.loader.next_batch()
        wait = clk.now() - t0
        host = batch_to_numpy(batch, self.seq_len)
        # Host copy is complete: recycle the arena slab (no-op without one).
        batch.release()
        self._queue.append((self._put(host), batch))
        return wait, hit

    # -- checkpointing ------------------------------------------------------
    def state(self) -> dict:
        """Consumer-facing loader position: the loader cursor rewound by the
        device-queue batches the trainer has not consumed yet."""
        return self.loader.state(rewind_batches=len(self._queue))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        wait, hit = 0.0, True
        if not self._started:
            if not self.loader.started:
                self.loader.start()
            self._started = True
            for _ in range(self.prefetch):
                w, h = self._pull_one()
                wait += w
                hit = hit and h
        dev_batch, meta = self._queue.popleft()
        w, h = self._pull_one()              # refill behind the consumer
        self.step_stats.on_wait(wait + w, blocked=not (hit and h))
        return dev_batch, meta


class ImageFeed:
    """Loader -> device feed for fixed-size pixel rows (e.g.
    ``SyntheticPixelDataset``) with fused on-device crop/mirror/normalize.

    Two host paths, selected by whether the loader carries a pinned arena
    (``LoaderConfig.use_arena=True``):

    * **arena** (zero-copy): ``batch.pixels()`` views the slab as one
      contiguous ``(B, h, w, c)`` uint8 tensor, a *single* ``device_put``
      uploads it, and the Pallas ``crop_mirror_normalize`` kernel does the
      crop + mirror + uint8->f32 + normalize + HWC->CHW fused on device.
      The host never materializes a float batch.
    * **materialize** (baseline): per-sample ``np.frombuffer`` -> stack ->
      the NumPy reference transform (four passes over f32 data) ->
      ``device_put`` of the float output.  This is the classic CPU pipeline
      the paper's DALI path replaces.

    Both paths draw crop offsets / mirror flags from the same seeded RNG
    stream (one draw per batch, in pull order), so a pair of runs that
    differs only in the path produces identical augmentations — the
    property ``bench_wirefmt``'s equivalence check and the host-CPU ratio
    comparison rely on.  Per-batch host prep wall time (everything up to
    and including the H2D hand-off, *not* device compute) accumulates in
    ``host_prep_s``.
    """

    def __init__(self, loader: CassandraLoader, h: int, w: int, c: int,
                 out_h: int, out_w: int,
                 mean=None, std=None, seed: int = 0, prefetch: int = 2,
                 step_stats: Optional[StepStats] = None) -> None:
        self.loader = loader
        self.h, self.w, self.c = h, w, c
        self.out_h, self.out_w = out_h, out_w
        self.mean = np.asarray(
            mean if mean is not None else [127.5] * c, dtype=np.float32)
        self.std = np.asarray(
            std if std is not None else [64.0] * c, dtype=np.float32)
        self.prefetch = prefetch
        self.step_stats = step_stats or StepStats(loader.clock)
        self.mode = "arena" if getattr(loader, "arena", None) else "materialize"
        self.host_prep_s = 0.0
        self.batches = 0
        self._rng = np.random.default_rng(seed)
        self._queue: collections.deque = collections.deque()
        self._started = False

    def _augment_draws(self, B: int):
        oy = self._rng.integers(0, self.h - self.out_h + 1, size=B)
        ox = self._rng.integers(0, self.w - self.out_w + 1, size=B)
        mirror = self._rng.integers(0, 2, size=B)
        return (oy.astype(np.int32), ox.astype(np.int32),
                mirror.astype(np.int32))

    def _form(self, batch) -> Dict[str, jax.Array]:
        # Kernel imports stay lazy: token-path users of this module never
        # pay for building the Pallas kernels.
        from repro.kernels import ops as kernel_ops
        from repro.kernels.ref import crop_mirror_normalize_np

        B = len(batch.samples)
        oy, ox, mirror = self._augment_draws(B)
        labels = batch.labels
        if self.mode == "arena":
            t0 = time.perf_counter()
            pix = batch.pixels(self.h, self.w, self.c)   # zero-copy view
            img_dev = jax.device_put(pix)                # ONE uint8 upload
            self.host_prep_s += time.perf_counter() - t0
            batch.release()          # slab uploaded; recycle it
            images = kernel_ops.crop_mirror_normalize(
                img_dev, jnp.asarray(oy), jnp.asarray(ox),
                jnp.asarray(mirror), jnp.asarray(self.mean),
                jnp.asarray(self.std), out_h=self.out_h, out_w=self.out_w)
        else:
            t0 = time.perf_counter()
            n = self.h * self.w * self.c
            imgs = np.stack([
                np.frombuffer(p, dtype=np.uint8,
                              count=n).reshape(self.h, self.w, self.c)
                for p in batch.payloads()])
            host = crop_mirror_normalize_np(
                imgs, oy, ox, mirror, self.mean, self.std,
                self.out_h, self.out_w)
            images = jax.device_put(host)
            self.host_prep_s += time.perf_counter() - t0
        self.batches += 1
        return {"images": images, "labels": jax.device_put(labels)}

    def _pull_one(self) -> tuple:
        hit = self.loader.ready_batches > 0
        clk = self.loader.clock
        t0 = clk.now()
        batch = self.loader.next_batch()
        wait = clk.now() - t0
        self._queue.append((self._form(batch), batch))
        return wait, hit

    def state(self) -> dict:
        """Consumer-facing loader position (see ``DeviceFeed.state``)."""
        return self.loader.state(rewind_batches=len(self._queue))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        wait, hit = 0.0, True
        if not self._started:
            if not self.loader.started:
                self.loader.start()
            self._started = True
            for _ in range(self.prefetch):
                w, h = self._pull_one()
                wait += w
                hit = hit and h
        dev_batch, meta = self._queue.popleft()
        w, h = self._pull_one()              # refill behind the consumer
        self.step_stats.on_wait(wait + w, blocked=not (hit and h))
        return dev_batch, meta


__all__ = ["DeviceFeed", "ImageFeed", "batch_to_numpy"]
