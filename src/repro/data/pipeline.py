"""Loader -> JAX device feed.

Bridges the paper's loader (AssembledBatch of token-record blobs) to jitted
train steps:
  * decodes token records on host (numpy),
  * assembles the per-host shard of the global batch,
  * forms jax.Arrays laid out for the mesh
    (``jax.make_array_from_process_local_data`` on multi-host,
    plain device_put on single-host),
  * keeps a device-side prefetch queue of depth 2 (double buffering) so
    H2D copy overlaps the train step — the on-device mirror of the paper's
    host-side prefetching.
"""

from __future__ import annotations

import collections
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loader import CassandraLoader
from repro.core.stats import StepStats
from repro.data.datasets import decode_token_record


def batch_to_numpy(batch, seq_len: int, pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Decode an AssembledBatch of token records into dense arrays."""
    B = len(batch.samples)
    tokens = np.full((B, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((B, seq_len), dtype=np.float32)
    labels = np.zeros((B,), dtype=np.int32)
    for i, s in enumerate(batch.samples):
        if s.payload is None:
            raise ValueError("pipeline requires materialized payloads "
                             "(LoaderConfig.materialize=True)")
        toks, label = decode_token_record(s.payload)
        n = min(len(toks), seq_len)
        tokens[i, :n] = toks[:n]
        mask[i, :n] = 1.0
        labels[i] = label
    return {"tokens": tokens, "loss_mask": mask, "labels": labels}


class DeviceFeed:
    """Iterator of device-resident batches with double buffering.

    Beyond forming device arrays, the feed is the measurement point for
    per-step data-stall accounting: every ``__next__`` reports to
    ``step_stats`` (a ``core.stats.StepStats``) how long it blocked on the
    loader — on the *loader's* clock, so virtual-clock sims and wall-clock
    runs are both internally consistent — and whether the batch was served
    straight from an already-assembled buffer.  The training loop closes
    each step with ``step_stats.on_compute``.

    The feed also owns the *consumer-facing* checkpoint position:
    ``state()`` is the loader position rewound by the batches sitting in
    the device queue (pulled past the loader cursor but never handed to the
    trainer).  Checkpointing ``loader.state()`` directly would skip those
    in-flight batches on restore; checkpointing ``feed.state()`` makes
    restore exactly-once.
    """

    def __init__(self, loader: CassandraLoader, seq_len: int,
                 shardings: Optional[Dict] = None, mesh=None,
                 prefetch: int = 2,
                 step_stats: Optional[StepStats] = None) -> None:
        self.loader = loader
        self.seq_len = seq_len
        self.shardings = shardings
        self.mesh = mesh
        self.prefetch = prefetch
        self.step_stats = step_stats or StepStats(loader.clock)
        self._queue: collections.deque = collections.deque()
        self._started = False

    def _put(self, host_batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        out = {}
        for k, v in host_batch.items():
            sh = (self.shardings or {}).get(k)
            if sh is not None and jax.process_count() > 1:  # pragma: no cover
                out[k] = jax.make_array_from_process_local_data(sh, v)
            elif sh is not None:
                out[k] = jax.device_put(v, sh)
            else:
                out[k] = jax.device_put(v)
        return out

    def _pull_one(self) -> tuple:
        """Pull one batch from the loader onto the device queue.  Returns
        ``(wait_seconds, buffer_hit)`` on the loader's clock."""
        hit = self.loader.ready_batches > 0
        clk = self.loader.clock
        t0 = clk.now()
        batch = self.loader.next_batch()
        wait = clk.now() - t0
        host = batch_to_numpy(batch, self.seq_len)
        self._queue.append((self._put(host), batch))
        return wait, hit

    # -- checkpointing ------------------------------------------------------
    def state(self) -> dict:
        """Consumer-facing loader position: the loader cursor rewound by the
        device-queue batches the trainer has not consumed yet."""
        return self.loader.state(rewind_batches=len(self._queue))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        wait, hit = 0.0, True
        if not self._started:
            if not self.loader.started:
                self.loader.start()
            self._started = True
            for _ in range(self.prefetch):
                w, h = self._pull_one()
                wait += w
                hit = hit and h
        dev_batch, meta = self._queue.popleft()
        w, h = self._pull_one()              # refill behind the consumer
        self.step_stats.on_wait(wait + w, blocked=not (hit and h))
        return dev_batch, meta


__all__ = ["DeviceFeed", "batch_to_numpy"]
