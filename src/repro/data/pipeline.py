"""Loader -> JAX device feed.

Bridges the paper's loader (AssembledBatch of token-record blobs) to jitted
train steps:
  * decodes token records on host (numpy),
  * assembles the per-host shard of the global batch,
  * forms jax.Arrays laid out for the mesh
    (``jax.make_array_from_process_local_data`` on multi-host,
    plain device_put on single-host),
  * keeps a device-side prefetch queue of depth 2 (double buffering) so
    H2D copy overlaps the train step — the on-device mirror of the paper's
    host-side prefetching.
"""

from __future__ import annotations

import collections
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loader import CassandraLoader
from repro.data.datasets import decode_token_record


def batch_to_numpy(batch, seq_len: int, pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Decode an AssembledBatch of token records into dense arrays."""
    B = len(batch.samples)
    tokens = np.full((B, seq_len), pad_id, dtype=np.int32)
    mask = np.zeros((B, seq_len), dtype=np.float32)
    labels = np.zeros((B,), dtype=np.int32)
    for i, s in enumerate(batch.samples):
        if s.payload is None:
            raise ValueError("pipeline requires materialized payloads "
                             "(LoaderConfig.materialize=True)")
        toks, label = decode_token_record(s.payload)
        n = min(len(toks), seq_len)
        tokens[i, :n] = toks[:n]
        mask[i, :n] = 1.0
        labels[i] = label
    return {"tokens": tokens, "loss_mask": mask, "labels": labels}


class DeviceFeed:
    """Iterator of device-resident batches with double buffering."""

    def __init__(self, loader: CassandraLoader, seq_len: int,
                 shardings: Optional[Dict] = None, mesh=None,
                 prefetch: int = 2) -> None:
        self.loader = loader
        self.seq_len = seq_len
        self.shardings = shardings
        self.mesh = mesh
        self.prefetch = prefetch
        self._queue: collections.deque = collections.deque()
        self._started = False

    def _put(self, host_batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        out = {}
        for k, v in host_batch.items():
            sh = (self.shardings or {}).get(k)
            if sh is not None and jax.process_count() > 1:  # pragma: no cover
                out[k] = jax.make_array_from_process_local_data(sh, v)
            elif sh is not None:
                out[k] = jax.device_put(v, sh)
            else:
                out[k] = jax.device_put(v)
        return out

    def _pull_one(self) -> None:
        batch = self.loader.next_batch()
        host = batch_to_numpy(batch, self.seq_len)
        self._queue.append((self._put(host), batch))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if not self._started:
            if not self.loader.prefetcher._started:
                self.loader.start()
            self._started = True
            for _ in range(self.prefetch):
                self._pull_one()
        dev_batch, meta = self._queue.popleft()
        self._pull_one()                     # refill behind the consumer
        return dev_batch, meta


__all__ = ["DeviceFeed", "batch_to_numpy"]
