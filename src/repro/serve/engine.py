"""Batched serving engine: continuous batching over a fixed-slot KV cache.

Requests (prompts fetched from the KV store via the paper's loader, or given
directly) occupy batch slots; each engine step decodes one token for every
active slot; finished slots are refilled from the queue — the standard
continuous-batching pattern, with the *data-loading* side (prompt blobs over
the network) handled by the same out-of-order prefetching loader as training.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.step import make_serve_step


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int = 8
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1              # -1: run to max_new_tokens
    greedy: bool = True
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, cfg: ServeConfig) -> None:
        self.model = model
        self.params = params
        self.cfg = cfg
        self.step_fn = jax.jit(make_serve_step(model), donate_argnums=(1,))
        self.cache = model.init_cache(cfg.batch_slots, cfg.max_seq)
        self.slots: List[Optional[Request]] = [None] * cfg.batch_slots
        self.queue: List[Request] = []
        self._slot_pending: List[List[int]] = [[] for _ in range(cfg.batch_slots)]
        self._next_token = np.zeros((cfg.batch_slots, 1), np.int32)
        self._rng = np.random.default_rng(cfg.seed)
        self.steps = 0

    # -- request management --------------------------------------------------
    def submit(self, prompt: np.ndarray, rid: Optional[int] = None) -> Request:
        req = Request(rid=rid if rid is not None else len(self.queue),
                      prompt=np.asarray(prompt, np.int32))
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for i in range(self.cfg.batch_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # prompt tokens are fed one at a time through decode steps
                # (single-token engine keeps the step shape static)
                self._slot_pending[i] = list(req.prompt)
                self._next_token[i, 0] = self._slot_pending[i].pop(0)

    # -- stepping ---------------------------------------------------------
    def step(self) -> None:
        self._admit()
        tokens = jnp.asarray(self._next_token)
        logits, self.cache = self.step_fn(self.params, self.cache, tokens)
        self.steps += 1
        next_ids = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self._slot_pending[i]:
                # still consuming the prompt: feed next prompt token
                self._next_token[i, 0] = self._slot_pending[i].pop(0)
                continue
            tok = int(next_ids[i])
            req.out_tokens.append(tok)
            self._next_token[i, 0] = tok
            if (tok == self.cfg.eos_id
                    or len(req.out_tokens) >= self.cfg.max_new_tokens):
                req.done = True
                self.slots[i] = None     # slot freed -> continuous batching
        # note: freed slots keep stale cache entries; new occupants overwrite
        # positions from their own pos counter in a fresh engine. For exact
        # isolation per slot, production would track per-slot pos; here the
        # engine is drained per wave (see run()).

    def run(self, requests: List[np.ndarray]) -> List[Request]:
        """Serve a list of prompts to completion (wave-scheduled)."""
        out: List[Request] = []
        for r in requests:
            out.append(self.submit(r))
        while self.queue or any(s is not None for s in self.slots):
            self.step()
        return out


__all__ = ["ServeConfig", "ServingEngine", "Request"]
