"""Architecture & shape configuration schema + registry."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    window: int = 0                # sliding-window size for SWA attention
    # --- enc-dec / modality stubs ---
    enc_layers: int = 0
    enc_frames: int = 0            # audio frontend stub: frames fed to encoder
    n_patches: int = 0             # vlm frontend stub: patch embeddings
    # --- runtime ---
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # sharding profile: "tp" (params sharded over model axis only) or
    # "fsdp_tp" (additionally sharded over the data axis — big models)
    sharding_profile: str = "tp"
    source: str = ""               # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke_config(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16, d_ff=128, vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            window=min(self.window, 16) if self.window else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            enc_frames=min(self.enc_frames, 24) if self.enc_frames else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            dtype="float32", scan_layers=True, remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    def smoke(self) -> "ShapeConfig":
        return dataclasses.replace(self, seq_len=min(self.seq_len, 64),
                                   global_batch=min(self.global_batch, 2))


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

ARCH_IDS: List[str] = [
    "qwen3_4b", "yi_34b", "qwen3_14b", "stablelm_1_6b", "whisper_tiny",
    "grok_1_314b", "kimi_k2_1t_a32b", "hymba_1_5b", "xlstm_350m",
    "internvl2_2b",
]

# long_500k needs sub-quadratic attention: runs only for ssm/hybrid families.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def applicable_shapes(cfg: ArchConfig) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in LONG_CONTEXT_FAMILIES:
        out.append("long_500k")
    return out


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        for shape in applicable_shapes(cfg):
            cells.append((aid, shape))
    return cells


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_arch",
           "applicable_shapes", "all_cells", "LONG_CONTEXT_FAMILIES"]
