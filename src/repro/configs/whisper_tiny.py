"""whisper-tiny [audio] — enc-dec, conv frontend stubbed as 1500 precomputed
frame embeddings. [arXiv:2212.04356]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865,
    head_dim=64,
    enc_layers=4, enc_frames=1500,
    sharding_profile="tp",
    source="arXiv:2212.04356 (unverified)",
)
