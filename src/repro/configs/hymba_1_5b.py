"""hymba-1.5b [hybrid] — parallel attention+mamba heads, SWA attention.
[arXiv:2411.13676; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    head_dim=64,
    ssm_state=16, window=1024,    # Hymba uses SWA for most layers
    sharding_profile="tp",
    source="arXiv:2411.13676",
)
