"""stablelm-1.6b [dense] — MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352,
    head_dim=64,
    rope_theta=1e4,
    sharding_profile="tp",
    source="hf:stabilityai/stablelm-2-1_6b (unverified)",
)
