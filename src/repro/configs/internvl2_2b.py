"""internvl2-2b [vlm] — InternViT frontend stubbed as 256 precomputed patch
embeddings scattered over the leading token positions; InternLM2 backbone.
[arXiv:2404.16821; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553,
    head_dim=128,
    n_patches=256,
    rope_theta=1e6,
    sharding_profile="tp",
    source="arXiv:2404.16821",
)
