"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936,
    head_dim=128,
    qk_norm=True, rope_theta=1e6,
    sharding_profile="fsdp_tp",
    source="hf:Qwen/Qwen3-8B (family); assigned dims",
)
