"""qwen3-4b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936,
    head_dim=128,          # Qwen3 uses an explicit 128 head_dim
    qk_norm=True, rope_theta=1e6,
    sharding_profile="tp",
    source="hf:Qwen/Qwen3-8B (family); assigned dims",
)
