"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks, d_ff=0.
[arXiv:2405.04517]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    head_dim=256,
    sharding_profile="tp",
    source="arXiv:2405.04517 (unverified)",
)
