from .base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig, all_cells,
                   applicable_shapes, get_arch)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "all_cells",
           "applicable_shapes", "get_arch"]
