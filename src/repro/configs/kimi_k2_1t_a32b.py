"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 with
d_ff=2048 per expert. [arXiv:2501.kimi2 (paper-table; unverified)]

Fits 512x16GB only with 8-bit optimizer state + full FSDPxTP parameter
sharding (see train/optimizer.py and EXPERIMENTS.md §Dry-run).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840,
    head_dim=112,
    n_experts=384, top_k=8, capacity_factor=1.25,
    sharding_profile="fsdp_tp",
    source="arXiv:2501.kimi2 (paper-table; unverified)",
)
