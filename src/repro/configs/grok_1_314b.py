"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1]"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    head_dim=128,
    n_experts=8, top_k=2, capacity_factor=1.25,
    sharding_profile="fsdp_tp",
    source="hf:xai-org/grok-1 (unverified)",
)
