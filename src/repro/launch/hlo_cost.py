"""HLO cost analyzer with control-flow multiplicity.

``compiled.cost_analysis()`` visits every computation ONCE — a scan over 61
layers or 16 microbatches under-counts FLOPs/bytes/collective traffic by the
trip count, which poisons roofline math for scanned models.  This analyzer
re-derives the three roofline inputs from the optimized HLO text:

  * FLOPs: 2 * prod(out_dims) * prod(lhs contracting dims) per dot
    (convolutions are not used by these models);
  * HBM bytes: operand+output bytes of materialized (top-level) ops —
    fusion internals are VMEM/register traffic and excluded;
  * collective bytes: operand bytes per all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;

each multiplied by the product of enclosing `while` trip counts
(``known_trip_count`` backend_config, emitted for counted scans).

This is an estimator: CSE/in-place details are invisible, but loop
multiplicity — the dominant error, up to ~1000x — is handled exactly.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops with no real data movement
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+"
                    r"\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')


def _shape_bytes_list(type_str: str) -> List[Tuple[str, int, int]]:
    """[(dtype, numel, bytes)] for a (possibly tuple) type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dt, n, n * DTYPE_BYTES[dt]))
    return out


def _total_bytes(type_str: str) -> int:
    return sum(b for _, _, b in _shape_bytes_list(type_str))


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    rest: str                      # args + attrs blob
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)   # %name -> type
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)     # %name -> out type
    max_s32_const: int = 0          # loop-bound heuristic for while conds

_COMMENT_RE = re.compile(r"/\*.*?\*/")
_S32_CONST_RE = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)   # strip /*index=N*/ tuple comments
        stripped = line.strip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameters typed in the signature
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^()]*\)|[a-z0-9]+"
                                      r"\[[0-9,]*\](?:\{[^}]*\})?)",
                                      m.group(2)):
                    cur.params["%" + pm.group(1)] = pm.group(2)
                    cur.defs["%" + pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cm = _S32_CONST_RE.search(line)
        if cm:
            cur.max_s32_const = max(cur.max_s32_const, int(cm.group(1)))
        m = _OP_RE.match(line)
        if not m:
            # parameter declarations inside body: "%p = f32[...] parameter(0)"
            continue
        name, out_type, kind, rest = m.groups()
        # operand names: %refs before the closing paren of the arg list
        depth, i, args_end = 1, 0, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args_end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", rest[:args_end])
        op = Op("%" + name, kind, out_type, rest, ["%" + o for o in operands])
        cur.ops.append(op)
        cur.defs[op.name] = out_type
    return comps, entry


def _dot_flops(comp: Computation, op: Op) -> int:
    out_elems = sum(n for _, n, _ in _shape_bytes_list(op.out_type))
    lhs_type = comp.defs.get(op.operands[0], "") if op.operands else ""
    shapes = _SHAPE_RE.findall(lhs_type)
    if not shapes:
        return 0
    dims = [int(d) for d in shapes[0][1].split(",") if d] or [1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    k = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                k *= dims[i]
    return 2 * out_elems * k


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0, "bytes": 0, "collective_bytes": 0,
                "collectives": {}}

    fusion_bodies = set()
    for comp in comps.values():
        for op in comp.ops:
            m = re.search(r"calls=%([\w.\-]+)", op.rest)
            if m and op.kind == "fusion":
                fusion_bodies.add(m.group(1))

    from functools import lru_cache

    _SLICE_KINDS = {"dynamic-slice", "slice", "gather"}
    _PASSTHRU = {"bitcast", "reshape", "convert", "copy", "transpose"}

    @lru_cache(maxsize=None)
    def fusion_param_charges(comp_name: str) -> Dict[int, int]:
        """param index -> charged bytes, for params consumed only through a
        slice/gather inside the fusion (true traffic = slice size)."""
        comp = comps.get(comp_name)
        if comp is None:
            return {}
        param_order = list(comp.params.keys())
        # name -> source param (transitively through pass-through ops)
        src: Dict[str, str] = {p: p for p in param_order}
        sliced: Dict[str, int] = {}
        consumed_other: set = set()
        for op in comp.ops:
            if op.kind == "parameter":
                continue
            if op.kind in _PASSTHRU and op.operands:
                o = op.operands[0]
                if o in src:
                    src[op.name] = src[o]
                continue
            for i, o in enumerate(op.operands):
                p = src.get(o)
                if p is None:
                    continue
                if op.kind in _SLICE_KINDS and i == 0:
                    sliced[p] = sliced.get(p, 0) + _total_bytes(op.out_type)
                else:
                    consumed_other.add(p)
        out = {}
        for idx, p in enumerate(param_order):
            if p in sliced and p not in consumed_other:
                out[idx] = sliced[p]
        return out

    @lru_cache(maxsize=None)
    def fusion_dot_flops(comp_name: str) -> int:
        comp = comps.get(comp_name)
        if comp is None:
            return 0
        total = 0
        for op in comp.ops:
            if op.kind == "dot":
                total += _dot_flops(comp, op)
            m = re.search(r"calls=%([\w.\-]+)", op.rest)
            if m:
                total += fusion_dot_flops(m.group(1))
        return total

    coll_totals = {c: 0.0 for c in COLLECTIVES}
    seen = set()

    def cost_of(comp_name: str, mult: float) -> Tuple[float, float, float]:
        comp = comps.get(comp_name)
        if comp is None:
            return 0.0, 0.0, 0.0
        flops = bytes_ = coll = 0.0
        for op in comp.ops:
            if op.kind in _FREE_OPS:
                continue
            if op.kind == "while":
                tm = _TRIP_RE.search(op.rest)
                body = re.search(r"body=%([\w.\-]+)", op.rest)
                cond = re.search(r"condition=%([\w.\-]+)", op.rest)
                if tm:
                    trips = int(tm.group(1))
                else:
                    # counted scans: loop bound is the s32 constant the
                    # condition compares against (start 0, step 1)
                    cc = comps.get(cond.group(1)) if cond else None
                    trips = max(cc.max_s32_const, 1) if cc else 1
                for target in (body, cond):
                    if target:
                        f, b, c = cost_of(target.group(1), mult * trips)
                        flops += f
                        bytes_ += b
                        coll += c
                continue
            if op.kind == "conditional":
                for target in re.findall(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations=\{)[^,}]*%([\w.\-]+)", op.rest):
                    f, b, c = cost_of(target, mult)
                    flops += f
                    bytes_ += b
                    coll += c
                continue
            if op.kind == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", op.rest)
                if m:
                    f, b, c = cost_of(m.group(1), mult)
                    flops += f
                    bytes_ += b
                    coll += c
                continue
            out_b = _total_bytes(op.out_type)
            operand_bytes = [_total_bytes(comp.defs.get(o, ""))
                             for o in op.operands]
            if op.kind in _SLICE_KINDS:
                # reads only the slice, not the source buffer
                in_b = 2 * out_b
            elif op.kind == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", op.rest)
                charges = fusion_param_charges(m.group(1)) if m else {}
                in_b = sum(charges.get(i, b)
                           for i, b in enumerate(operand_bytes))
            else:
                in_b = sum(operand_bytes)
            io = (out_b + in_b) * mult
            if op.kind in ("fusion", "dynamic-update-slice") and \
                    len(op.operands) > 1:
                # in-place update pattern: an operand with the output's exact
                # type aliases the output buffer (DUS / accumulator); true
                # HBM traffic is the non-aliased operands (read) + the same
                # amount written, not the whole carried buffer per iteration.
                out_sig = _SHAPE_RE.findall(op.out_type)
                for o in op.operands:
                    if _SHAPE_RE.findall(comp.defs.get(o, "")) == out_sig \
                            and out_sig:
                        matched = _total_bytes(comp.defs[o])
                        # only a genuine carried buffer: dominant operand of
                        # exactly the output's size
                        if matched == out_b and matched >= 0.5 * in_b:
                            io = 2.0 * max(in_b - matched, 0) * mult
                        break
            if op.kind == "dot":
                flops += _dot_flops(comp, op) * mult
                bytes_ += io
            elif op.kind == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m:
                    flops += fusion_dot_flops(m.group(1)) * mult
                bytes_ += io
            elif op.kind in COLLECTIVES:
                coll += in_b * mult
                coll_totals[op.kind] += in_b * mult
                bytes_ += io
            else:
                bytes_ += io
        return flops, bytes_, coll

    flops, bytes_, coll = cost_of(entry, 1.0)
    return {"flops": flops, "bytes": bytes_, "collective_bytes": coll,
            "collectives": coll_totals}


__all__ = ["analyze", "parse_module"]
