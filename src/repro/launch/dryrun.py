import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below happens only after the device count is pinned --------
import argparse
import json
import sys
import time

from repro.configs.base import ARCH_IDS, applicable_shapes, get_arch
from repro.launch.dryrun_lib import run_cell
from repro.launch.mesh import make_production_mesh


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every (arch x shape) "
                    "cell on the production mesh and dump roofline inputs.")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' (applicable shapes only)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh (default 16x16)")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run each cell on single-pod AND multi-pod meshes")
    ap.add_argument("--out", default="",
                    help="append JSON-lines results to this file")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    failures = []
    results = []
    for arch_id in archs:
        cfg = get_arch(arch_id)
        shapes = (applicable_shapes(cfg) if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            for mesh in meshes:
                try:
                    res = run_cell(arch_id, shape_name, mesh)
                    results.append(res)
                except Exception as e:  # a failure here is a sharding bug
                    failures.append((arch_id, shape_name,
                                     "x".join(map(str, mesh.devices.shape)),
                                     repr(e)[:500]))
                    print(f"[dryrun] FAIL {arch_id} {shape_name}: {e!r}",
                          file=sys.stderr, flush=True)
    if args.out:
        with open(args.out, "a") as f:
            for r in results:
                f.write(json.dumps(r) + "\n")
    print(f"[dryrun] {len(results)} cells OK, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", *f_[:3])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
