"""Dry-run core: lower + compile every (arch x shape) cell on a mesh and
extract memory / FLOP / collective statistics for the roofline analysis.

Import this ONLY from an entry point that has already set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` (see dryrun.py);
importing jax locks the device count.
"""

from __future__ import annotations

import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_arch
from repro.models import build_model
from repro.sharding.rules import tree_shardings
from repro.train.optimizer import OptimizerConfig
from repro.train.step import (abstract_state, make_prefill_step,
                              make_serve_step, make_train_step,
                              state_logical_axes)

# Per-arch memory policy (derived by napkin math, validated by the probe runs
# recorded in EXPERIMENTS.md §Dry-run):
#   * optimizer state dtype — int8 (grok: 314B params) or int8 + factored
#     second moment (kimi: 1.03T params);
#   * gradient-accumulation microbatch count for train_4k (divides the
#     per-device activation footprint);
#   * gradient accumulator dtype (bf16 for the two giants, f32 otherwise).
OPT_STATE_DTYPE = {
    "grok-1-314b": "int8",
    "kimi-k2-1t-a32b": "int8_factored",
}
# With sequence-parallel activations, layer-boundary saves shrink 16x and
# most archs need NO gradient accumulation (mb>1 would multiply FSDP weight
# gathers by the microbatch count — the dominant collective cost otherwise).
TRAIN_MICROBATCHES = {
    "qwen3-4b": 1, "qwen3-14b": 1, "yi-34b": 1, "stablelm-1.6b": 1,
    "whisper-tiny": 4, "grok-1-314b": 2, "kimi-k2-1t-a32b": 2,
    "hymba-1.5b": 2, "xlstm-350m": 2, "internvl2-2b": 1,
}
ACCUM_DTYPE = {
    "grok-1-314b": jnp.bfloat16,
    "kimi-k2-1t-a32b": jnp.bfloat16,
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in the (SPMD) HLO module."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in COLLECTIVE_OPS:
            marker = f" {op}("
            if marker in stripped and not stripped.startswith("//"):
                # operands are the typed shapes after the opening paren;
                # fall back to the output shape (start of line) if absent.
                paren = stripped.index(marker) + len(marker)
                operand_str = stripped[paren:]
                shapes = _SHAPE_RE.findall(operand_str)
                if not shapes:
                    shapes = _SHAPE_RE.findall(stripped[:paren])[:1]
                nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes
                             if dt in _DTYPE_BYTES)
                out[op] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    return out


def model_flops_estimate(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one token/seq."""
    from repro.models.params import count_params

    model = build_model(cfg)
    n_params = count_params(model.param_specs())
    if cfg.n_experts and cfg.top_k:
        # subtract inactive expert params
        expert_params = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
        active = expert_params * cfg.top_k / cfg.n_experts
        n_active = n_params - expert_params + active
    else:
        n_active = n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def build_cell(arch_id: str, shape_name: str, mesh,
               opt_cfg: Optional[OptimizerConfig] = None,
               microbatches: Optional[int] = None,
               seq_parallel: bool = True):
    """Returns (jitted_fn, abstract_args, cfg, shape) for a cell.

    seq_parallel=True applies the Megatron-SP residual-stream constraint
    (sequence-sharded layer-boundary activations); False is the naive
    baseline recorded in EXPERIMENTS.md §Perf.
    """
    from repro.sharding.rules import make_act_constrainer, make_attn_constrainers

    cfg = get_arch(arch_id)
    model = build_model(cfg)
    if seq_parallel:
        from repro.sharding.rules import make_moe_constrainer
        model.constrain_act = make_act_constrainer(mesh)
        cq, ckv = make_attn_constrainers(mesh)
        model.constrain_q = cq
        model.constrain_kv = ckv
        model.constrain_moe = make_moe_constrainer(mesh)
    shape = SHAPES[shape_name]
    profile = cfg.sharding_profile
    if opt_cfg is None:
        opt_cfg = OptimizerConfig(
            state_dtype=OPT_STATE_DTYPE.get(cfg.name, "float32"))
    if microbatches is None:
        microbatches = TRAIN_MICROBATCHES.get(cfg.name, 1)

    params_sh = tree_shardings(model.abstract_params(),
                               model.param_logical_axes(), mesh, profile)
    input_specs = model.input_specs(shape)
    input_axes = model.input_logical_axes(shape)
    inputs_sh = tree_shardings(input_specs, input_axes, mesh, profile)

    if shape.kind == "train":
        step = make_train_step(model, opt_cfg, microbatches=microbatches,
                               accum_dtype=ACCUM_DTYPE.get(cfg.name,
                                                           jnp.float32))
        state = abstract_state(model, opt_cfg)
        axes = state_logical_axes(model, opt_cfg)
        # ZeRO-1: optimizer state is additionally sharded over the data axis
        # regardless of the parameter profile (touched once per step, so the
        # reshard cost is tiny; saves (8 bytes/param)/dp_size of HBM).
        state_sh = {
            "params": tree_shardings(state["params"], axes["params"], mesh,
                                     profile),
            "opt": tree_shardings(state["opt"], axes["opt"], mesh,
                                  "fsdp_tp"),
        }
        jitted = jax.jit(step, in_shardings=(state_sh, inputs_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        return jitted, (state, input_specs), cfg, shape
    if shape.kind == "prefill":
        step = make_prefill_step(model)
        jitted = jax.jit(step, in_shardings=(params_sh, inputs_sh))
        return jitted, (model.abstract_params(), input_specs), cfg, shape
    # decode
    step = make_serve_step(model)
    cache_spec = input_specs["cache"]
    cache_sh = inputs_sh["cache"]
    tok_spec, tok_sh = input_specs["tokens"], inputs_sh["tokens"]
    jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(1,))
    return jitted, (model.abstract_params(), cache_spec, tok_spec), cfg, shape


def run_cell(arch_id: str, shape_name: str, mesh, verbose: bool = True
             ) -> Dict[str, Any]:
    t0 = time.time()
    jitted, args, cfg, shape = build_cell(arch_id, shape_name, mesh)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # loop-multiplicity-aware analysis (cost_analysis counts scan bodies once)
    from repro.launch.hlo_cost import analyze as hlo_analyze
    hc = hlo_analyze(hlo)
    coll = {k: float(v) for k, v in hc["collectives"].items()}
    coll["total"] = float(hc["collective_bytes"])
    n_dev = int(np.prod(mesh.devices.shape))

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "devices": n_dev,
        "flops_per_device": float(hc["flops"]),
        "bytes_per_device": float(hc["bytes"]),
        "collective_bytes_per_device": coll,
        "raw_cost_analysis": {"flops": float(cost.get("flops", -1)),
                              "bytes": float(cost.get("bytes accessed", -1))},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
        },
        "model_flops_total": model_flops_estimate(cfg, shape),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if verbose:
        m = result["memory"]
        peak = (m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"]
                - max(m["alias_bytes"], 0))
        print(f"[dryrun] {arch_id:18s} {shape_name:12s} mesh={result['mesh']:9s}"
              f" flops/dev={result['flops_per_device']:.3e}"
              f" bytes/dev={result['bytes_per_device']:.3e}"
              f" coll/dev={coll['total']:.3e}"
              f" mem(arg+tmp+out-alias)={peak / 2**30:.2f} GiB"
              f" lower={t_lower:.0f}s compile={t_compile:.0f}s", flush=True)
    return result


__all__ = ["build_cell", "run_cell", "collective_bytes_from_hlo",
           "model_flops_estimate", "OPT_STATE_DTYPE", "TRAIN_MICROBATCHES",
           "ACCUM_DTYPE", "COLLECTIVE_OPS"]
