"""Training launcher.

Two modes:
  * ``--demo``: end-to-end single-host run — ingest a synthetic token
    dataset into the KV store, train a reduced model for N steps with the
    network loader (virtual-clock network), checkpoint/restart enabled.
  * default: production lowering — build the jitted, sharded train step for
    ``--arch`` on the production mesh (requires the dry-run env flag; on a
    real TPU cluster this is where jax.distributed.initialize + per-host
    loaders would engage).

On a multi-host cluster, per-host data loading is configured with
``LoaderConfig(shard_id=jax.process_index(), num_shards=jax.process_count())``
so each host fetches exactly its shard of the global batch.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--route", default="high")
    ap.add_argument("--out-of-order", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.base import get_arch
    from repro.core import KVStore, LoaderConfig
    from repro.data.datasets import SyntheticTokenDataset, ingest
    from repro.models import build_model
    from repro.train.loop import TrainLoopConfig, run_training

    if args.arch == "demo":
        from repro.configs.base import ArchConfig
        cfg = ArchConfig(name="demo-120m", family="dense", n_layers=4,
                         d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                         vocab=32000, head_dim=32, dtype="float32",
                         remat=False)
    else:
        cfg = get_arch(args.arch).smoke_config()
    model = build_model(cfg)

    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(
        n_samples=max(args.batch_size * 64, 2048), seq_len=args.seq_len,
        vocab=cfg.vocab, seed=args.seed))
    loader_cfg = LoaderConfig(batch_size=args.batch_size, prefetch_buffers=8,
                              io_threads=8, route=args.route,
                              out_of_order=bool(args.out_of_order),
                              materialize=True, seed=args.seed)
    loop_cfg = TrainLoopConfig(total_steps=args.steps, seq_len=args.seq_len,
                               checkpoint_dir=args.checkpoint_dir or None,
                               seed=args.seed)
    result = run_training(model, store, uuids, loader_cfg, loop_cfg,
                          on_metrics=lambda m: print(
                              f"step {m['step']:5d} loss {m['loss']:.4f} "
                              f"{m['sps']:.0f} samples/s", flush=True))
    first, last = result["history"][0], result["history"][-1]
    print(f"loss {first['loss']:.4f} -> {last['loss']:.4f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
