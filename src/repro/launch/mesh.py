"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh is
16x16 = 256 chips (v5e pod); multi-pod adds a leading "pod" axis (2 pods =
512 chips).  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to build these meshes on CPU.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
HW = {
    "peak_bf16_flops": 197e12,        # FLOP/s
    "hbm_bandwidth": 819e9,           # B/s
    "ici_link_bandwidth": 50e9,       # B/s per link
    "hbm_bytes": 16 * 2 ** 30,        # 16 GB
}


__all__ = ["make_production_mesh", "make_test_mesh", "HW"]
