"""Serving launcher: continuous-batching decode fed by the network loader.

``--demo`` runs end-to-end on CPU (reduced model, simulated WAN prompts).
On a real cluster this is where the production mesh + per-host loaders
engage (see dryrun.py for the decode-shape sharding that serve_step uses).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="demo")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--route", default="med")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs.base import ArchConfig, get_arch
    from repro.core import CassandraLoader, KVStore, LoaderConfig
    from repro.data.datasets import (SyntheticTokenDataset,
                                     decode_token_record, ingest)
    from repro.models import build_model
    from repro.serve import ServeConfig, ServingEngine

    if args.arch == "demo":
        cfg = ArchConfig(name="serve-demo", family="dense", n_layers=2,
                         d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                         vocab=2048, head_dim=32, dtype="float32",
                         remat=False)
    else:
        cfg = get_arch(args.arch).smoke_config()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(
        n_samples=max(args.requests * 4, 256), seq_len=12, vocab=cfg.vocab,
        seed=args.seed))
    loader = CassandraLoader(store, uuids, LoaderConfig(
        batch_size=args.requests, prefetch_buffers=2, io_threads=2,
        route=args.route, materialize=True, seed=args.seed)).start()
    batch = loader.next_batch()
    prompts = [decode_token_record(s.payload)[0] for s in batch.samples]

    engine = ServingEngine(model, params,
                           ServeConfig(batch_slots=args.slots,
                                       max_seq=64,
                                       max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    reqs = engine.run(prompts)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.0f} tok/s, {engine.steps} engine steps, "
          f"{args.slots} slots)")
    loader.close()


if __name__ == "__main__":
    main()
