"""Cassandra-compatible KV store model: tables, rows, atomic batch insert.

Mirrors the paper's data model (Listing 1): a ``metadata`` table queried only
at split-creation time, and a ``data`` table holding ``(uuid, label, blob)``
rows fetched during training.  Features and annotations travel together in one
row — the property that makes out-of-order batch assembly possible (Sec. 3.4).

Blobs may be *lazy* (size-only) so benchmarks can model a 147 GB dataset
without materializing it; integration tests and examples use real payloads.
"""

from __future__ import annotations

import hashlib
import threading
import uuid as _uuid
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np


def make_uuid(rng: np.random.Generator) -> _uuid.UUID:
    """Deterministic UUID4 from a seeded generator."""
    return _uuid.UUID(bytes=rng.bytes(16), version=4)


@dataclass
class DataRow:
    """Row of the ``data`` table: features + annotation in a single row."""

    uuid: _uuid.UUID
    label: int
    size: int                       # payload size in bytes
    payload: Optional[bytes] = None  # None => lazy blob (benchmarks)

    def materialize(self) -> bytes:
        """Full-size payload — always ``len() == self.size`` so arena copies
        and ``bytes_received`` accounting line up with ``FetchResult.size``."""
        if self.payload is not None:
            return self.payload
        # Deterministic pseudo-payload derived from the uuid.
        seed = int.from_bytes(self.uuid.bytes[:8], "little")
        return np.random.default_rng(seed).bytes(self.size)


@dataclass
class MetaRow:
    """Row of the ``metadata`` table (entity/class info used for splits)."""

    uuid: _uuid.UUID
    entity_id: str                  # e.g. patient_id — must not leak across splits
    label: int
    extra: Dict = field(default_factory=dict)


class KVStore:
    """The logical database: data + metadata tables with atomic batch insert."""

    def __init__(self, keyspace: str = "patches") -> None:
        self.keyspace = keyspace
        self._data: Dict[_uuid.UUID, DataRow] = {}
        self._meta: Dict[_uuid.UUID, MetaRow] = {}
        self._lock = threading.Lock()

    # -- writes --------------------------------------------------------------
    def insert_atomic(self, data: DataRow, meta: MetaRow) -> None:
        """Cassandra ``BatchStatement`` analogue: both rows or neither."""
        if data.uuid != meta.uuid:
            raise ValueError("data/meta uuid mismatch in atomic batch")
        with self._lock:
            self._data[data.uuid] = data
            self._meta[data.uuid] = meta

    def insert_many(self, rows: Iterable) -> int:
        n = 0
        for data, meta in rows:
            self.insert_atomic(data, meta)
            n += 1
        return n

    # -- reads ---------------------------------------------------------------
    def get_data(self, key: _uuid.UUID) -> DataRow:
        try:
            return self._data[key]
        except KeyError:
            raise KeyError(f"uuid {key} not in {self.keyspace}.data") from None

    def get_meta(self, key: _uuid.UUID) -> MetaRow:
        return self._meta[key]

    def scan_metadata(self) -> List[MetaRow]:
        """Full metadata scan — used only for split creation (cheap table)."""
        with self._lock:
            return list(self._meta.values())

    def uuids(self) -> List[_uuid.UUID]:
        with self._lock:
            return list(self._data.keys())

    def __len__(self) -> int:
        return len(self._data)

    def total_bytes(self) -> int:
        return sum(r.size for r in self._data.values())


def token_of(key: _uuid.UUID) -> int:
    """Cassandra Murmur3-style token (md5 here; distribution is what matters)."""
    return int.from_bytes(hashlib.md5(key.bytes).digest()[:8], "big")


__all__ = ["KVStore", "DataRow", "MetaRow", "make_uuid", "token_of"]
