"""Declarative experiment scenarios: routes x schedules x workload x mode.

The time-varying route machinery (``netsim.RouteSchedule`` /
``RouteProfile.schedules``) turns "the network degraded mid-epoch" from a
hand-written test fixture into data.  This module goes one step further and
makes the whole *experiment* data: a ``Scenario`` is a frozen, JSON-round-
trippable description of one network condition — base route parameters, the
schedules and outage windows laid over them, the consumer workload (tight
loop or paced training steps) and the run length — and the benchmark matrix
(``benchmarks/bench_scenarios.py``) is just ``SCENARIOS x MODES``.

Modes compare three ways of choosing the prefetch in-flight budget on the
same scenario:

* ``static-<k>``  — the paper's fixed depth ``k`` (no knowledge of time);
* ``adaptive``    — the BDP-tracking ``FlowController`` (measures, so it
  re-converges when the route moves; see ``core/flowctl.py``);
* ``oracle``      — ``OracleDepthController``: reads the *scenario itself*
  and sets depth from the analytic schedule-aware BDP at every fill
  (``netsim.route_bdp_samples`` at the current clock), depth 1 inside an
  outage window.  It knows the future; nothing real can.  It is the
  yardstick the adaptive controller is judged against, and the bar no
  fixed depth clears on every scenario.

The headline assertion of the matrix benchmark: adaptive holds
``>= oracle/1.5`` throughput on *every* cell with zero per-scenario tuning,
while every fixed depth falls below that bound on at least one dynamic
scenario — under-buffered after a latency spike multiplies the BDP, or
pointlessly deep when the route shrinks under it.
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass, replace
from typing import Dict, List, Optional, Tuple

from .flowctl import FlowControlConfig
from .loader import CassandraLoader, LoaderConfig
from .netsim import (CASSANDRA, RouteProfile, RouteSchedule, SCYLLA,
                     route_bdp_samples)
from .prefetcher import PrefetchConfig, make_prefetcher

# The flow-control modes of one matrix row.  The static sweep spans the
# useful depth range on the scenario base route: 2 is near the static BDP,
# 32 is deep over-provisioning.
STATIC_SWEEP: Tuple[int, ...] = (2, 8, 32)
MODES: Tuple[str, ...] = tuple(f"static-{k}" for k in STATIC_SWEEP) \
    + ("adaptive", "oracle")


@dataclass(frozen=True)
class Scenario:
    """One cell-row of the matrix: a network condition plus a workload.

    Everything is a plain value — ``to_dict``/``from_dict`` round-trip
    through JSON, so a scenario can live in a config file or a results
    artifact as easily as in this registry.  The base route is deliberately
    scaled *down* from the paper tiers (tens of MB/s per connection, 150 ms
    RTT) so a full matrix runs in CI: what matters is the ratio between the
    bandwidth-delay product and the prefetch depth, not absolute rates.
    """

    name: str
    description: str = ""
    # -- base route (static part) -------------------------------------------
    rtt: float = 0.15                  # s; WAN-ish so BDP spans batches
    conn_capacity: float = 30e6        # bytes/s per TCP stream
    loss_per_byte: float = 1e-11       # low: AIMD noise would blur ratios
    # -- time-varying part ----------------------------------------------------
    schedules: Tuple[RouteSchedule, ...] = ()
    outages: Tuple[Tuple[float, float], ...] = ()
    # -- workload -------------------------------------------------------------
    workload: str = "tight"            # "tight" | "paced"
    step_time: float = 0.05            # paced: per-batch consumer compute, s
    n_batches: int = 160
    batch_size: int = 128
    io_threads: int = 4                # x2 connections
    backend: str = "scylla"
    # -- controller sizing ----------------------------------------------------
    # Short dynamic runs need short filter horizons: the min-RTT window must
    # expire a pre-degradation minimum within seconds or the budget stays
    # pinned to the old route (exactly the failure mode the windowed
    # filters exist to fix — see FlowControlConfig.rtt_window).  But both
    # horizons must also clear the *worst* RTT any schedule produces: a
    # PROBE_RTT interval shorter than one post-spike round trip would keep
    # the controller in permanent drain.
    rtt_window: float = 8.0
    probe_rtt_interval: float = 12.0
    # One completed min-RTT bucket whose floor sits regime_factor above the
    # filter minimum is already unambiguous at these run lengths (a bucket
    # is 2 s of samples); the conservative default of 2 exists for noisy
    # production-scale windows, not for a 30-60 s scenario.
    regime_buckets: int = 1
    # The backoff threshold is load-aware (inflation x expected self-RTT,
    # see FlowControlConfig.rtt_inflation), so the transfer-heavy scenario
    # routes work at the stock default; the knob stays declarative here so
    # a scenario *can* pick a twitchier or laxer controller.
    rtt_inflation: float = 2.0
    ceiling_batches: int = 128

    def __post_init__(self) -> None:
        if self.workload not in ("tight", "paced"):
            raise ValueError(f"unknown workload {self.workload!r} "
                             f"(choose tight | paced)")
        if not isinstance(self.schedules, tuple):
            object.__setattr__(self, "schedules", tuple(self.schedules))
        if not isinstance(self.outages, tuple):
            object.__setattr__(self, "outages",
                               tuple((float(s), float(d))
                                     for s, d in self.outages))

    @property
    def dynamic(self) -> bool:
        return bool(self.schedules or self.outages)

    def route(self) -> RouteProfile:
        return RouteProfile(f"scn/{self.name}", rtt=self.rtt,
                            conn_capacity=self.conn_capacity,
                            loss_per_byte=self.loss_per_byte,
                            schedules=self.schedules, outages=self.outages)

    def flow(self) -> FlowControlConfig:
        return FlowControlConfig(rtt_window=self.rtt_window,
                                 probe_rtt_interval=self.probe_rtt_interval,
                                 rtt_inflation=self.rtt_inflation,
                                 regime_buckets=self.regime_buckets,
                                 ceiling_batches=self.ceiling_batches)

    def backend_model(self):
        return CASSANDRA if self.backend == "cassandra" else SCYLLA

    # -- declarative round-trip ----------------------------------------------
    def to_dict(self) -> Dict:
        d = asdict(self)
        d["schedules"] = [asdict(s) for s in self.schedules]
        d["outages"] = [list(o) for o in self.outages]
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "Scenario":
        d = dict(d)
        d["schedules"] = tuple(RouteSchedule(**s) if isinstance(s, dict)
                               else s for s in d.get("schedules", ()))
        d["outages"] = tuple((float(s), float(dur))
                             for s, dur in d.get("outages", ()))
        return cls(**d)


class OracleDepthController:
    """Schedule-aware analytic depth: the controller that read the config.

    Duck-types the one method the prefetcher consults
    (``depth(batch_size)``); no samples are fed to it — the depth is
    recomputed from first principles at every fill, from the scenario's own
    schedules evaluated at the current clock:

        depth(t) = clamp(ceil(gain * BDP_samples(t) / B), 1, ceiling)

    with ``BDP_samples(t)`` = ``netsim.route_bdp_samples(..., t=t)`` (the
    same analytic yardstick the flow-control tests use, with the schedule
    multipliers applied at ``t``) and depth pinned to 1 inside an outage
    window — a down link has no BDP worth buffering for.  ``gain`` matches
    the adaptive controller's headroom factor so the two modes aim at the
    same operating point and differ only in *how they know* the BDP.
    """

    def __init__(self, clock, route: RouteProfile, n_conns: int,
                 sample_bytes: float, backend=None, gain: float = 1.75,
                 ceiling_batches: int = 128, batch_size: int = 128) -> None:
        self._clock = clock
        self.route = route
        self.n_conns = n_conns
        self.sample_bytes = sample_bytes
        self.backend = backend
        self.gain = gain
        self.ceiling_batches = ceiling_batches
        self.batch_size = batch_size

    def depth(self, batch_size: Optional[int] = None) -> int:
        B = batch_size or self.batch_size
        t = self._clock.now()
        if self.route.down_at(t):
            return 1
        bdp = route_bdp_samples(self.route, self.n_conns, self.sample_bytes,
                                self.backend, t=t)
        return max(1, min(self.ceiling_batches,
                          math.ceil(self.gain * bdp / B)))


def run_cell(store, uuids, sc: Scenario, mode: str, seed: int = 2) -> Dict:
    """Run one (scenario, mode) cell; returns its metrics.

    Every mode consumes the same ``sc.n_batches`` batches over the same
    route object on a virtual clock, so throughput ratios reduce to
    sim-time ratios and the comparison is deterministic.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r} (choose from {MODES})")
    route = sc.route()
    static_k = int(mode.split("-", 1)[1]) if mode.startswith("static-") else 8
    cfg = LoaderConfig(
        batch_size=sc.batch_size, prefetch_buffers=static_k,
        io_threads=sc.io_threads, route=route, backend=sc.backend,
        seed=seed, virtual_clock=True,
        flow_control="adaptive" if mode == "adaptive" else "static",
        flow=sc.flow() if mode == "adaptive" else None)
    ld = CassandraLoader(store, uuids, cfg)
    if mode == "oracle":
        sample_bytes = store.total_bytes() / max(len(uuids), 1)
        oc = OracleDepthController(
            ld.clock, route, n_conns=sc.io_threads * cfg.conns_per_thread,
            sample_bytes=sample_bytes, backend=sc.backend_model(),
            gain=sc.flow().gain, ceiling_batches=sc.ceiling_batches,
            batch_size=sc.batch_size)
        pcfg = PrefetchConfig(batch_size=sc.batch_size,
                              num_buffers=static_k, out_of_order=True)
        ld.prefetcher = make_prefetcher(ld.clock, ld.pool, ld.plan, pcfg,
                                        controller=oc)
    ld.start()
    for _ in range(sc.n_batches):
        ld.next_batch(timeout=3000.0)
        if sc.workload == "paced":
            ld.clock.sleep(sc.step_time)
    out = {
        "MBps": ld.stats.throughput(skip=2) / 1e6,
        "t_end_s": ld.clock.now(),
        "failovers": ld.pool.failovers,
    }
    if ld.flow_controller is not None:
        rep = ld.flow_controller.report()
        out.update(steady_depth=rep["depth_batches"],
                   min_rtt_s=rep["min_rtt_s"],
                   backoffs=rep["backoffs"],
                   regime_shifts=rep["regime_shifts"])
    return out


# ---------------------------------------------------------------------------
# The registry: one named scenario per network condition the matrix covers.
# ---------------------------------------------------------------------------

def _scn(*args, **kw) -> Scenario:
    return Scenario(*args, **kw)


SCENARIOS: Dict[str, Scenario] = {s.name: s for s in (
    # Static control cell: no schedules at all — the pre-refactor network.
    # Keeps the matrix honest (adaptive must also win nothing here) and
    # regression-guards the static fast path.
    _scn("steady", "static base route, no time variation",
         n_batches=120),
    # Bandwidth collapses to a quarter mid-run and stays there (congested
    # peering, throttled tenant).  The BDP *shrinks*: the adaptive
    # controller's expiring max-rate filter must let the old rate go
    # instead of budgeting for a pipe that no longer exists.
    _scn("bw_step", "bandwidth x0.25 step at t=3s, permanent",
         schedules=(RouteSchedule("bandwidth", "step", factor=0.25, at=3.0),),
         workload="paced", step_time=0.04, n_batches=140),
    # RTT jumps x32 at t=2s (severe WAN reroute).  The BDP multiplies to
    # ~72 batches: every fixed depth under-buffers (even depth 32 delivers
    # about half of what the pipe can carry), and a min-RTT filter that
    # never expired its pre-spike minimum would pin the adaptive budget to
    # the old route.  This is the cell that kills every static depth.
    _scn("lat_spike", "latency x32 step at t=2s, permanent",
         schedules=(RouteSchedule("latency", "step", factor=32.0, at=2.0),),
         n_batches=400),
    # Slow congestion onset: latency ramps up x8 over [2s, 8s] and holds —
    # the gradual version of lat_spike; re-convergence must track a moving
    # target, not just a single step edge.
    _scn("lat_ramp", "latency ramp to x8 over [2s, 8s], holds",
         schedules=(RouteSchedule("latency", "ramp", factor=8.0, at=2.0,
                                  until=8.0),),
         n_batches=360),
    # Diurnal-style oscillation: bandwidth swings +-50% with a 6 s period
    # (fast-forwarded day/night).  Nothing converges once and rests; the
    # budget has to breathe with the route.
    _scn("diurnal", "bandwidth sinusoid, amplitude 0.5, period 6s",
         schedules=(RouteSchedule("bandwidth", "sinusoid", amplitude=0.5,
                                  period=6.0),),
         n_batches=170),
    # A 1 s hard outage at t=4s: every in-flight request fails and retries.
    # Tests recovery, not steady state — the oracle drops to depth 1 for
    # the window (buffering for a dead link is pointless), everyone eats
    # the same dead second, and the adaptive controller must come back
    # without being pinned by outage-era RTT garbage.
    _scn("outage_flash", "1s full route outage at t=4s",
         outages=((4.0, 1.0),),
         n_batches=160),
    # Random-walk wander (full matrix only — slowest to simulate): the
    # bandwidth multiplier exp-random-walks with sigma 0.35 per 0.5 s
    # step, seeded, so the run is still deterministic.
    _scn("rwalk", "seeded bandwidth random walk, sigma 0.35 per 0.5s",
         schedules=(RouteSchedule("bandwidth", "random_walk", sigma=0.35,
                                  interval=0.5, seed=7),),
         n_batches=170),
)}

# The quick matrix drops the random walk (it needs the longest run to be
# interesting) — CI runs 6 scenarios x 5 modes.
QUICK_MATRIX: Tuple[str, ...] = ("steady", "bw_step", "lat_spike",
                                 "lat_ramp", "diurnal", "outage_flash")
FULL_MATRIX: Tuple[str, ...] = QUICK_MATRIX + ("rwalk",)


def matrix(quick: bool = False) -> List[Scenario]:
    names = QUICK_MATRIX if quick else FULL_MATRIX
    out = []
    for n in names:
        sc = SCENARIOS[n]
        # full mode doubles the run length: ratios sharpen as the dynamic
        # tail dominates the shared pre-event prefix
        out.append(sc if quick else replace(sc, n_batches=sc.n_batches * 2))
    return out


__all__ = ["Scenario", "SCENARIOS", "QUICK_MATRIX", "FULL_MATRIX", "MODES",
           "STATIC_SWEEP", "OracleDepthController", "run_cell", "matrix"]
