"""Instrumentation: batch-time series, throughput windows, epoch summaries.

Produces the raw material for the paper's Figs. 4-7 and Tables 3-4.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


def windowed_series(events: Sequence[Tuple[float, float]],
                    window: float = 0.5,
                    start: float = 0.0) -> List[Tuple[float, float]]:
    """Aggregate timestamped amounts into fixed windows.

    ``events`` is a time-ordered sequence of ``(t, amount)``; the result is
    one ``(window_start, amount_per_second)`` tuple per ``window``-wide
    bucket from ``start`` through the last event (empty buckets yield 0.0).

    This is the single windowed-throughput aggregation the whole stack
    shares: per-connection transfer traces (``SimConnection
    .throughput_series``, Figs. 5/6), consumed-batch throughput
    (``LoaderStats.throughput_windows``, Fig. 4), and the flow controller's
    delivery-rate estimate (``core/flowctl.py``).
    """
    if window <= 0.0:
        raise ValueError(f"window must be positive, got {window}")
    if not events:
        return []
    out: List[Tuple[float, float]] = []
    acc = 0.0
    w0, i = start, 0
    end = events[-1][0]
    while w0 <= end:
        w1 = w0 + window
        while i < len(events) and events[i][0] < w1:
            acc += events[i][1]
            i += 1
        out.append((w0, acc / window))
        acc, w0 = 0.0, w1
    return out


class LoaderStats:
    def __init__(self, clock) -> None:
        self._clock = clock
        self.batch_ready_t: List[float] = []
        self.batch_consume_t: List[float] = []
        self.batch_nbytes: List[int] = []
        self.batch_wait: List[float] = []      # consumer-visible wait per batch
        self.sample_arrive_t: List[float] = []
        self.issues: List[tuple] = []
        self._last_consume: Optional[float] = None

    # -- hooks -------------------------------------------------------------
    def on_issue(self, seq: int, n: int) -> None:
        self.issues.append((self._clock.now(), seq, n))

    def on_sample(self, res) -> None:
        self.sample_arrive_t.append(res.t_done)

    def on_batch_ready(self, batch) -> None:
        self.batch_ready_t.append(batch.t_ready)

    def on_consume(self, batch) -> None:
        now = self._clock.now()
        self.batch_consume_t.append(now)
        self.batch_nbytes.append(batch.nbytes)
        prev = self._last_consume if self._last_consume is not None else 0.0
        # "batch loading time" as plotted in Fig. 4: gap between consecutive
        # batch deliveries as seen by the consumer.
        self.batch_wait.append(now - prev)
        self._last_consume = now

    # -- summaries -----------------------------------------------------------
    def batch_times(self, skip: int = 0) -> np.ndarray:
        return np.asarray(self.batch_wait[skip:], dtype=np.float64)

    def throughput(self, skip: int = 0) -> float:
        """Average bytes/s over consumed batches (epoch-style accounting)."""
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0 = self.batch_consume_t[skip]
        t1 = self.batch_consume_t[-1]
        nbytes = sum(self.batch_nbytes[skip + 1:])
        return nbytes / max(t1 - t0, 1e-9)

    def samples_per_second(self, batch_size: int, skip: int = 0) -> float:
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0, t1 = self.batch_consume_t[skip], self.batch_consume_t[-1]
        n = (len(self.batch_consume_t) - skip - 1) * batch_size
        return n / max(t1 - t0, 1e-9)

    def throughput_windows(self, window: float = 0.5) -> List[tuple]:
        """(t, bytes/s) aggregate over consumed batches."""
        return windowed_series(list(zip(self.batch_consume_t,
                                        self.batch_nbytes)), window)


def summarize(values: np.ndarray) -> dict:
    if values.size == 0:
        return {"mean": 0.0, "std": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    return {"mean": float(values.mean()), "std": float(values.std()),
            "p50": float(np.percentile(values, 50)),
            "p99": float(np.percentile(values, 99)),
            "max": float(values.max())}


__all__ = ["LoaderStats", "summarize", "windowed_series"]
