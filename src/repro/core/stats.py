"""Instrumentation: batch-time series, throughput windows, epoch summaries.

Produces the raw material for the paper's Figs. 4-7 and Tables 3-4.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


def windowed_series(events: Sequence[Tuple[float, float]],
                    window: float = 0.5,
                    start: float = 0.0) -> List[Tuple[float, float]]:
    """Aggregate timestamped amounts into fixed windows.

    ``events`` is a time-ordered sequence of ``(t, amount)``; the result is
    one ``(window_start, amount_per_second)`` tuple per ``window``-wide
    bucket from ``start`` through the last event (empty buckets yield 0.0).

    This is the single windowed-throughput aggregation the whole stack
    shares: per-connection transfer traces (``SimConnection
    .throughput_series``, Figs. 5/6), consumed-batch throughput
    (``LoaderStats.throughput_windows``, Fig. 4), and the flow controller's
    delivery-rate estimate (``core/flowctl.py``).
    """
    if window <= 0.0:
        raise ValueError(f"window must be positive, got {window}")
    if not events:
        return []
    out: List[Tuple[float, float]] = []
    acc = 0.0
    w0, i = start, 0
    end = events[-1][0]
    while w0 <= end:
        w1 = w0 + window
        while i < len(events) and events[i][0] < w1:
            acc += events[i][1]
            i += 1
        out.append((w0, acc / window))
        acc, w0 = 0.0, w1
    return out


class LoaderStats:
    def __init__(self, clock) -> None:
        self._clock = clock
        self.batch_ready_t: List[float] = []
        self.batch_consume_t: List[float] = []
        self.batch_nbytes: List[int] = []
        self.batch_wait: List[float] = []      # consumer-visible wait per batch
        self.sample_arrive_t: List[float] = []
        self.issues: List[tuple] = []
        self._last_consume: Optional[float] = None

    # -- hooks -------------------------------------------------------------
    def on_issue(self, seq: int, n: int) -> None:
        self.issues.append((self._clock.now(), seq, n))

    def on_sample(self, res) -> None:
        self.sample_arrive_t.append(res.t_done)

    def on_batch_ready(self, batch) -> None:
        self.batch_ready_t.append(batch.t_ready)

    def on_consume(self, batch) -> None:
        now = self._clock.now()
        self.batch_consume_t.append(now)
        self.batch_nbytes.append(batch.nbytes)
        prev = self._last_consume if self._last_consume is not None else 0.0
        # "batch loading time" as plotted in Fig. 4: gap between consecutive
        # batch deliveries as seen by the consumer.
        self.batch_wait.append(now - prev)
        self._last_consume = now

    # -- summaries -----------------------------------------------------------
    def batch_times(self, skip: int = 0) -> np.ndarray:
        return np.asarray(self.batch_wait[skip:], dtype=np.float64)

    def throughput(self, skip: int = 0) -> float:
        """Average bytes/s over consumed batches (epoch-style accounting)."""
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0 = self.batch_consume_t[skip]
        t1 = self.batch_consume_t[-1]
        nbytes = sum(self.batch_nbytes[skip + 1:])
        return nbytes / max(t1 - t0, 1e-9)

    def samples_per_second(self, batch_size: int, skip: int = 0) -> float:
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0, t1 = self.batch_consume_t[skip], self.batch_consume_t[-1]
        n = (len(self.batch_consume_t) - skip - 1) * batch_size
        return n / max(t1 - t0, 1e-9)

    def throughput_windows(self, window: float = 0.5) -> List[tuple]:
        """(t, bytes/s) aggregate over consumed batches."""
        return windowed_series(list(zip(self.batch_consume_t,
                                        self.batch_nbytes)), window)


class StepStats:
    """Per-step data-stall accounting (Zolnouri et al., arxiv 2005.02130).

    Where ``LoaderStats`` measures the *supply* side (batch delivery gaps),
    ``StepStats`` measures what the accelerator actually sees: every train
    step is split into *wait-for-batch* time (the consumer blocked on the
    data pipeline) and *step-compute* time.  ``DeviceFeed`` feeds the wait
    half (``on_wait`` per ``__next__``, flagging whether the batch was
    served from the double buffer or had to block on the loader) and the
    training loop feeds the compute half (``on_compute`` per step); steps
    pair up positionally, so summaries only read the paired prefix.

    All timestamps live on ONE clock — the loader's (virtual or real) — so
    stall fractions are internally consistent even when the network is
    simulated.
    """

    def __init__(self, clock=None) -> None:
        self._clock = clock
        self.wait_s: List[float] = []      # per-step wait-for-batch seconds
        self.compute_s: List[float] = []   # per-step compute seconds
        self.step_end_t: List[float] = []  # clock time at each step end
        self.buffer_hits = 0               # __next__ served without blocking
        self.blocked = 0                   # __next__ had to wait on the loader

    # -- hooks -------------------------------------------------------------
    def on_wait(self, wait: float, blocked: bool = True) -> None:
        """One ``DeviceFeed.__next__``: seconds blocked on the loader."""
        self.wait_s.append(float(wait))
        if blocked:
            self.blocked += 1
        else:
            self.buffer_hits += 1

    def on_compute(self, compute: float, t_end: Optional[float] = None) -> None:
        """Close the current step with its compute seconds."""
        self.compute_s.append(float(compute))
        if t_end is None:
            t_end = self._clock.now() if self._clock is not None else 0.0
        self.step_end_t.append(float(t_end))

    # -- summaries ---------------------------------------------------------
    @property
    def steps(self) -> int:
        """Completed (wait, compute) pairs."""
        return min(len(self.wait_s), len(self.compute_s))

    def _paired(self, skip: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        n = self.steps
        return (np.asarray(self.wait_s[skip:n], dtype=np.float64),
                np.asarray(self.compute_s[skip:n], dtype=np.float64))

    def stall_frac(self, skip: int = 0) -> float:
        """Fraction of wall time the consumer spent waiting for data."""
        w, c = self._paired(skip)
        total = float(w.sum() + c.sum())
        return float(w.sum()) / total if total > 0 else 0.0

    def goodput_sps(self, batch_size: int, skip: int = 0) -> float:
        """Samples/s actually trained (wait + compute in the denominator)."""
        w, c = self._paired(skip)
        total = float(w.sum() + c.sum())
        return len(w) * batch_size / total if total > 0 else 0.0

    def stall_windows(self, window: float = 0.5) -> List[Tuple[float, float]]:
        """(t, stalled-seconds-per-second) over fixed windows — the
        stall-rate mirror of ``LoaderStats.throughput_windows``."""
        n = self.steps
        return windowed_series(list(zip(self.step_end_t[:n],
                                        self.wait_s[:n])), window)

    def summary(self, batch_size: int, skip: int = 0) -> dict:
        w, c = self._paired(skip)
        return {
            "steps": self.steps,
            "skip": skip,
            "stall_frac": self.stall_frac(skip),
            "goodput_sps": self.goodput_sps(batch_size, skip),
            "buffer_hits": self.buffer_hits,
            "blocked": self.blocked,
            "wait_s": summarize(w),
            "compute_s": summarize(c),
        }


def summarize(values: np.ndarray) -> dict:
    if values.size == 0:
        return {"mean": 0.0, "std": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    return {"mean": float(values.mean()), "std": float(values.std()),
            "p50": float(np.percentile(values, 50)),
            "p99": float(np.percentile(values, 99)),
            "max": float(values.max())}


__all__ = ["LoaderStats", "StepStats", "summarize", "windowed_series"]
