"""Multi-host loading: N training hosts against one shared cluster.

The paper's scaling story (Sec. 4, multi-GPU training) has several training
hosts hammering the same database cluster at once; what makes that realistic
here is that all the *shared* server-side resources — per-node disk and NIC
egress FIFOs, backend service processes — live in one ``Cluster`` on one
``VirtualClock``, while each host brings its own ``ConnectionPool`` (own TCP
connections, own AIMD processes, own ingress NIC).  Adding clients therefore
degrades per-client throughput through genuine egress/disk contention, not
through an ad-hoc penalty factor.

``MultiHostRun`` wires up N ``CassandraLoader`` shards — one strip of one
global shuffle per host, carved by a placement policy (``contiguous`` or the
replica-skewed ``token_aware``, see ``core/placement.py``) — and drives them
in round-robin lockstep: one batch per host per round, so every host has
consumed the same number of batches whenever control returns to the caller.
That lockstep is what makes ``checkpoint()`` consistent: the per-shard
``(epoch, cursor)`` states it captures all correspond to the same global
batch boundary.

``start(checkpoint)`` is *elastic*: a checkpoint taken with N hosts restores
onto M hosts for any M.  With M == N every shard resumes exactly where it
stopped (bit-identical to the fixed-count behaviour).  With M != N the
unfinished part of the interrupted epoch(s) is reflowed — ``compute_reflow``
collects each old shard's undelivered tail per epoch, the placement policy
splits every tail into M balanced strips, and those strips are installed as
per-epoch overrides on the M fresh plans — so every sample is still
delivered exactly once per epoch across the resize, and later epochs use the
plain M-host sharding (identical to a run that started with M hosts).

Failure injection (``inject_failure``) takes a ``SimServerNode`` dark
mid-run; hedged requests plus the connection-pool failover path keep all
loaders alive through it (requests re-route to live replicas).

Adaptive flow control (``MultiHostConfig.flow_control="adaptive"``,
``core/flowctl.py``): every host gets its own BDP-tracking controller (one
per member cluster under a federation), per-shard controller snapshots ride
``checkpoint()`` (elastic restores merge the N budgets and split them M
ways instead of re-slow-starting), and ``shared_client_ingress=True`` puts
all hosts behind one client NIC with a fair-share budget cap so they
converge to ~1/N shares.  The default ``"static"`` keeps runs bit-identical
to pre-flow-control behaviour.

Multi-cluster federation (``MultiHostConfig.clusters``): instead of one
shared cluster, the run spans several storage clusters — each with its own
token ring, node set, replication factor and WAN route (``core/federation``).
Every uuid is owned by exactly one member cluster; each host's
``FederatedConnectionPool`` routes fetches to the owning cluster over that
cluster's route, degrading to a replica cluster when the owner is dark.
``cluster_aware`` placement prefers the key's same-region cluster first and
a replica-local node within it second; the run report breaks out
per-cluster egress and the WAN-bytes share.  Checkpoints record the
federation's ring metadata, so elastic restores rebuild the old strips
exactly — across host-count changes AND federation changes.

Runtime placement (``core/replication.py``): ``sampling="zipf"`` opens the
skewed-access workload class (with-replacement Zipf draws, globally-shared
hot keys); ``placement="replication_aware"`` (or an explicit
``MultiHostConfig.replication``) promotes hot keys onto the hosts' region
cluster and serves them locally, reported as ``replica_hit_frac`` and
``wan_bytes_saved``; ``MultiHostRun.rebalance()`` shifts weighted keyspace
ownership toward members whose flow controllers measure spare
bandwidth-delay product.  Replica cache and rebalanced ownership map ride
``checkpoint()`` and restore across elastic N->M unchanged.

Multi-tenant QoS (``MultiHostConfig.tenants``, ``core/tenancy.py``): hosts
are tagged with tenants (round-robin, or an explicit ``tenant_of_host``
map) and the shared client ingress is scheduled by a weighted-fair
``TenantScheduler`` instead of the equal-split ``SharedIngressLimiter`` —
rate floors/ceilings, work-conserving redistribution, tenant-level
admission on the route-admission path, and per-tenant
egress/hit-rate/stall/latency sections in the run report.  Tenant specs
may carry their own sampling mode, so one run mixes a uniform
latency-sensitive tenant with zipf batch tenants; ``host_sampling``
expresses the same mixed workload without tenancy (the untenanted
baseline of ``benchmarks/bench_tenancy.py``).  Scheduler state rides
``checkpoint()`` like flow snapshots do.

Invariants this module maintains (property-tested in
``tests/test_resharding.py`` / ``tests/test_multihost.py`` /
``tests/test_federation.py``):

* **Exactly-once per epoch** — each epoch delivers every dataset uuid
  exactly once across all hosts, through checkpoint/restore, elastic N->M
  resizes, node failures and cluster outages.  It is a *plan* property
  (strips are disjoint and jointly covering), never a routing one.
* **Contiguous-strip-of-shuffle sharding** — strips are contiguous slices
  of one seeded global shuffle (never strided slices of the raw uuid list),
  so shards stay unbiased samples and sizes differ by at most one.
* **M == N bit-identity** — restoring a checkpoint onto the same host count
  with the same strip-defining metadata (seed, placement, ring, federation)
  resumes each shard exactly where it stopped, bit-identical to an
  uninterrupted run; any metadata mismatch triggers a reflow instead of
  silently applying old cursors to different strips.
* **Lockstep checkpoints** — the round-robin driver keeps every shard at the
  same global batch boundary, so ``checkpoint()`` is always consistent.
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .cluster import Cluster, TokenRing
from .federation import (ClusterSpec, FederatedCluster,
                         FederatedConnectionPool, FederatedRing,
                         federated_preferred_subsets)
from .flowctl import (FlowControlConfig, FlowControllerGroup,
                      SharedIngressLimiter, merge_snapshots)
from .kvstore import KVStore
from .loader import CassandraLoader, LoaderConfig
from .netsim import DISK_BANDWIDTH, NIC_BANDWIDTH, RateResource, VirtualClock
from .placement import (FEDERATED_POLICIES, PLACEMENT_POLICIES,
                        RING_POLICIES, global_order, preferred_node_subsets,
                        split_strips)
from .prefetcher import EpochPlan, compute_reflow
from .replication import SAMPLING_MODES, ReplicationConfig, ZipfPlan
from .stats import summarize
from .tenancy import TenantScheduler, TenantSpec

import numpy as np


@dataclass
class MultiHostConfig:
    """N-host run over a shared cluster; loader knobs mirror LoaderConfig."""

    n_hosts: int = 2
    batch_size: int = 256
    prefetch_buffers: int = 8
    io_threads: int = 8
    conns_per_thread: int = 2
    out_of_order: bool = True
    incremental_ramp: bool = True
    ramp_every: int = 4
    # route tier name or a RouteProfile (schedule-carrying dynamic routes)
    route: "str | object" = "high"
    backend: str = "scylla"
    n_nodes: int = 4
    replication_factor: int = 2
    # Hedge delay in seconds, None (no hedging), or "auto" — with adaptive
    # flow control, derive the delay per fetch from the controller's
    # measured min-RTT (see FlowControlConfig.hedge_rtt_multiple) so the
    # trigger tracks the route instead of needing hand-tuning per tier.
    hedge_after: "Optional[float | str]" = 1.0
    seed: int = 0
    materialize: bool = False
    # Shared-cluster capacity: per-node NIC/disk.  The default is the paper's
    # 50 Gb/s NIC; pinch it (e.g. 1-10 GbE) to study egress contention as the
    # client count grows.
    node_egress_bandwidth: float = NIC_BANDWIDTH
    node_disk_bandwidth: float = DISK_BANDWIDTH
    # Shard placement policy: "contiguous" (paper-faithful strips),
    # "token_aware" (replica-skewed strips + preferred-node routing),
    # "cluster_aware" (federation: same-region cluster, then replica-local
    # node; requires ``clusters``) or "replication_aware" (cluster_aware
    # strips + hot-key replica serving/promotion at runtime; requires
    # ``clusters`` and switches replication on with default knobs).
    placement: str = "contiguous"
    # Multi-cluster federation: when set, the run spans these member
    # clusters (per-cluster ring/route/rf/weight; see core/federation.py)
    # instead of one shared cluster built from route/backend/n_nodes/
    # replication_factor above, and each host talks to every member over
    # that member's own route via a FederatedConnectionPool.
    clusters: Optional[Tuple[ClusterSpec, ...]] = None
    # Flow control (core/flowctl.py): "static" keeps the fixed
    # prefetch_buffers depth (default, bit-identical to pre-flow-control
    # runs); "adaptive" gives every host its own BDP-tracking controller
    # (one per member cluster under a federation).
    flow_control: str = "static"
    flow: Optional[FlowControlConfig] = None
    # Shared client ingress: all hosts behind ONE client NIC (co-located
    # consumers) instead of one NIC per host.  With adaptive flow control a
    # fairness cap limits each host's budget to its fair-share BDP of that
    # NIC, so N hosts converge to ~1/N shares.
    shared_client_ingress: bool = False
    client_ingress_bandwidth: float = NIC_BANDWIDTH
    # Hot-key replication knobs (core/replication.py): set to enable
    # promotion of skewed-access keys onto the hosts' region cluster under
    # any federated placement; ``placement="replication_aware"`` enables it
    # with defaults when left None.  Needs ``clusters``.
    replication: Optional[ReplicationConfig] = None
    # Access distribution: "uniform" (per-epoch permutations, exactly-once —
    # the default and the paper's workload) or "zipf" (seeded Zipf(zipf_s)
    # sampling with replacement over the global key list — the skewed
    # workload class hot-key replication exists for; exactly-once per epoch
    # deliberately does not hold, see core/replication.py:ZipfPlan).
    sampling: str = "uniform"
    zipf_s: float = 1.05
    # Moving hotset: rotate the Zipf rank->key map every this many epochs
    # (see ZipfPlan.shift_every) — the workload class replica demotion
    # (ReplicationConfig.demote_after) exists for.  None = fixed hotset.
    zipf_shift_every: Optional[int] = None
    # Ownership-rebalance cadence: every this many rounds, ``run()`` invokes
    # ``rebalance()`` with its default step — so a route whose measured
    # spare BDP drifts (schedules, outages) sheds keyspace weight without
    # the caller scripting it.  Requires a federation + adaptive flow
    # control.  None = caller-invoked only (the pre-cadence behaviour).
    rebalance_every: Optional[int] = None
    # Per-key route admission in the prefetcher (see PrefetchConfig):
    # requires adaptive flow control to have per-route budgets to consult.
    route_admission: bool = False
    # Multi-tenant QoS (core/tenancy.py): when set, hosts are tagged with
    # tenants (``tenant_of_host``, or round-robin over the specs) and the
    # client NIC is scheduled by a weighted-fair TenantScheduler instead of
    # the equal-split SharedIngressLimiter.  Requires
    # flow_control="adaptive" (QoS shares are enforced through the
    # controllers' budget caps) and — single-cluster — also
    # shared_client_ingress=True (the NIC the shares divide); under a
    # federation the scheduler caps per-member budgets against
    # client_ingress_bandwidth without a shared ingress pipe (each host
    # keeps its own NIC).  A tenant spec's ``sampling``/``zipf_s`` drive
    # that tenant's hosts' access pattern.
    tenants: Optional[Tuple[TenantSpec, ...]] = None
    tenant_of_host: Optional[Tuple[str, ...]] = None
    # Per-host sampling override ("uniform"/"zipf" per host), independent of
    # tenancy — how the untenanted baseline of bench_tenancy expresses the
    # same mixed workload.  Takes precedence over tenant-spec sampling;
    # ``sampling="zipf"`` above still forces every host to zipf.
    host_sampling: Optional[Tuple[str, ...]] = None
    # Wire codec — LoaderConfig.wire_codec, one level up.  A codec name
    # applies to every host's pool; under a federation (``clusters``) a
    # ``{member: codec}`` dict or ``"auto"`` (compress WAN members only,
    # see FederatedConnectionPool) are also accepted.  "none" stays
    # bit-identical to the pre-codec path.
    wire_codec: "str | Dict[str, str]" = "none"
    # Controller-driven issue-parallelism scaling — LoaderConfig.io_scaling
    # spelling; needs flow_control="adaptive" to have a budget to follow.
    io_scaling: bool = False
    # Pinned-arena batch assembly — LoaderConfig.use_arena spelling; only
    # effective with materialize=True (same rule as the single-host loader).
    use_arena: bool = False
    arena_slot_bytes: Optional[int] = None

    def loader_config(self, shard_id: int,
                      preferred_nodes: Optional[tuple] = None) -> LoaderConfig:
        return LoaderConfig(
            batch_size=self.batch_size,
            prefetch_buffers=self.prefetch_buffers,
            io_threads=self.io_threads,
            conns_per_thread=self.conns_per_thread,
            out_of_order=self.out_of_order,
            incremental_ramp=self.incremental_ramp,
            ramp_every=self.ramp_every,
            route=self.route,
            backend=self.backend,
            n_nodes=self.n_nodes,
            replication_factor=self.replication_factor,
            hedge_after=self.hedge_after,
            seed=self.seed,
            shard_id=shard_id,
            num_shards=self.n_hosts,
            materialize=self.materialize,
            virtual_clock=True,
            preferred_nodes=preferred_nodes,
            flow_control=self.flow_control,
            flow=self.flow,
            route_admission=self.route_admission,
            # dict/"auto" codecs are federation-level: the per-member
            # resolution happens in FederatedConnectionPool, which replaces
            # the loader-built pool, so the per-loader config carries the
            # codec only when it is a plain name
            wire_codec=(self.wire_codec
                        if isinstance(self.wire_codec, str)
                        and self.wire_codec != "auto" else "none"),
            io_scaling=self.io_scaling,
            use_arena=self.use_arena,
            arena_slot_bytes=self.arena_slot_bytes)


class MultiHostRun:
    """Coordinator for N sharded loaders on one clock + one cluster."""

    def __init__(self, store: KVStore, uuids: List[_uuid.UUID],
                 cfg: MultiHostConfig,
                 clock: Optional[VirtualClock] = None,
                 cluster: Optional[Cluster] = None) -> None:
        if cfg.n_hosts < 1:
            raise ValueError("need at least one host")
        if cfg.placement not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown placement policy {cfg.placement!r} "
                             f"(choose from {PLACEMENT_POLICIES})")
        if cfg.placement in FEDERATED_POLICIES and not cfg.clusters \
                and not isinstance(cluster, FederatedCluster):
            raise ValueError(f"{cfg.placement} placement needs a federation "
                             "(set MultiHostConfig.clusters)")
        if cfg.sampling not in SAMPLING_MODES:
            raise ValueError(f"unknown sampling mode {cfg.sampling!r} "
                             f"(choose from {SAMPLING_MODES})")
        if cfg.rebalance_every is not None:
            if cfg.rebalance_every < 1:
                raise ValueError(f"rebalance_every must be >= 1, "
                                 f"got {cfg.rebalance_every}")
            if not cfg.clusters and not isinstance(cluster, FederatedCluster):
                raise ValueError("rebalance_every needs a federation "
                                 "(set MultiHostConfig.clusters)")
            if cfg.flow_control != "adaptive":
                raise ValueError("rebalance_every needs "
                                 "flow_control='adaptive' (the spare-BDP "
                                 "signal comes from the flow controllers)")
        if cfg.hedge_after == "auto" and cfg.flow_control != "adaptive":
            raise ValueError("hedge_after='auto' needs "
                             "flow_control='adaptive' (the delay comes from "
                             "the controller's min-RTT)")
        if cfg.tenant_of_host is not None and not cfg.tenants:
            raise ValueError("tenant_of_host needs tenants "
                             "(set MultiHostConfig.tenants)")
        if (cfg.wire_codec == "auto" or isinstance(cfg.wire_codec, dict)) \
                and not cfg.clusters \
                and not isinstance(cluster, FederatedCluster):
            raise ValueError("wire_codec='auto' / per-member codec dicts "
                             "are federation-level (set "
                             "MultiHostConfig.clusters); a single shared "
                             "cluster takes one codec name")
        if cfg.io_scaling and cfg.flow_control != "adaptive":
            raise ValueError("io_scaling needs flow_control='adaptive' "
                             "(the active-connection prefix follows the "
                             "controller's budget)")
        if cfg.use_arena and not cfg.materialize:
            raise ValueError("use_arena needs materialize=True (the arena "
                             "holds real payload bytes)")
        self.tenant_of_host: Optional[Tuple[str, ...]] = None
        if cfg.tenants:
            if cfg.flow_control != "adaptive":
                raise ValueError("tenants need flow_control='adaptive' (QoS "
                                 "shares are enforced through the "
                                 "controllers' budget caps)")
            assignment = cfg.tenant_of_host or tuple(
                cfg.tenants[i % len(cfg.tenants)].name
                for i in range(cfg.n_hosts))
            if len(assignment) != cfg.n_hosts:
                raise ValueError(f"tenant_of_host has {len(assignment)} "
                                 f"entries for {cfg.n_hosts} hosts")
            known = {t.name for t in cfg.tenants}
            unknown = sorted(set(assignment) - known)
            if unknown:
                raise ValueError(f"tenant_of_host names unknown tenants "
                                 f"{unknown} (have {sorted(known)})")
            self.tenant_of_host = tuple(assignment)
        if cfg.host_sampling is not None:
            if len(cfg.host_sampling) != cfg.n_hosts:
                raise ValueError(f"host_sampling has "
                                 f"{len(cfg.host_sampling)} entries for "
                                 f"{cfg.n_hosts} hosts")
            bad = sorted(set(cfg.host_sampling) - set(SAMPLING_MODES))
            if bad:
                raise ValueError(f"unknown sampling modes {bad} in "
                                 f"host_sampling (choose from "
                                 f"{SAMPLING_MODES})")
        self.cfg = cfg
        self.clock = clock or VirtualClock()
        if cluster is not None:
            self.cluster = cluster
        elif cfg.clusters:
            self.cluster = FederatedCluster(self.clock, store, cfg.clusters,
                                            seed=cfg.seed + 5)
        else:
            self.cluster = Cluster(
                self.clock, store, backend=cfg.backend, n_nodes=cfg.n_nodes,
                rf=cfg.replication_factor, seed=cfg.seed + 5,
                disk_bandwidth=cfg.node_disk_bandwidth,
                egress_bandwidth=cfg.node_egress_bandwidth)
        self.federation = (self.cluster
                           if isinstance(self.cluster, FederatedCluster)
                           else None)
        # Hot-key replication: explicit config or the replication_aware
        # policy switches it on (shared tracker + cache on the federation).
        if cfg.replication is not None or cfg.placement == "replication_aware":
            if self.federation is None:
                raise ValueError("hot-key replication needs a federation "
                                 "(set MultiHostConfig.clusters)")
            self.federation.attach_replication(cfg.replication)
        self.rebalances = 0
        self._uuids = list(uuids)
        if self.federation is not None:
            self.preferred = federated_preferred_subsets(
                self.federation.node_names_by_cluster(), cfg.n_hosts)
        else:
            self.preferred = preferred_node_subsets(
                self.cluster.node_names(), cfg.n_hosts)
        prefs = (self.preferred if cfg.placement in RING_POLICIES
                 else [None] * cfg.n_hosts)
        # Per-host access pattern: the global sampling mode forces every
        # host to zipf; else an explicit host_sampling map; else the hosts'
        # tenant specs; else uniform everywhere (the default).
        if cfg.sampling == "zipf":
            self._host_sampling = ["zipf"] * cfg.n_hosts
            self._host_zipf_s: List[Optional[float]] = \
                [cfg.zipf_s] * cfg.n_hosts
        elif cfg.host_sampling is not None:
            self._host_sampling = list(cfg.host_sampling)
            self._host_zipf_s = [cfg.zipf_s if s == "zipf" else None
                                 for s in self._host_sampling]
        elif self.tenant_of_host is not None:
            by_host = [{t.name: t for t in cfg.tenants}[name]
                       for name in self.tenant_of_host]
            self._host_sampling = [t.sampling for t in by_host]
            self._host_zipf_s = [t.zipf_s if t.sampling == "zipf" else None
                                 for t in by_host]
        else:
            self._host_sampling = ["uniform"] * cfg.n_hosts
            self._host_zipf_s = [None] * cfg.n_hosts
        # zipf hosts sample the global rank->key map with replacement
        # (placement strips don't apply — there is no exactly-once delivery
        # set — preferred-node routing does); uniform hosts keep their
        # strip-of-shuffle plans even in a mixed run, so *their* epochs stay
        # exactly-once over their strips.
        strips = None
        if (cfg.placement in RING_POLICIES
                and "uniform" in self._host_sampling):
            strips = _steady_strips(uuids, cfg.seed, cfg.n_hosts,
                                    cfg.placement, ring=self.cluster.ring,
                                    rf=self.cluster.rf,
                                    preferred=self.preferred)
        plans: List[object] = []
        for i in range(cfg.n_hosts):
            if self._host_sampling[i] == "zipf":
                plans.append(ZipfPlan(uuids, cfg.seed, i, cfg.n_hosts,
                                      s=self._host_zipf_s[i],
                                      shift_every=cfg.zipf_shift_every))
            elif strips is not None:
                plans.append(EpochPlan.from_samples(strips[i], cfg.seed, i,
                                                    cfg.n_hosts))
            else:   # contiguous: loader carves its own strip (PR1 semantics)
                plans.append(None)
        if cfg.shared_client_ingress and self.federation is not None:
            raise ValueError("shared_client_ingress is not supported with a "
                             "federation (each host already multiplexes its "
                             "member sub-pools over one NIC)")
        if cfg.tenants and self.federation is None \
                and not cfg.shared_client_ingress:
            raise ValueError("tenants need shared_client_ingress=True (the "
                             "NIC whose bandwidth the QoS shares divide) — "
                             "or a federation, where the scheduler caps "
                             "per-member budgets against "
                             "client_ingress_bandwidth instead")
        # Co-located consumers: one client NIC for every host, plus — under
        # adaptive flow control — a fairness cap so the hosts' budgets
        # converge to ~1/N shares of that NIC instead of out-buffering each
        # other.  With tenants the cap generalizes to weighted-fair QoS
        # shares (core/tenancy.py); under a federation the scheduler runs
        # caps-only (no shared ingress pipe — each host has its own NIC).
        shared_ingress = None
        self.limiter: Optional[SharedIngressLimiter] = None
        if cfg.shared_client_ingress:
            shared_ingress = RateResource("client/shared-ingress",
                                          cfg.client_ingress_bandwidth)
            if cfg.flow_control == "adaptive":
                if cfg.tenants:
                    self.limiter = TenantScheduler(
                        cfg.client_ingress_bandwidth, cfg.tenants,
                        clock=self.clock)
                else:
                    self.limiter = SharedIngressLimiter(
                        cfg.client_ingress_bandwidth, clock=self.clock)
        elif cfg.tenants:
            self.limiter = TenantScheduler(cfg.client_ingress_bandwidth,
                                           cfg.tenants, clock=self.clock)
        self.loaders = []
        for i in range(cfg.n_hosts):
            pool = None
            if self.federation is not None:
                pool = FederatedConnectionPool(
                    self.clock, self.federation,
                    io_threads=cfg.io_threads,
                    conns_per_thread=cfg.conns_per_thread,
                    seed=cfg.seed + 11 + 104729 * i,
                    hedge_after=cfg.hedge_after,
                    materialize=cfg.materialize,
                    preferred_nodes=prefs[i],
                    wire_codec=(None if cfg.wire_codec == "none"
                                else cfg.wire_codec),
                    io_scaling=cfg.io_scaling)
            self.loaders.append(
                CassandraLoader(store, uuids,
                                cfg.loader_config(i, None if pool
                                                  else prefs[i]),
                                clock=self.clock, cluster=self.cluster,
                                plan=plans[i], pool=pool,
                                ingress=shared_ingress,
                                flow_limiter=self.limiter))
        # Tag every host's controller(s) with its tenant — under a
        # federation that is each member controller of the host's group, so
        # the scheduler sees per-route demand and the summed group budget
        # respects the tenant's cap.
        if self.tenant_of_host is not None:
            for ld, tenant in zip(self.loaders, self.tenant_of_host):
                ctl = ld.flow_controller
                members = (ctl.members.values()
                           if isinstance(ctl, FlowControllerGroup)
                           else [ctl])
                for m in members:
                    self.limiter.assign(m, tenant)
        # Per-host consumption accounting (cheap bookkeeping, no clock
        # events): buffer hits vs stalls behind ``next_batch``, the inputs
        # of the per-tenant hit_frac/stall_frac report sections.
        self._host_pulls = [0] * cfg.n_hosts
        self._host_hits = [0] * cfg.n_hosts
        self._host_stall_s = [0.0] * cfg.n_hosts
        self.rounds_consumed = 0
        self._started = False

    def _split(self, samples: List[_uuid.UUID]) -> List[List[_uuid.UUID]]:
        return split_strips(samples, self.cfg.n_hosts, self.cfg.placement,
                            ring=self.cluster.ring, rf=self.cluster.rf,
                            preferred=self.preferred)

    # -- lifecycle ----------------------------------------------------------
    def start(self, checkpoint: Optional[Dict] = None) -> "MultiHostRun":
        """Start all shards: fresh, from a matching-shards checkpoint (each
        shard resumes exactly where it stopped), or via an elastic reshard
        (``_start_resharded``) when the host count — or any strip-defining
        metadata like seed or placement policy — differs, so old cursors are
        never silently applied to different strips."""
        if checkpoint is None:
            for ld in self.loaders:
                ld.start()
            self._started = True
            return self
        # every strip (old and new) is a deterministic function of the uuid
        # list, so restoring against a different dataset would silently
        # reflow wrong permutations — refuse instead
        ck_size = checkpoint.get("dataset_size", len(self._uuids))
        if ck_size != len(self._uuids):
            raise ValueError(f"checkpoint was taken over {ck_size} samples, "
                             f"this run has {len(self._uuids)} — not the "
                             "same dataset")
        ck_hs = checkpoint.get("host_sampling")
        ck_zipf = (checkpoint.get("sampling", "uniform") == "zipf"
                   or (ck_hs is not None and "zipf" in ck_hs))
        if ck_zipf or "zipf" in self._host_sampling:
            self._start_zipf(checkpoint)
        elif (len(checkpoint["shards"]) == len(self.loaders)
                and self._same_strips(checkpoint)):
            for ld, s in zip(self.loaders, checkpoint["shards"]):
                overrides = s.get("overrides")
                if overrides:
                    ld.plan.install_overrides(_parse_overrides(overrides))
                ld.start(s["epoch"], s["cursor"])
                ld.restore_flow(s.get("flow"))
        else:
            self._start_resharded(checkpoint)
        self._restore_runtime_placement(checkpoint)
        # per-tenant cumulative counters re-seed (specs themselves come from
        # this run's config — a restore never resurrects dropped tenants)
        if self.tenant_of_host is not None:
            self.limiter.restore(checkpoint.get("tenants"))
        self._started = True
        return self

    def _start_zipf(self, checkpoint: Dict) -> None:
        """Restore involving Zipf sampling (pure or mixed per host):
        with-replacement draws have no exactly-once delivery set to reflow,
        so a matching checkpoint resumes each shard's sample stream exactly
        and any mismatch (host count, seed, exponent, per-host sampling
        map) restarts at the slowest shard's epoch boundary with the merged
        flow-control budget.  In a *mixed* run that boundary restart also
        applies to the uniform hosts — their interrupted epoch replays
        (at-least-once) because the zipf hosts leave nothing to reflow
        against; matching restores stay exact/exactly-once."""
        shards = checkpoint["shards"]
        # Per-host sampling metadata, defaulted for checkpoints predating
        # mixed workloads (pure-zipf runs recorded only the global keys).
        ck_hs = checkpoint.get("host_sampling") or \
            [checkpoint.get("sampling", "uniform")] * len(shards)
        ck_zs = checkpoint.get("host_zipf_s") or \
            [checkpoint.get("zipf_s", self.cfg.zipf_s) if s == "zipf"
             else None for s in ck_hs]
        exact = (len(shards) == len(self.loaders)
                 and list(ck_hs) == list(self._host_sampling)
                 and list(ck_zs) == list(self._host_zipf_s)
                 and checkpoint.get("seed", self.cfg.seed) == self.cfg.seed
                 and checkpoint.get("zipf_shift_every",
                                    self.cfg.zipf_shift_every)
                 == self.cfg.zipf_shift_every
                 and (("uniform" not in ck_hs)
                      or self._same_strips(checkpoint)))
        if exact:
            for ld, s in zip(self.loaders, shards):
                ld.start(s["epoch"], s["cursor"])
                ld.restore_flow(s.get("flow"))
            return
        start_epoch = min(s["epoch"] for s in shards)
        merged = merge_snapshots([s.get("flow") for s in shards],
                                 len(self.loaders))
        for ld in self.loaders:
            ld.start(start_epoch, 0)
            ld.restore_flow(merged)

    def _restore_runtime_placement(self, checkpoint: Dict) -> None:
        """Re-install checkpointed runtime placement state: the rebalanced
        ownership map and the hot-key replication snapshot.  Both are
        cluster-side, so they restore unchanged across elastic N->M; state
        recorded against a *different* federation is dropped (its member
        names no longer resolve)."""
        if self.federation is None:
            return
        members = {s.name for s in self.federation.specs}
        own = checkpoint.get("ownership")
        if own and [m["name"] for m in own] == [s.name
                                                for s in self.federation.specs]:
            self.federation.install_ownership(FederatedRing.from_metadata(own))
        snap = checkpoint.get("replication")
        if snap and self.federation.replication is not None:
            cache = {k: v for k, v in (snap.get("cache") or {}).items()
                     if v.get("cluster") in members}
            self.federation.replication.restore(
                {"tracker": snap.get("tracker"), "cache": cache})

    def _same_strips(self, checkpoint: Dict) -> bool:
        """Does the checkpointed run's strip assignment match this run's?
        Keys missing from pre-elastic checkpoints default to what those runs
        actually were — contiguous placement (the only pre-elastic policy;
        must match ``_rebuild_old_plans``) and this run's seed."""
        if (checkpoint.get("seed", self.cfg.seed) != self.cfg.seed
                or checkpoint.get("placement",
                                  "contiguous") != self.cfg.placement):
            return False
        if self.cfg.placement in RING_POLICIES:
            # ring-derived strips also depend on the topology: for a
            # federation that is the full per-member ring metadata, for a
            # single cluster the (node_names, ring_seed, rf) triple.
            fed_meta = (self.federation.ring.metadata()
                        if self.federation is not None else None)
            if checkpoint.get("federation") != fed_meta:
                return False
            if self.federation is not None:
                return True
            return (checkpoint.get("node_names",
                                   self.cluster.node_names())
                    == self.cluster.node_names()
                    and checkpoint.get("ring_seed", self.cluster.ring_seed)
                    == self.cluster.ring_seed
                    and checkpoint.get("replication_factor",
                                       self.cfg.replication_factor)
                    == self.cfg.replication_factor)
        return True

    def _start_resharded(self, checkpoint: Dict) -> None:
        """Elastic N->M restore: reflow the undelivered tail of every epoch
        at the checkpoint boundary into M strips (exactly-once preserved),
        then fall through to plain M-host sharding for later epochs."""
        old_plans = self._rebuild_old_plans(checkpoint)
        positions = [(s["epoch"], s["cursor"]) for s in checkpoint["shards"]]
        start_epoch, tails = compute_reflow(old_plans, positions)
        for epoch, tail in sorted(tails.items()):
            for ld, strip in zip(self.loaders, self._split(tail)):
                ld.plan.install_overrides({epoch: strip})
        # Re-seed flow control across the resize: the cluster-wide in-flight
        # total is conserved (N shards' budgets merge, then split M ways), so
        # the restored run resumes at the measured operating point instead of
        # re-slow-starting against a warm cluster.
        merged_flow = merge_snapshots(
            [s.get("flow") for s in checkpoint["shards"]], len(self.loaders))
        for ld in self.loaders:
            ld.start(start_epoch, 0)
            ld.restore_flow(merged_flow)

    def _rebuild_old_plans(self, checkpoint: Dict) -> List[EpochPlan]:
        """Reconstruct the checkpointed run's shard plans from the recorded
        (seed, placement, ring) metadata — strips are deterministic functions
        of those, so the checkpoint itself stays small."""
        shards = checkpoint["shards"]
        old_n = len(shards)
        seed = checkpoint.get("seed", self.cfg.seed)
        policy = checkpoint.get("placement", "contiguous")
        fed_meta = checkpoint.get("federation")
        if policy in RING_POLICIES and fed_meta:
            # federated strips: rebuild the keyspace ring (per-member token
            # rings + ownership weights) straight from the metadata
            ring = FederatedRing.from_metadata(fed_meta)
            preferred = federated_preferred_subsets(
                {m["name"]: [f"{m['name']}/node{i}"
                             for i in range(m["n_nodes"])]
                 for m in fed_meta}, old_n)
            strips = _steady_strips(self._uuids, seed, old_n, policy,
                                    ring=ring, rf=0, preferred=preferred)
            plans = [EpochPlan.from_samples(strips[i], seed, i, old_n)
                     for i in range(old_n)]
        elif policy == "token_aware":
            n_nodes = checkpoint.get("n_nodes", self.cfg.n_nodes)
            names = checkpoint.get("node_names",
                                   [f"node{i}" for i in range(n_nodes)])
            ring = TokenRing(names,
                             seed=checkpoint.get("ring_seed", seed + 5))
            rf = min(checkpoint.get("replication_factor",
                                    self.cfg.replication_factor), len(names))
            strips = _steady_strips(self._uuids, seed, old_n, "token_aware",
                                    ring=ring, rf=rf,
                                    preferred=preferred_node_subsets(names,
                                                                     old_n))
            plans = [EpochPlan.from_samples(strips[i], seed, i, old_n)
                     for i in range(old_n)]
        else:
            plans = [EpochPlan(self._uuids, seed=seed, shard_id=i,
                               num_shards=old_n) for i in range(old_n)]
        for plan, s in zip(plans, shards):
            overrides = s.get("overrides")
            if overrides:
                plan.install_overrides(_parse_overrides(overrides))
        return plans

    def inject_failure(self, node: str, after: float,
                       recover_after: Optional[float] = None) -> None:
        """Schedule ``node`` to go dark ``after`` virtual seconds from now.
        In a federation, node names are qualified: ``"eu/node2"``."""
        self.cluster.schedule_failure(node, after, recover_after)

    def inject_cluster_outage(self, cluster_name: str, after: float,
                              recover_after: Optional[float] = None) -> None:
        """Take an entire member cluster dark (region outage): its keys
        degrade to the replica cluster until it recovers."""
        if self.federation is None:
            raise ValueError("cluster outage injection needs a federation "
                             "(set MultiHostConfig.clusters)")
        self.federation.schedule_cluster_outage(cluster_name, after,
                                                recover_after)

    # -- driving ------------------------------------------------------------
    def run(self, n_rounds: int, step_time: float = 0.0,
            timeout: float = 600.0,
            on_batch: Optional[Callable] = None) -> Dict:
        """Consume ``n_rounds`` batches on every host, round-robin lockstep.

        ``step_time`` models the per-step GPU compute all hosts perform in
        parallel (one sleep per round, not per host).  ``on_batch(host_id,
        batch)`` is invoked for every delivered batch (tests and benchmarks
        use it to audit delivery instead of re-deriving from logs).  Returns
        a report dict; cumulative over repeated calls on the same run.
        """
        if not self._started:
            self.start()
        t0 = self.clock.now()
        bytes0 = [ld.pool.bytes_received for ld in self.loaders]
        served0 = [dict(ld.pool.served_by_node) for ld in self.loaders]
        egress0 = {name: node.egress_bytes
                   for name, node in self.cluster.nodes.items()}
        # retry counters snapshot: reports are per-window like the egress
        # numbers, so a recovered outage stops showing up in later windows
        counters0 = {
            "failovers": sum(ld.pool.failovers for ld in self.loaders),
            "requests_sent": sum(ld.pool.requests_sent
                                 for ld in self.loaders),
            "host_pulls": list(self._host_pulls),
            "host_hits": list(self._host_hits),
            "host_stall_s": list(self._host_stall_s),
        }
        if self.federation is not None:
            counters0["cluster_failovers"] = sum(ld.pool.cluster_failovers
                                                 for ld in self.loaders)
            if self.federation.replication is not None:
                counters0["fetches"] = sum(ld.pool.fetches
                                           for ld in self.loaders)
                counters0["replica_hits"] = sum(ld.pool.replica_hits
                                                for ld in self.loaders)
                counters0["wan_bytes_saved"] = sum(ld.pool.wan_bytes_saved
                                                   for ld in self.loaders)
        for _ in range(n_rounds):
            for host_id, ld in enumerate(self.loaders):
                t_pull = self.clock.now()
                hit = ld.ready_batches > 0
                batch = ld.next_batch(timeout=timeout)
                self._host_stall_s[host_id] += self.clock.now() - t_pull
                self._host_pulls[host_id] += 1
                if hit:
                    self._host_hits[host_id] += 1
                if on_batch is not None:
                    on_batch(host_id, batch)
            self.rounds_consumed += 1
            # Runtime placement maintenance on the round cadence: demote
            # replicas the hotset moved away from (no-op unless
            # ReplicationConfig.demote_after is set), and re-derive the
            # ownership map from the controllers' spare-BDP signal (no-op
            # unless rebalance_every is set) — counted against the run's
            # *total* rounds so the cadence survives repeated run() calls.
            if (self.federation is not None
                    and self.federation.replication is not None):
                self.federation.replication.demote_cold(self.clock.now())
            if (self.cfg.rebalance_every
                    and self.rounds_consumed % self.cfg.rebalance_every == 0):
                self.rebalance()
            if step_time > 0.0:
                self.clock.sleep(step_time)
        return self._report(t0, bytes0, served0, egress0, counters0,
                            n_rounds)

    def _report(self, t0: float, bytes0: List[int],
                served0: List[Dict[str, int]], egress0: Dict[str, int],
                counters0: Dict[str, int], n_rounds: int) -> Dict:
        elapsed = max(self.clock.now() - t0, 1e-9)
        per_client_bytes = [ld.pool.bytes_received - b0
                            for ld, b0 in zip(self.loaders, bytes0)]
        per_client_Bps = [b / elapsed for b in per_client_bytes]
        # placement stats over this run window: how many of each host's
        # fetches were served by one of its preferred nodes, and how the
        # cluster's egress split across nodes.
        local_served = total_served = 0
        for ld, base, pref in zip(self.loaders, served0, self.preferred):
            pref_set = frozenset(pref)
            for name, count in ld.pool.served_by_node.items():
                delta = count - base.get(name, 0)
                total_served += delta
                if name in pref_set:
                    local_served += delta
        egress_delta = {name: node.egress_bytes - egress0[name]
                        for name, node in self.cluster.nodes.items()}
        egress_total = max(sum(egress_delta.values()), 1)
        egress_share = {name: d / egress_total
                        for name, d in egress_delta.items()}
        mean_share = 1.0 / max(len(egress_share), 1)
        report = {
            "n_hosts": self.cfg.n_hosts,
            "rounds": n_rounds,
            "elapsed_s": elapsed,
            "aggregate_Bps": sum(per_client_bytes) / elapsed,
            "per_client_Bps": per_client_Bps,
            # fairness: worst/best per-client rate (1.0 = perfectly fair)
            "fairness": (min(per_client_Bps) / max(max(per_client_Bps), 1e-9)
                         if per_client_Bps else 0.0),
            "failovers": (sum(ld.pool.failovers for ld in self.loaders)
                          - counters0["failovers"]),
            "requests_sent": (sum(ld.pool.requests_sent
                                  for ld in self.loaders)
                              - counters0["requests_sent"]),
            "placement": self.cfg.placement,
            "replica_local_hit_frac": local_served / max(total_served, 1),
            "per_node_egress_share": egress_share,
            # max node share / even share (1.0 = perfectly balanced egress)
            "egress_imbalance": (max(egress_share.values()) / mean_share
                                 if egress_share else 0.0),
            "cluster_load": self.cluster.load_report(),
        }
        if self.cfg.flow_control == "adaptive":
            # per-host controller operating points (per member cluster under
            # a federation): budget, BDP estimate, min-RTT, backoff counts
            report["flow"] = [ld.flow_controller.report()
                              for ld in self.loaders]
        if self.limiter is not None:
            # per-host request-latency summaries from the limiter's
            # completion rings (recent fetches, bounded per member)
            report["request_latency_s"] = [
                summarize(np.asarray(self._host_request_latencies(ld),
                                     dtype=float))
                for ld in self.loaders]
        if self.tenant_of_host is not None:
            # per-tenant QoS view over this window: the scheduler's own
            # section (share, cumulative egress, latency summary, admission
            # counters) plus windowed consumption stats from the driver
            sched = self.limiter.report()
            tenants: Dict[str, Dict] = {}
            for name in self.limiter.tenants:
                hosts = [i for i, t in enumerate(self.tenant_of_host)
                         if t == name]
                t_bytes = sum(per_client_bytes[i] for i in hosts)
                pulls = sum(self._host_pulls[i]
                            - counters0["host_pulls"][i] for i in hosts)
                hits = sum(self._host_hits[i]
                           - counters0["host_hits"][i] for i in hosts)
                stall = sum(self._host_stall_s[i]
                            - counters0["host_stall_s"][i] for i in hosts)
                entry = dict(sched[name])
                entry.update({
                    "hosts": hosts,
                    "egress_Bps": t_bytes / elapsed,
                    "hit_frac": hits / max(pulls, 1),
                    "stall_frac": stall / (elapsed * max(len(hosts), 1)),
                })
                tenants[name] = entry
            report["tenants"] = tenants
        if self.federation is not None:
            # break the window's egress out per member cluster; the WAN-bytes
            # share is the fraction served over WAN routes (federation
            # placement + routing exist to keep it pinned at the WAN
            # clusters' ownership share, not above it)
            per_cluster: Dict[str, int] = {s.name: 0
                                           for s in self.federation.specs}
            for name, delta in egress_delta.items():
                per_cluster[self.federation.cluster_of_node(name)] += delta
            total = max(sum(per_cluster.values()), 1)
            wan = self.federation.wan_clusters()
            report["per_cluster_egress_bytes"] = per_cluster
            report["per_cluster_egress_share"] = {
                c: v / total for c, v in per_cluster.items()}
            report["wan_bytes_share"] = sum(
                v for c, v in per_cluster.items() if c in wan) / total
            report["cluster_failovers"] = (
                sum(ld.pool.cluster_failovers for ld in self.loaders)
                - counters0["cluster_failovers"])
            report["cluster_report"] = self.federation.cluster_report()
            if self.federation.replication is not None:
                # hot-key replication over this window: fraction of fetches
                # served from a promoted replica, and the WAN bytes those
                # hits kept off the intercontinental route
                fetches = (sum(ld.pool.fetches for ld in self.loaders)
                           - counters0["fetches"])
                hits = (sum(ld.pool.replica_hits for ld in self.loaders)
                        - counters0["replica_hits"])
                report["replica_hit_frac"] = hits / max(fetches, 1)
                report["wan_bytes_saved"] = (
                    sum(ld.pool.wan_bytes_saved for ld in self.loaders)
                    - counters0["wan_bytes_saved"])
                report["replication"] = self.federation.replication.report()
            report["ownership_weights"] = \
                self.federation.routing_ring.weights
            report["rebalances"] = self.rebalances
        return report

    def _host_request_latencies(self, ld) -> List[float]:
        """One host's recent per-fetch RTTs, pulled from the limiter's
        completion rings (all member controllers under a federation)."""
        ctl = ld.flow_controller
        if ctl is None or self.limiter is None:
            return []
        members = (ctl.members.values()
                   if isinstance(ctl, FlowControllerGroup) else [ctl])
        out: List[float] = []
        for m in members:
            out.extend(self.limiter.latencies(m))
        return out

    # -- bandwidth-aware ownership rebalancing -------------------------------
    def rebalance(self, step: float = 0.25) -> Dict[str, int]:
        """Shift weighted keyspace ownership toward member clusters with
        spare bandwidth-delay product, as measured by every host's
        per-member flow controllers (``FlowController.spare_bdp_samples``).
        Emits — and installs — a new deterministic ownership map; returns
        its weight map.  The declared ring (and therefore placement strips
        and exactly-once accounting) is untouched: rebalancing only moves
        *serving* load, which is safe because the keyspace is shared.
        Requires a federation and ``flow_control="adaptive"`` (the signal
        comes from the controllers)."""
        if self.federation is None:
            raise ValueError("ownership rebalancing needs a federation "
                             "(set MultiHostConfig.clusters)")
        if self.cfg.flow_control != "adaptive":
            raise ValueError("ownership rebalancing needs "
                             "flow_control='adaptive' (the spare-BDP signal "
                             "comes from the flow controllers)")
        spare = {s.name: 0.0 for s in self.federation.specs}
        for ld in self.loaders:
            for name, val in ld.flow_controller.spare_by_member().items():
                spare[name] += val
        new_ring = self.federation.routing_ring.rebalance(spare, step=step)
        self.federation.install_ownership(new_ring)
        self.rebalances += 1
        return new_ring.weights

    # -- coordinated checkpointing ------------------------------------------
    def checkpoint(self) -> Dict:
        """Consistent snapshot: all shards are at the same batch boundary
        (guaranteed by the round-robin driver).  Restorable onto any host
        count — the recorded seed/placement/topology let the restore rebuild
        the old strips, and any still-pending reshard-transition overrides
        travel with their shard."""
        consumed = {ld.prefetcher.consumed for ld in self.loaders}
        if len(consumed) > 1:
            raise RuntimeError(f"shards out of lockstep: consumed={consumed}")
        shards = []
        for ld in self.loaders:
            s = dict(ld.state())
            pending = ld.plan.pending_overrides(s["epoch"])
            if pending:
                s["overrides"] = {int(e): [str(u) for u in samples]
                                  for e, samples in pending.items()}
            if ld.flow_controller is not None:
                s["flow"] = ld.flow_controller.snapshot()
            shards.append(s)
        ck = {
            "rounds": self.rounds_consumed,
            "num_shards": self.cfg.n_hosts,
            "dataset_size": len(self._uuids),
            "seed": self.cfg.seed,
            "placement": self.cfg.placement,
            "sampling": self.cfg.sampling,
            "n_nodes": self.cfg.n_nodes,
            "node_names": self.cluster.node_names(),
            "ring_seed": self.cluster.ring_seed,
            "replication_factor": self.cfg.replication_factor,
            "shards": shards,
        }
        if self.cfg.sampling == "zipf":
            ck["zipf_s"] = self.cfg.zipf_s
            if self.cfg.zipf_shift_every is not None:
                ck["zipf_shift_every"] = self.cfg.zipf_shift_every
        if "zipf" in self._host_sampling and self.cfg.sampling != "zipf":
            # mixed workload: the per-host sampling map (and per-host zipf
            # exponents) decide restore exactness, see _start_zipf
            ck["host_sampling"] = list(self._host_sampling)
            ck["host_zipf_s"] = list(self._host_zipf_s)
            if self.cfg.zipf_shift_every is not None:
                ck["zipf_shift_every"] = self.cfg.zipf_shift_every
        if self.tenant_of_host is not None:
            ck["tenant_of_host"] = list(self.tenant_of_host)
            ck["tenants"] = self.limiter.snapshot()
        if self.federation is not None:
            ck["federation"] = self.federation.ring.metadata()
            # runtime placement state rides along: the rebalanced ownership
            # map (when one is installed) and the hot-key replica cache —
            # both cluster-side, so they restore onto any host count
            if self.federation.routing_ring is not self.federation.ring:
                ck["ownership"] = self.federation.routing_ring.metadata()
            if self.federation.replication is not None:
                ck["replication"] = self.federation.replication.snapshot()
        return ck

    # -- introspection -------------------------------------------------------
    def shard_sizes(self) -> List[int]:
        return [len(ld.plan) for ld in self.loaders]

    def describe(self) -> str:
        tenants = ""
        if self.cfg.tenants:
            tenants = " [tenants: " + ", ".join(
                f"{t.name}({t.qos}, w={t.weight:g})"
                for t in self.cfg.tenants) + "]"
        if self.federation is not None:
            members = ", ".join(
                f"{s.name}({s.n_nodes}x{s.backend}, rf={s.replication_factor},"
                f" {s.route if isinstance(s.route, str) else s.route_profile().name}"
                " route)" for s in self.federation.specs)
            return (f"{self.cfg.n_hosts} hosts x B={self.cfg.batch_size} "
                    f"-> federation [{members}] "
                    f"({self.cfg.placement} placement){tenants}")
        return (f"{self.cfg.n_hosts} hosts x B={self.cfg.batch_size} "
                f"-> {self.cfg.n_nodes}-node {self.cfg.backend} "
                f"(rf={self.cfg.replication_factor}, {self.cfg.route} route, "
                f"{self.cfg.placement} placement){tenants}")


def _steady_strips(uuids: List[_uuid.UUID], seed: int, n_hosts: int,
                   policy: str, ring=None, rf: int = 1,
                   preferred=None) -> List[List[_uuid.UUID]]:
    """One strip per host of the global shuffle, per placement policy — the
    single strip-builder both fresh runs and checkpoint reconstruction use,
    so the two can never drift."""
    return split_strips(global_order(uuids, seed, n_hosts), n_hosts, policy,
                        ring=ring, rf=rf, preferred=preferred)


def _parse_overrides(overrides: Dict) -> Dict[int, List[_uuid.UUID]]:
    """Checkpoint override lists back to UUID objects (keys may be str)."""
    return {int(e): [u if isinstance(u, _uuid.UUID) else _uuid.UUID(u)
                     for u in samples]
            for e, samples in overrides.items()}


__all__ = ["MultiHostConfig", "MultiHostRun"]
