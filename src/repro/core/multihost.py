"""Multi-host loading: N training hosts against one shared cluster.

The paper's scaling story (Sec. 4, multi-GPU training) has several training
hosts hammering the same database cluster at once; what makes that realistic
here is that all the *shared* server-side resources — per-node disk and NIC
egress FIFOs, backend service processes — live in one ``Cluster`` on one
``VirtualClock``, while each host brings its own ``ConnectionPool`` (own TCP
connections, own AIMD processes, own ingress NIC).  Adding clients therefore
degrades per-client throughput through genuine egress/disk contention, not
through an ad-hoc penalty factor.

``MultiHostRun`` wires up N ``CassandraLoader`` shards (disjoint contiguous
strips of one global shuffle — see ``EpochPlan``) and drives them in
round-robin lockstep: one batch per host per round, so every host has
consumed the same number of batches whenever control returns to the caller.
That lockstep is what makes ``checkpoint()`` consistent: the per-shard
``(epoch, cursor)`` states it captures all correspond to the same global
batch boundary, and ``start(checkpoint)`` resumes every shard from exactly
that boundary.

Failure injection (``inject_failure``) takes a ``SimServerNode`` dark
mid-run; hedged requests plus the connection-pool failover path keep all
loaders alive through it (requests re-route to live replicas).
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass
from typing import Dict, List, Optional

from .cluster import Cluster
from .kvstore import KVStore
from .loader import CassandraLoader, LoaderConfig
from .netsim import DISK_BANDWIDTH, NIC_BANDWIDTH, VirtualClock


@dataclass
class MultiHostConfig:
    """N-host run over a shared cluster; loader knobs mirror LoaderConfig."""

    n_hosts: int = 2
    batch_size: int = 256
    prefetch_buffers: int = 8
    io_threads: int = 8
    conns_per_thread: int = 2
    out_of_order: bool = True
    incremental_ramp: bool = True
    ramp_every: int = 4
    route: str = "high"
    backend: str = "scylla"
    n_nodes: int = 4
    replication_factor: int = 2
    hedge_after: Optional[float] = 1.0   # stragglers + failover need hedging
    seed: int = 0
    materialize: bool = False
    # Shared-cluster capacity: per-node NIC/disk.  The default is the paper's
    # 50 Gb/s NIC; pinch it (e.g. 1-10 GbE) to study egress contention as the
    # client count grows.
    node_egress_bandwidth: float = NIC_BANDWIDTH
    node_disk_bandwidth: float = DISK_BANDWIDTH

    def loader_config(self, shard_id: int) -> LoaderConfig:
        return LoaderConfig(
            batch_size=self.batch_size,
            prefetch_buffers=self.prefetch_buffers,
            io_threads=self.io_threads,
            conns_per_thread=self.conns_per_thread,
            out_of_order=self.out_of_order,
            incremental_ramp=self.incremental_ramp,
            ramp_every=self.ramp_every,
            route=self.route,
            backend=self.backend,
            n_nodes=self.n_nodes,
            replication_factor=self.replication_factor,
            hedge_after=self.hedge_after,
            seed=self.seed,
            shard_id=shard_id,
            num_shards=self.n_hosts,
            materialize=self.materialize,
            virtual_clock=True,
        )


class MultiHostRun:
    """Coordinator for N sharded loaders on one clock + one cluster."""

    def __init__(self, store: KVStore, uuids: List[_uuid.UUID],
                 cfg: MultiHostConfig,
                 clock: Optional[VirtualClock] = None,
                 cluster: Optional[Cluster] = None) -> None:
        if cfg.n_hosts < 1:
            raise ValueError("need at least one host")
        self.cfg = cfg
        self.clock = clock or VirtualClock()
        self.cluster = cluster or Cluster(
            self.clock, store, backend=cfg.backend, n_nodes=cfg.n_nodes,
            rf=cfg.replication_factor, seed=cfg.seed + 5,
            disk_bandwidth=cfg.node_disk_bandwidth,
            egress_bandwidth=cfg.node_egress_bandwidth)
        self.loaders: List[CassandraLoader] = [
            CassandraLoader(store, uuids, cfg.loader_config(i),
                            clock=self.clock, cluster=self.cluster)
            for i in range(cfg.n_hosts)
        ]
        self.rounds_consumed = 0
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def start(self, checkpoint: Optional[Dict] = None) -> "MultiHostRun":
        """Start all shards, either fresh or from a coordinated checkpoint."""
        if checkpoint is None:
            for ld in self.loaders:
                ld.start()
        else:
            shards = checkpoint["shards"]
            if len(shards) != len(self.loaders):
                raise ValueError(
                    f"checkpoint has {len(shards)} shards, run has "
                    f"{len(self.loaders)} — resharding is not supported")
            for ld, s in zip(self.loaders, shards):
                ld.start(s["epoch"], s["cursor"])
        self._started = True
        return self

    def inject_failure(self, node: str, after: float,
                       recover_after: Optional[float] = None) -> None:
        """Schedule ``node`` to go dark ``after`` virtual seconds from now."""
        self.cluster.schedule_failure(node, after, recover_after)

    # -- driving ------------------------------------------------------------
    def run(self, n_rounds: int, step_time: float = 0.0,
            timeout: float = 600.0) -> Dict:
        """Consume ``n_rounds`` batches on every host, round-robin lockstep.

        ``step_time`` models the per-step GPU compute all hosts perform in
        parallel (one sleep per round, not per host).  Returns a report dict;
        cumulative over repeated calls on the same run.
        """
        if not self._started:
            self.start()
        t0 = self.clock.now()
        bytes0 = [ld.pool.bytes_received for ld in self.loaders]
        for _ in range(n_rounds):
            for ld in self.loaders:
                ld.next_batch(timeout=timeout)
            if step_time > 0.0:
                self.clock.sleep(step_time)
        self.rounds_consumed += n_rounds
        return self._report(t0, bytes0, n_rounds)

    def _report(self, t0: float, bytes0: List[int], n_rounds: int) -> Dict:
        elapsed = max(self.clock.now() - t0, 1e-9)
        per_client_bytes = [ld.pool.bytes_received - b0
                            for ld, b0 in zip(self.loaders, bytes0)]
        per_client_Bps = [b / elapsed for b in per_client_bytes]
        return {
            "n_hosts": self.cfg.n_hosts,
            "rounds": n_rounds,
            "elapsed_s": elapsed,
            "aggregate_Bps": sum(per_client_bytes) / elapsed,
            "per_client_Bps": per_client_Bps,
            # fairness: worst/best per-client rate (1.0 = perfectly fair)
            "fairness": (min(per_client_Bps) / max(max(per_client_Bps), 1e-9)
                         if per_client_Bps else 0.0),
            "failovers": sum(ld.pool.failovers for ld in self.loaders),
            "requests_sent": sum(ld.pool.requests_sent for ld in self.loaders),
            "cluster_load": self.cluster.load_report(),
        }

    # -- coordinated checkpointing ------------------------------------------
    def checkpoint(self) -> Dict:
        """Consistent snapshot: all shards are at the same batch boundary
        (guaranteed by the round-robin driver)."""
        consumed = {ld.prefetcher.consumed for ld in self.loaders}
        if len(consumed) > 1:
            raise RuntimeError(f"shards out of lockstep: consumed={consumed}")
        return {
            "rounds": self.rounds_consumed,
            "num_shards": self.cfg.n_hosts,
            "shards": [ld.state() for ld in self.loaders],
        }

    # -- introspection -------------------------------------------------------
    def shard_sizes(self) -> List[int]:
        return [len(ld.plan) for ld in self.loaders]

    def describe(self) -> str:
        return (f"{self.cfg.n_hosts} hosts x B={self.cfg.batch_size} "
                f"-> {self.cfg.n_nodes}-node {self.cfg.backend} "
                f"(rf={self.cfg.replication_factor}, {self.cfg.route} route)")


__all__ = ["MultiHostConfig", "MultiHostRun"]
