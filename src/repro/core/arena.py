"""Pinned prefetcher arena: reusable contiguous batch buffers.

The materialize path used to build every batch as per-sample Python
``bytes`` plus a fresh ``bytearray`` per batch — three host passes (decode
to bytes, copy into the batch buffer, re-parse into arrays) before the
device ever saw a byte.  The arena replaces that with a small pool of
preallocated page-aligned-style numpy slabs (the sim analogue of pinned
host memory): ``BatchAssembler`` writes each arriving sample straight into
its slot of a reused ``(batch, slot_bytes)`` uint8 buffer, drops the
per-sample bytes, and the device feed hands the *whole slab* to a single
``device_put`` + fused Pallas crop/mirror/normalize call
(``kernels/crop_norm.py``) — zero per-batch host materialize/transpose
passes.

Slabs cycle acquire -> write -> (device upload) -> release; the pool grows
only when the consumer holds more slabs than expected (``slabs_created``
makes that visible), so steady state allocates nothing per batch.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np


class ArenaSlab:
    """One pinned batch buffer: ``(batch_size, slot_bytes)`` uint8."""

    __slots__ = ("buf", "lengths", "_arena")

    def __init__(self, batch_size: int, slot_bytes: int,
                 arena: "Optional[PinnedArena]" = None) -> None:
        self.buf = np.zeros((batch_size, slot_bytes), dtype=np.uint8)
        self.lengths = np.zeros((batch_size,), dtype=np.int64)
        self._arena = arena

    @property
    def batch_size(self) -> int:
        return self.buf.shape[0]

    @property
    def slot_bytes(self) -> int:
        return self.buf.shape[1]

    @property
    def nbytes(self) -> int:
        return self.buf.nbytes

    def write(self, slot: int, payload: Optional[bytes], size: int) -> None:
        """Copy one sample's payload into its slot (clipped to the slot, the
        tail zeroed so a reused slab never leaks a previous batch's bytes)."""
        cap = self.slot_bytes
        n = 0
        if payload is not None:
            n = min(len(payload), size, cap)
            self.buf[slot, :n] = np.frombuffer(payload, dtype=np.uint8,
                                               count=n)
        if n < cap:
            self.buf[slot, n:] = 0
        self.lengths[slot] = n

    def view(self, slot: int, size: Optional[int] = None) -> memoryview:
        """Zero-copy view of one sample's bytes (buffer-protocol compatible:
        ``np.frombuffer``, ``struct.unpack`` and slicing all accept it)."""
        n = int(self.lengths[slot]) if size is None else min(size,
                                                             self.slot_bytes)
        return memoryview(self.buf[slot, :n])  # type: ignore[arg-type]

    def pixels(self, h: int, w: int, c: int) -> np.ndarray:
        """Zero-copy ``(B, h, w, c)`` uint8 view over the slab — what the
        device feed uploads in one shot for the fused Pallas decode."""
        n = h * w * c
        if n > self.slot_bytes:
            raise ValueError(f"slot holds {self.slot_bytes} B, "
                             f"image needs {n}")
        return self.buf[:, :n].reshape(self.batch_size, h, w, c)

    def release(self) -> None:
        if self._arena is not None:
            self._arena.release(self)


class PinnedArena:
    """Fixed-geometry slab pool; grows on demand, reuses in steady state."""

    def __init__(self, batch_size: int, slot_bytes: int,
                 initial_slabs: int = 0) -> None:
        if batch_size < 1 or slot_bytes < 1:
            raise ValueError(f"bad arena geometry {batch_size}x{slot_bytes}")
        self.batch_size = batch_size
        self.slot_bytes = slot_bytes
        self._free: List[ArenaSlab] = [ArenaSlab(batch_size, slot_bytes, self)
                                       for _ in range(initial_slabs)]
        self.slabs_created = initial_slabs
        self.acquires = 0
        self.reuses = 0
        self.outstanding = 0
        self.high_water = initial_slabs

    def acquire(self) -> ArenaSlab:
        self.acquires += 1
        self.outstanding += 1
        self.high_water = max(self.high_water, self.outstanding
                              + len(self._free))
        if self._free:
            self.reuses += 1
            return self._free.pop()
        self.slabs_created += 1
        return ArenaSlab(self.batch_size, self.slot_bytes, self)

    def release(self, slab: ArenaSlab) -> None:
        if slab.batch_size != self.batch_size \
                or slab.slot_bytes != self.slot_bytes:
            raise ValueError("slab does not belong to this arena")
        if slab in self._free:
            return                      # idempotent release
        self.outstanding = max(0, self.outstanding - 1)
        self._free.append(slab)

    def stats(self) -> dict:
        return {"slabs_created": self.slabs_created,
                "acquires": self.acquires,
                "reuses": self.reuses,
                "outstanding": self.outstanding,
                "high_water": self.high_water,
                "slab_bytes": self.batch_size * self.slot_bytes}


__all__ = ["ArenaSlab", "PinnedArena"]
