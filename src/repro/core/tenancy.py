"""Multi-tenant weighted-fair QoS: tenant specs, scheduling, admission.

A production federation serving "millions of users" (ROADMAP north star)
multiplexes many concurrent jobs — latency-sensitive serve-style decode
streams next to throughput batch scans — over the same client NICs, and
cloud-storage contention between such tenants is exactly where throughput
and tail latency collapse (Krichevsky et al., arxiv 2108.06322).
:class:`repro.core.flowctl.SharedIngressLimiter` splits that NIC equally
*per host* with no notion of tenant, priority, or starvation; this module
generalizes it:

* :class:`TenantSpec` — a declarative tenant: QoS class (``latency`` |
  ``batch``), weight, optional rate floor/ceiling in bytes/s, and the
  tenant's workload shape (``uniform``, or the PR-5 ``zipf`` machinery as
  the adversarial batch tenant).
* :class:`TenantScheduler` — a deficit-round-robin-style weighted-fair
  split of the NIC among tenants *with demand*, enforced the same way the
  base limiter enforces its equal split: as a cap on each member
  controller's budget (``fair_cap_samples``), so adaptive flow control and
  QoS compose instead of fight.  Plus tenant-level admission control
  (``admit``), consulted by ``ConnectionPool.admit`` on the PR-6
  route-admission deferral path.

Scheduling invariants (property-tested in ``tests/test_tenancy.py``):

* **conservation** — granted shares never sum above the NIC bandwidth;
* **weighted fairness** — backlogged tenants without floors/ceilings split
  the NIC in proportion to their weights;
* **work conservation** — an idle tenant's share (and the slice a capped
  or low-demand tenant cannot use) is fully redistributed over the tenants
  that still have demand, never stranded;
* **no starvation** — a tenant holding a ``rate_floor`` is granted at
  least that floor whenever it has demand, no matter how heavy an
  adversarial tenant's weight or workload is.

A single tenant with default weights degenerates to exactly the untenanted
limiter: the water-fill grants it the whole NIC (same floats as
``bandwidth / n_active``), demand caps are skipped when no other tenant
could use the surplus, and admission always passes — the bit-identity
regression in ``tests/test_tenancy.py`` pins this.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .flowctl import FlowController, SharedIngressLimiter
from .replication import SAMPLING_MODES
from .stats import summarize

QOS_CLASSES = ("latency", "batch")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant sharing the federation: QoS class, scheduling weight,
    optional absolute rate floor/ceiling (bytes/s), and workload shape.

    ``qos`` steers admission (``latency`` tenants get burst headroom so a
    short serve-style burst rides through; ``batch`` tenants defer strictly
    at their share) and groups the per-tenant report sections.  ``weight``
    sets the proportional share of NIC bandwidth left after floors.
    ``sampling``/``zipf_s`` describe the tenant's access pattern — hosts
    tagged with a ``zipf`` tenant run the PR-5 skewed sampler, which is how
    the aggressive batch tenant of the isolation bench is expressed."""

    name: str
    qos: str = "batch"
    weight: float = 1.0
    rate_floor: Optional[float] = None      # guaranteed bytes/s under load
    rate_ceiling: Optional[float] = None    # hard cap, bytes/s
    sampling: str = "uniform"
    zipf_s: float = 1.05

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("a tenant needs a non-empty name")
        if self.qos not in QOS_CLASSES:
            raise ValueError(f"unknown qos class {self.qos!r} "
                             f"(choose from {QOS_CLASSES})")
        if self.weight <= 0.0:
            raise ValueError(f"tenant weight must be positive, "
                             f"got {self.weight}")
        if self.rate_floor is not None and self.rate_floor <= 0.0:
            raise ValueError(f"rate_floor must be positive, "
                             f"got {self.rate_floor}")
        if self.rate_ceiling is not None and self.rate_ceiling <= 0.0:
            raise ValueError(f"rate_ceiling must be positive, "
                             f"got {self.rate_ceiling}")
        if (self.rate_floor is not None and self.rate_ceiling is not None
                and self.rate_ceiling < self.rate_floor):
            raise ValueError(f"rate_ceiling ({self.rate_ceiling}) below "
                             f"rate_floor ({self.rate_floor})")
        if self.sampling not in SAMPLING_MODES:
            raise ValueError(f"unknown sampling mode {self.sampling!r} "
                             f"(choose from {SAMPLING_MODES})")
        if self.zipf_s <= 0.0:
            raise ValueError(f"zipf_s must be positive, got {self.zipf_s}")


class TenantScheduler(SharedIngressLimiter):
    """Weighted-fair NIC shares per tenant, enforced through budget caps.

    Member controllers (one per host route, or one per federation member
    under a :class:`~repro.core.flowctl.FlowControllerGroup`) are
    ``assign``-ed to tenants.  ``tenant_shares`` runs a DRR-style water-
    fill: rate floors come off the top, the remainder is split by weight,
    and a tenant closes out early at its ``rate_ceiling`` or at its
    *measured demand* (delivery rate plus growth headroom) — its unused
    slice re-enters the fill for the still-open tenants, which is what
    makes the split work-conserving.  ``fair_cap_samples`` then divides a
    tenant's share equally among its active members and converts to a BDP
    cap exactly like the base limiter.

    ``admit`` adds tenant-level admission on top of the per-route budget:
    a new request is deferred when the tenant's measured in-flight load
    already covers its share's BDP (``latency`` tenants get
    ``latency_burst`` headroom).  It is advisory like the rest of the
    admission chain — the prefetcher defers boundedly and force-issues, so
    delivery is never dropped (see ``OutOfOrderPrefetcher``).
    """

    _TENANT_RING = 65536        # recent request latencies kept per tenant

    def __init__(self, bandwidth: float, tenants: Sequence[TenantSpec],
                 clock=None, activity_window: float = 1.0,
                 latency_burst: float = 1.25,
                 demand_headroom: float = 1.5) -> None:
        super().__init__(bandwidth, clock=clock,
                         activity_window=activity_window)
        specs: Tuple[TenantSpec, ...] = tuple(tenants)
        if not specs:
            raise ValueError("a tenant scheduler needs at least one tenant")
        names = [t.name for t in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        floors = sum(t.rate_floor or 0.0 for t in specs)
        if floors > bandwidth:
            raise ValueError(f"rate floors oversubscribe the NIC "
                             f"({floors:.4g} > {bandwidth:.4g} B/s)")
        if latency_burst < 1.0:
            raise ValueError(f"latency_burst must be >= 1, "
                             f"got {latency_burst}")
        if demand_headroom <= 1.0:
            raise ValueError(f"demand_headroom must be > 1, "
                             f"got {demand_headroom}")
        self.specs = specs
        self.tenants: Dict[str, TenantSpec] = {t.name: t for t in specs}
        self.latency_burst = latency_burst
        self.demand_headroom = demand_headroom
        self._default_tenant = specs[0].name
        self._tenant_of: Dict[FlowController, str] = {}
        self._tenant_bytes: Dict[str, int] = {n: 0 for n in names}
        self._tenant_completions: Dict[str, int] = {n: 0 for n in names}
        self._tenant_latency: Dict[str, Deque[float]] = {
            n: deque(maxlen=self._TENANT_RING) for n in names}
        self.admit_checks: Dict[str, int] = {n: 0 for n in names}
        self.admit_denials: Dict[str, int] = {n: 0 for n in names}
        # water-fill memo: the split only moves when virtual time advances
        # or a completion/registration lands, and the admission path asks
        # for it once per would-be fetch — without the memo a deferring
        # tenant recomputes an identical split thousands of times per round
        self._events = 0
        self._shares_cache: Optional[Tuple[tuple, Dict[str, float]]] = None
        # admission memo: at a fixed instant with no new completions or
        # issues, the verdict for one asking controller cannot change, but
        # the prefetcher re-asks once per deferred key per fill slot — a
        # deferral storm makes that thousands of identical computations
        self._admit_cache: Dict[str, Tuple[tuple, bool]] = {}

    # -- membership ---------------------------------------------------------
    def register(self, ctl: FlowController) -> None:
        super().register(ctl)
        self._tenant_of.setdefault(ctl, self._default_tenant)
        self._events += 1

    def assign(self, ctl: FlowController, tenant: str) -> None:
        """Tag a controller with its tenant (``MultiHostRun`` calls this for
        each host's controller — or each group member — after wiring)."""
        if tenant not in self.tenants:
            raise ValueError(f"unknown tenant {tenant!r} "
                             f"(have {sorted(self.tenants)})")
        super().register(ctl)
        self._tenant_of[ctl] = tenant
        self._events += 1

    def note_issue(self) -> None:
        """A member pool issued a fetch: in-flight EMAs moved, so cached
        admission verdicts (and shares, conservatively) are stale."""
        self._events += 1

    def tenant_of(self, ctl: FlowController) -> Optional[str]:
        return self._tenant_of.get(ctl)

    def _members_of(self, name: str, now: float,
                    include: Optional[FlowController] = None,
                    ) -> List[FlowController]:
        """A tenant's *active* members (same activity rule as the base
        limiter, scoped to the tenant)."""
        out = [c for c in self._members
               if self._tenant_of.get(c) == name
               and (c not in self._last_seen
                    or now - self._last_seen[c] <= self.activity_window)]
        if (include is not None and include not in out
                and self._tenant_of.get(include) == name):
            out.append(include)
        return out

    # -- bookkeeping --------------------------------------------------------
    def on_complete(self, ctl: FlowController, rtt: float, now: float,
                    nbytes: int) -> None:
        super().on_complete(ctl, rtt, now, nbytes)
        self._events += 1
        name = self._tenant_of.get(ctl)
        if name is not None:
            self._tenant_bytes[name] += nbytes
            self._tenant_completions[name] += 1
            self._tenant_latency[name].append(rtt)

    # -- the weighted-fair split --------------------------------------------
    def _demand_cap(self, spec: TenantSpec,
                    members: List[FlowController]) -> Optional[float]:
        """Measured demand of a tenant (bytes/s) padded with growth
        headroom, floored at its ``rate_floor``.  ``None`` = unbounded: a
        member without a rate sample yet is still ramping and must be
        allowed to probe past any measurement."""
        total = 0.0
        for m in members:
            rate = m.delivery_rate()
            avg = m.avg_sample_bytes()
            if rate is None or avg is None:
                return None
            total += rate * avg
        return max(total * self.demand_headroom, spec.rate_floor or 0.0)

    def tenant_shares(self, now: Optional[float] = None,
                      include: Optional[FlowController] = None,
                      ) -> Dict[str, float]:
        """Work-conserving weighted-fair split of the NIC among tenants
        with demand (bytes/s per active tenant; idle tenants get nothing —
        their slice is redistributed).  Floors come off the top; the
        remainder water-fills by weight, closing a tenant out at its
        ceiling or measured demand and re-filling the surplus."""
        if now is None:
            now = self._now()
        # memo: same instant + no new events + same asking tenant => same
        # split (rates/activity are functions of time and completions only)
        key = (now, self._events,
               self._tenant_of.get(include) if include is not None else None)
        if self._shares_cache is not None and self._shares_cache[0] == key:
            return dict(self._shares_cache[1])
        active_members = {name: self._members_of(name, now, include)
                          for name in self.tenants}
        active = [self.tenants[n]
                  for n, ms in active_members.items() if ms]
        if not active:
            return {}
        grant = {t.name: 0.0 for t in active}
        remaining = self.bandwidth
        # 1. rate floors off the top (ctor validates they fit the NIC)
        for t in active:
            f = min(t.rate_floor or 0.0, remaining)
            grant[t.name] += f
            remaining -= f
        # 2. per-tenant close-out caps.  Demand caps exist so another
        # tenant can use the surplus — with a single active tenant there is
        # no beneficiary, and skipping them keeps the lone-tenant grant
        # bit-identical to the untenanted limiter's full-NIC share.
        caps: Dict[str, Optional[float]] = {}
        for t in active:
            cap = t.rate_ceiling
            if len(active) > 1:
                demand = self._demand_cap(t, active_members[t.name])
                if demand is not None:
                    cap = demand if cap is None else min(cap, demand)
            caps[t.name] = cap
        # 3. DRR-style water-fill of the remainder by weight
        todo = list(active)
        while todo and remaining > 1e-9:
            wsum = sum(t.weight for t in todo)
            closed = [t for t in todo
                      if caps[t.name] is not None
                      and grant[t.name] + remaining * t.weight / wsum
                      >= caps[t.name]]
            if not closed:
                for t in todo:
                    grant[t.name] += remaining * t.weight / wsum
                break
            for t in closed:
                extra = min(max(caps[t.name] - grant[t.name], 0.0),
                            remaining)
                grant[t.name] += extra
                remaining -= extra
                todo.remove(t)
        self._shares_cache = (key, dict(grant))
        return grant

    def fair_cap_samples(self, ctl: FlowController) -> float:
        min_rtt = ctl.min_rtt()
        avg = ctl.avg_sample_bytes()
        if min_rtt is None or avg is None:
            return math.inf
        name = self._tenant_of.get(ctl)
        if name is None:                    # unassigned: equal-split fallback
            return super().fair_cap_samples(ctl)
        now = self._now()
        shares = self.tenant_shares(now, include=ctl)
        members = self._members_of(name, now, include=ctl)
        share = shares.get(name, 0.0) / max(len(members), 1)
        return ctl.cfg.gain * (share / avg) * min_rtt

    # -- admission ----------------------------------------------------------
    def admit(self, ctl: FlowController) -> bool:
        """May this tenant put one more request in flight?  Compares the
        tenant's measured in-flight load (sum of member EMAs) against the
        BDP of its granted share; ``latency`` tenants ride ``latency_burst``
        headroom, ``batch`` tenants defer right at their share."""
        name = self._tenant_of.get(ctl)
        if name is None:
            return True
        self.admit_checks[name] += 1
        now = self._now()
        # the verdict is a function of (time, completions/issues seen,
        # asking controller) — ``note_issue`` bumps ``_events`` so an
        # in-fill issue invalidates this like a completion would
        key = (now, self._events, id(ctl))
        hit = self._admit_cache.get(name)
        if hit is not None and hit[0] == key:
            ok = hit[1]
        else:
            ok = self._admit_verdict(name, ctl, now)
            self._admit_cache[name] = (key, ok)
        if not ok:
            self.admit_denials[name] += 1
        return ok

    def _admit_verdict(self, name: str, ctl: FlowController,
                       now: float) -> bool:
        members = self._members_of(name, now, include=ctl)
        cap = 0.0
        for m in members:
            c = self.fair_cap_samples(m)
            if math.isinf(c):
                return True                 # still unmeasured: let it ramp
            cap += c
        load = sum(m.inflight_samples() for m in members)
        burst = (self.latency_burst
                 if self.tenants[name].qos == "latency" else 1.0)
        return load < burst * cap

    # -- reporting / checkpoint ---------------------------------------------
    def report(self) -> Dict:
        """Per-tenant scheduling view: current share, cumulative egress,
        request-latency summary over the recent ring, admission counters."""
        now = self._now()
        shares = self.tenant_shares(now)
        out: Dict[str, Dict] = {}
        for name, spec in self.tenants.items():
            lat = np.asarray(self._tenant_latency[name], dtype=float)
            out[name] = {
                "qos": spec.qos,
                "weight": spec.weight,
                "rate_floor": spec.rate_floor,
                "rate_ceiling": spec.rate_ceiling,
                "active_members": len(self._members_of(name, now)),
                "share_Bps": shares.get(name, 0.0),
                "egress_bytes": self._tenant_bytes[name],
                "completions": self._tenant_completions[name],
                "request_latency_s": summarize(lat),
                "admit_checks": self.admit_checks[name],
                "admit_denials": self.admit_denials[name],
            }
        return out

    def snapshot(self) -> Dict:
        """Checkpoint state: specs ride along so an elastic N->M restore can
        assert weight conservation, counters re-seed the cumulative
        per-tenant totals."""
        return {"bandwidth": self.bandwidth,
                "tenants": {name: {
                    "qos": spec.qos,
                    "weight": spec.weight,
                    "rate_floor": spec.rate_floor,
                    "rate_ceiling": spec.rate_ceiling,
                    "egress_bytes": self._tenant_bytes[name],
                    "completions": self._tenant_completions[name],
                    "admit_checks": self.admit_checks[name],
                    "admit_denials": self.admit_denials[name],
                } for name, spec in self.tenants.items()}}

    def restore(self, state: Optional[Dict]) -> None:
        if not state:
            return
        for name, s in (state.get("tenants") or {}).items():
            if name not in self.tenants:
                continue        # tenant dropped from the config: state moot
            self._tenant_bytes[name] = int(s.get("egress_bytes", 0))
            self._tenant_completions[name] = int(s.get("completions", 0))
            self.admit_checks[name] = int(s.get("admit_checks", 0))
            self.admit_denials[name] = int(s.get("admit_denials", 0))


__all__ = ["QOS_CLASSES", "TenantSpec", "TenantScheduler"]
