"""Cluster topology: consistent-hash token ring, replication, routing.

Models the server side of a Cassandra/ScyllaDB deployment: each node owns
token ranges (with virtual nodes for balance), rows are replicated RF-ways,
and a token-aware client can route any request directly to a replica —
the property the paper's driver relies on for low latency.
"""

from __future__ import annotations

import bisect
import uuid as _uuid
from typing import Dict, List, Optional

import numpy as np

from .kvstore import KVStore, token_of
from .netsim import (BACKENDS, DISK_BANDWIDTH, NIC_BANDWIDTH, BackendModel,
                     Clock, RouteProfile, SimServerNode, TIERS)


class TokenRing:
    """Consistent-hash ring with virtual nodes."""

    def __init__(self, node_names: List[str], vnodes: int = 64, seed: int = 7) -> None:
        rng = np.random.default_rng(seed)
        self._points: List[int] = []
        self._owners: List[str] = []
        entries = []
        for name in node_names:
            for tok in rng.integers(0, 2 ** 64, size=vnodes, dtype=np.uint64):
                entries.append((int(tok), name))
        entries.sort()
        self._points = [e[0] for e in entries]
        self._owners = [e[1] for e in entries]
        self._names = list(node_names)

    def replicas_for_token(self, token: int, rf: int) -> List[str]:
        """Walk the ring clockwise collecting rf distinct owners."""
        if not self._points:
            return []
        idx = bisect.bisect_right(self._points, token) % len(self._points)
        out: List[str] = []
        i = idx
        while len(out) < min(rf, len(self._names)):
            owner = self._owners[i % len(self._points)]
            if owner not in out:
                out.append(owner)
            i += 1
        return out

    def replicas(self, key: _uuid.UUID, rf: int) -> List[str]:
        return self.replicas_for_token(token_of(key), rf)


class Cluster:
    """A set of simulated storage nodes fronted by a token ring.

    The *store* (logical contents) is shared; per-node simulation state
    (disk, egress, GC) is separate, so routing decisions have performance
    consequences just as they do against a real cluster.
    """

    def __init__(self, clock: Clock, store: KVStore, backend: str = "scylla",
                 n_nodes: int = 1, rf: int = 1, seed: int = 1234,
                 disk_bandwidth: float = DISK_BANDWIDTH,
                 egress_bandwidth: float = NIC_BANDWIDTH,
                 node_prefix: str = "", cpu_cores: int = 0) -> None:
        if isinstance(backend, str):
            backend_model = BACKENDS[backend]
        else:
            backend_model = backend
        self.clock = clock
        self.store = store
        self.backend = backend_model
        self.rf = min(rf, n_nodes)
        self.ring_seed = seed     # recorded so checkpoints can rebuild the ring
        # A federation member qualifies its node names ("eu/node0") so the
        # merged node namespace stays collision-free across clusters.
        self.node_prefix = node_prefix
        names = [f"{node_prefix}node{i}" for i in range(n_nodes)]
        self.nodes: Dict[str, SimServerNode] = {
            name: SimServerNode(name, backend_model,
                                np.random.default_rng(seed + 17 * i),
                                disk_bandwidth=disk_bandwidth,
                                egress_bandwidth=egress_bandwidth,
                                cpu_cores=cpu_cores)
            for i, name in enumerate(names)
        }
        self.ring = TokenRing(names, seed=seed)

    def replica_nodes(self, key: _uuid.UUID) -> List[SimServerNode]:
        return [self.nodes[n] for n in self.ring.replicas(key, self.rf)]

    def total_disk_bytes(self) -> int:
        return sum(n.disk_bytes for n in self.nodes.values())

    def node_names(self) -> List[str]:
        return list(self.nodes.keys())

    # -- failure injection --------------------------------------------------
    def fail_node(self, name: str) -> None:
        """Take a node dark immediately (see schedule_failure for mid-run)."""
        self.nodes[name].fail()

    def recover_node(self, name: str) -> None:
        self.nodes[name].recover()

    def schedule_failure(self, name: str, after: float,
                         recover_after: Optional[float] = None) -> None:
        """Node ``name`` goes dark ``after`` seconds from now; optionally
        comes back ``recover_after`` seconds later."""
        node = self.nodes[name]
        self.clock.schedule(after, node.fail)
        if recover_after is not None:
            self.clock.schedule(after + recover_after, node.recover)

    def alive_nodes(self) -> List[str]:
        return [n for n, node in self.nodes.items() if not node.down]

    # -- load reporting -----------------------------------------------------
    def load_report(self) -> Dict[str, Dict[str, float]]:
        """Per-node served-load snapshot (replica-aware routing makes these
        diverge under contention; the multi-host benchmark prints them).
        ``egress_share`` is each node's fraction of total cluster egress —
        the imbalance signal the placement policies compete on."""
        now = self.clock.now()
        total_egress = sum(n.egress_bytes for n in self.nodes.values())
        report: Dict[str, Dict[str, float]] = {}
        for name, node in self.nodes.items():
            report[name] = {
                "requests": node.requests_served,
                "egress_bytes": node.egress_bytes,
                "egress_share": node.egress_bytes / max(total_egress, 1),
                "disk_bytes": node.disk_bytes,
                "egress_busy_frac": (node.egress.fifo.busy_seconds
                                     / max(now, 1e-9)),
                # Single-core seconds spent encoding wire-codec frames
                # (zero without a codec) — the CPU the node trades for
                # wire bandwidth.
                "encode_cpu_s": node.encode_cpu_seconds,
                "down": float(node.down),
            }
        return report


__all__ = ["TokenRing", "Cluster"]
