"""Client-side connection pool: io-threads × 2 connections, token-aware routing.

Mirrors the paper's driver usage (Sec. 3.3): multiple low-level I/O threads,
each holding two TCP connections; up to 1024 concurrent requests per
connection; completions delivered via callbacks (no busy waiting).

Extensions beyond the paper (flagged):
  * hedged requests — if a replica hasn't answered within ``hedge_after``
    seconds, a duplicate request is sent to another replica and the first
    response wins.  This is our straggler-mitigation addition for multi-node
    clusters; it is off by default to keep the paper-faithful baseline exact.
    ``hedge_after="auto"`` derives the delay per fetch from the attached
    flow controller's measured min-RTT (``FlowController.hedge_after``)
    instead of a hand-tuned constant, and suppresses hedging during
    PROBE_RTT drains (slow completions are expected while the queue drains).
"""

from __future__ import annotations

import uuid as _uuid
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from .cluster import Cluster
from .flowctl import (FlowControlConfig, FlowController,
                      SharedIngressLimiter)
from .kvstore import DataRow
from .netsim import (Clock, FifoResource, RateResource, RouteProfile,
                     SimConnection, TIERS, NIC_BANDWIDTH)
from .wirefmt import HOST_CODEC_CORES, WireCodec, get_codec

_codec_alias_warned = False


def _warn_codec_alias() -> None:
    """DeprecationWarning for ``ConnectionPool(codec=...)``, emitted once."""
    global _codec_alias_warned
    if not _codec_alias_warned:
        _codec_alias_warned = True
        warnings.warn("ConnectionPool(codec=...) is deprecated; use "
                      "wire_codec= (the spelling shared by LoaderConfig and "
                      "MultiHostConfig)", DeprecationWarning, stacklevel=3)


@dataclass
class FetchResult:
    uuid: _uuid.UUID
    label: int
    size: int
    payload: Optional[bytes]
    t_issued: float
    t_done: float
    conn_id: int
    hedged: bool = False
    # serving node (qualified "<cluster>/<node>" under a federation) — what
    # replica-hit accounting attributes a completion to, so a fetch routed
    # to a replica but diverted mid-flight is not reported as a saving
    node: Optional[str] = None
    # bytes this fetch put on the wire (== size unless a codec compressed
    # it) — what egress/ingress accounting and per-tenant billing must use
    wire_size: int = 0

    def __post_init__(self) -> None:
        if self.wire_size == 0:
            self.wire_size = self.size


class ConnectionPool:
    """All connections of one client process (one training host)."""

    def __init__(self, clock: Clock, cluster: Cluster, route: RouteProfile | str,
                 io_threads: int = 8, conns_per_thread: int = 2, seed: int = 99,
                 hedge_after: "Optional[float | str]" = None,
                 materialize: bool = False,
                 client_ingress_bandwidth: float = NIC_BANDWIDTH,
                 preferred_nodes: Optional[Iterable[str]] = None,
                 ingress: Optional[RateResource] = None,
                 on_exhausted: Optional[Callable] = None,
                 wire_codec: "str | WireCodec | None" = None,
                 io_scaling: bool = False,
                 codec: "str | WireCodec | None" = None) -> None:
        # ``wire_codec`` is the one spelling used across LoaderConfig /
        # MultiHostConfig / FederatedConnectionPool; ``codec=`` is the
        # pre-normalization name kept as a deprecated alias.
        if codec is not None:
            if wire_codec is not None:
                raise TypeError("pass wire_codec= only (codec= is its "
                                "deprecated alias)")
            _warn_codec_alias()
            wire_codec = codec
        if isinstance(route, str):
            route = TIERS[route]
        if isinstance(hedge_after, str) and hedge_after != "auto":
            raise ValueError(f"hedge_after must be a delay in seconds, None "
                             f"or 'auto', got {hedge_after!r}")
        self.clock = clock
        self.cluster = cluster
        self.route = route
        self.materialize = materialize
        self.hedge_after = hedge_after
        # Cluster-level failover hook (multi-cluster federation): called as
        # ``on_exhausted(key, on_done) -> bool`` once every connection of this
        # pool has failed for a request.  Returning True means another pool
        # (a replica cluster's) took the request over; False falls back to
        # the backoff-and-retry-here loop (single-cluster behaviour).
        self.on_exhausted = on_exhausted
        # Token-aware *placement* (see core/placement.py) skews this host's
        # keys toward replicas on its preferred nodes; biasing routing the
        # same way concentrates the host's egress there.  None = unbiased.
        self.preferred_nodes = (frozenset(preferred_nodes)
                                if preferred_nodes else None)
        self._rng = np.random.default_rng(seed)
        # Federation sub-pools share one ingress: a host has one NIC no
        # matter how many storage clusters it talks to.
        self.ingress = ingress or RateResource("client/ingress",
                                               client_ingress_bandwidth)
        n_conns = io_threads * conns_per_thread
        node_list = list(cluster.nodes.values())
        self.connections: List[SimConnection] = []
        self._conns_by_node: Dict[str, List[SimConnection]] = {n.name: [] for n in node_list}
        for cid in range(n_conns):
            node = node_list[cid % len(node_list)]
            conn = SimConnection(cid, clock, node, route,
                                 np.random.default_rng(seed + 1009 * cid), self.ingress)
            self.connections.append(conn)
            self._conns_by_node[node.name].append(conn)
        # Wire codec (core/wirefmt.py): rows travel encoded — the node pays
        # encode CPU, every wire stage carries the encoded byte count, and
        # the client pays decode CPU (the FIFO below models the io-threads'
        # decode workers: full single-core latency per fetch, 1/cores of
        # serialized time).  ``none`` keeps every code path bit-identical.
        self.codec = get_codec(wire_codec)
        self._codec_active = self.codec.name != "none"
        self._decode_cpu = FifoResource("client/decode")
        # Controller-driven io-scaling (carried-over ROADMAP item): when on,
        # routing concentrates on the first ceil(budget/32/n_nodes)
        # connections per node, so a shallow budget runs few warm streams
        # instead of spraying over all io_threads x 2 cold ones.
        self.io_scaling = io_scaling
        self._conn_rank: Dict[SimConnection, int] = {
            c: i for conns in self._conns_by_node.values()
            for i, c in enumerate(conns)}
        self.requests_sent = 0
        self.bytes_received = 0            # wire bytes (encoded)
        self.payload_bytes_received = 0    # decoded payload bytes
        self.decode_cpu_seconds = 0.0      # host decode core-seconds
        self.failovers = 0
        self.served_by_node: Dict[str, int] = {}
        # Adaptive flow control (core/flowctl.py): when attached, every
        # completion feeds an RTT + delivery-rate sample and every
        # failover/hedge a loss-style signal.  None = static prefetch depth.
        self.controller: Optional[FlowController] = None

    def attach_flow_control(self, cfg: FlowControlConfig, batch_size: int,
                            limiter: Optional[SharedIngressLimiter] = None
                            ) -> FlowController:
        """Create (once) and attach the BDP-tracking controller this pool
        feeds; returns the attached controller on repeat calls."""
        if self.controller is None:
            self.controller = FlowController(cfg, batch_size, self.clock,
                                             name=self.route.name,
                                             limiter=limiter)
        return self.controller

    def _hedge_delay(self) -> Optional[float]:
        """Hedge delay for a fetch issued now: the configured constant, or —
        ``"auto"`` — the controller's ``hedge_rtt_multiple x min_rtt``.
        None disables the hedge for this fetch: auto mode has no delay until
        a first RTT sample exists (hedging an unmeasured route is a guess)
        and suppresses hedging during a PROBE_RTT drain (slow completions
        are the drain working, not stragglers)."""
        h = self.hedge_after
        if h == "auto":
            if self.controller is None or self.controller.in_drain():
                return None
            return self.controller.hedge_after()
        return h

    def admit(self, key: _uuid.UUID) -> bool:
        """Per-route admission (``PrefetchConfig.route_admission``): may one
        more fetch be issued right now without pushing this route past its
        measured budget?  Advisory — the prefetcher defers, never drops, and
        force-issues when nothing is admissible.  Always true without a
        controller (static mode has no per-route budget to consult); the
        federated pool overrides this with the *serving member's* budget.
        When the controller sits behind a tenant scheduler
        (``core/tenancy.py``), the tenant's aggregate share is consulted
        too — an over-share tenant defers even if this one route still has
        budget (the base ``SharedIngressLimiter`` admits everything)."""
        if self.controller is None:
            return True
        if self.inflight >= self.controller.budget():
            return False
        limiter = self.controller.limiter
        return limiter.admit(self.controller) if limiter is not None else True

    # -- routing ---------------------------------------------------------
    def active_conns_per_node(self) -> Optional[int]:
        """Connections per node the io-scaler keeps in rotation right now
        (``None`` = no narrowing: io_scaling off or no controller yet)."""
        if not self.io_scaling or self.controller is None:
            return None
        total = self.controller.io_parallelism(len(self.connections))
        n_nodes = max(len(self._conns_by_node), 1)
        return max(1, -(-total // n_nodes))

    def _pick_connection(self, key: _uuid.UUID,
                         exclude: Iterable[SimConnection] = (),
                         rf: Optional[int] = None) -> SimConnection:
        """Token-aware: least-loaded connection to a *live* replica of
        ``key`` — biased toward this host's preferred nodes when a preferred
        replica is alive; falls back to any live node, then to anything at
        all (a totally dark cluster still gets a target, and the request
        fails).  ``rf`` widens the replica set beyond the cluster's own
        (hot-key replicas are fanned out across the region cluster, see
        core/replication.py)."""
        excluded = set(exclude)
        replicas = self.cluster.ring.replicas(key, rf or self.cluster.rf)
        candidates: List[SimConnection] = []
        for name in replicas:
            candidates.extend(self._conns_by_node.get(name, []))
        if not candidates:  # client holds no connection to a replica: any conn
            candidates = self.connections
        live = [c for c in candidates if not c.node_down and c not in excluded]
        # Controller-driven issue parallelism: restrict routing to each
        # node's active-prefix of connections sized from the flow budget
        # (few deep streams at shallow budgets; all of them at WAN depth).
        # Narrowing only ever filters the happy path — if it would empty
        # the candidate set (exclusions, down nodes) full coverage returns.
        m = self.active_conns_per_node()
        if m is not None and live:
            narrowed = [c for c in live if self._conn_rank[c] < m]
            if narrowed:
                live = narrowed
        # Bias only the *first* pick toward preferred nodes: hedge and
        # failover re-picks (exclusions present) must divert to another
        # replica, not back onto the same — possibly struggling — node.
        if self.preferred_nodes and live and not excluded:
            preferred = [c for c in live
                         if c.node_name in self.preferred_nodes]
            if preferred:
                live = preferred
        pool = (live
                or [c for c in self.connections
                    if not c.node_down and c not in excluded]
                or [c for c in candidates if c not in excluded]
                or candidates)
        return min(pool, key=lambda c: (c.inflight, c.conn_id))

    # -- fetch -------------------------------------------------------------
    def fetch(self, key: _uuid.UUID, on_done: Callable[[FetchResult], None],
              rf: Optional[int] = None) -> None:
        """Single-row read: features + label in one query (Sec. 3.1).

        A connection error (target node down) triggers failover: the request
        is re-sent on a connection to a different node.  Once every distinct
        connection has failed, retries continue after an RTT of backoff —
        so a cluster-wide outage surfaces as the caller's timeout, while a
        node that recovers mid-run is picked up automatically.  ``rf``
        widens the routable replica set (hot-key replica serving).
        """
        row = self.cluster.store.get_data(key)
        t0 = self.clock.now()
        state = {"done": False}

        # Wire-format accounting, decided once per fetch (hedged attempts
        # bill the same bytes): real payloads get really encoded — the wire
        # carries ``len(encode(payload))`` — while lazy (size-only) rows use
        # the codec's deterministic size model.  codec "none" leaves every
        # value on the legacy path (wire == size, zero CPU, no extra event).
        encoded: Optional[bytes] = None
        if self._codec_active:
            if row.payload is not None or self.materialize:
                encoded = self.codec.encode(row.payload if row.payload
                                            is not None else row.materialize())
                wire = len(encoded)
            else:
                wire = self.codec.encoded_size(row.size)
            enc_s = self.codec.encode_seconds(row.size)
            dec_s = self.codec.decode_seconds(row.size)
        else:
            wire = row.size
            enc_s = dec_s = 0.0

        def complete(conn: SimConnection, hedged: bool, t_done: float) -> None:
            if state["done"]:
                return  # a hedge lost the race
            state["done"] = True

            def deliver(t_ready: float) -> None:
                self.bytes_received += wire
                self.payload_bytes_received += row.size
                if self.controller is not None:
                    # The controller sees *wire* bytes: its byte-level fair
                    # caps and the tenant egress accounting stay truthful
                    # under compression, and the delivery-rate/BDP estimate
                    # (samples/s x RTT) budgets the effective gain.
                    self.controller.on_complete(t0, t_ready, wire)
                name = conn.node_name
                self.served_by_node[name] = (self.served_by_node.get(name, 0)
                                             + 1)
                if encoded is not None:
                    payload = self.codec.decode(encoded)
                elif self.materialize:
                    payload = row.materialize()
                else:
                    payload = row.payload
                on_done(FetchResult(uuid=key, label=row.label, size=row.size,
                                    payload=payload, t_issued=t0,
                                    t_done=t_ready, conn_id=conn.conn_id,
                                    hedged=hedged, node=name,
                                    wire_size=wire))

            if dec_s > 0.0:
                # Host-side decode: full single-core latency, 1/cores of
                # serialized FIFO time (io-threads double as decode
                # workers) — delivery (and the controller's RTT sample)
                # waits for the decoded bytes.
                self.decode_cpu_seconds += dec_s
                t_ready = max(self._decode_cpu.acquire(t_done,
                                                       dec_s / HOST_CODEC_CORES),
                              t_done + dec_s)
                self.clock.schedule(t_ready - t_done, deliver, t_ready)
            else:
                deliver(t_done)

        def attempt(conn: SimConnection, hedged: bool, tried: frozenset) -> None:
            self.requests_sent += 1

            def failed(_t: float) -> None:
                if state["done"]:
                    return  # the other (hedged) attempt already answered
                self.failovers += 1
                if self.controller is not None:
                    self.controller.on_failure()
                now_tried = tried | {conn}
                nxt = self._pick_connection(key, exclude=now_tried, rf=rf)
                if nxt in now_tried:
                    # no untried connection left for this key (e.g. the whole
                    # cluster is dark): a federated pool may divert the
                    # request to a replica cluster (cluster-level outage).
                    # Marking the fetch done stops the hedge timer and any
                    # late completion from double-counting it here.
                    if (self.on_exhausted is not None
                            and self.on_exhausted(key, on_done)):
                        state["done"] = True
                        return
                    # ...otherwise back off an RTT, start over
                    self.clock.schedule(
                        max(self.route.rtt, 1e-3),
                        lambda: state["done"] or attempt(
                            self._pick_connection(key, rf=rf), hedged,
                            frozenset()))
                    return
                attempt(nxt, hedged, now_tried)

            conn.request(row.size, lambda t: complete(conn, hedged, t), failed,
                         wire_bytes=wire if self._codec_active else None,
                         encode_seconds=enc_s)

        if self.controller is not None:
            self.controller.note_inflight(self.inflight)
        first = self._pick_connection(key, rf=rf)
        attempt(first, False, frozenset())

        hedge_delay = self._hedge_delay()
        if hedge_delay is not None:
            def maybe_hedge() -> None:
                if state["done"]:
                    return
                backup = self._pick_connection(key, exclude=(first,), rf=rf)
                if backup is first:
                    # no distinct connection to divert to (single-connection
                    # pool / everything else dark): nothing is sent, so no
                    # congestion signal either — feeding on_hedge here would
                    # AIMD-back-off the budget for a hedge that never
                    # happened.
                    return
                if self.controller is not None:
                    self.controller.on_hedge()
                attempt(backup, True, frozenset({first}))

            self.clock.schedule(hedge_delay, maybe_hedge)

    # -- introspection -------------------------------------------------------
    @property
    def inflight(self) -> int:
        return sum(c.inflight for c in self.connections)

    def throughput_traces(self, window: float = 0.5):
        return {c.conn_id: c.throughput_series(window) for c in self.connections}


__all__ = ["ConnectionPool", "FetchResult"]
