"""Top-level loader — the ``fn.crs4.cassandra(...)`` analogue (Listing 3).

One object wires together: a cluster (or a handle to a shared one), the
client connection pool, the epoch plan, and a prefetching strategy.  It is
the single public entry point used by the data pipeline, the benchmarks and
the examples.
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .arena import PinnedArena
from .batch_loader import BatchAssembler
from .cluster import Cluster
from .connection import ConnectionPool
from .flowctl import FlowControlConfig
from .kvstore import KVStore
from .netsim import Clock, RealClock, VirtualClock
from .prefetcher import EpochPlan, PrefetchConfig, make_prefetcher


@dataclass
class LoaderConfig:
    """Mirrors the plugin arguments of the paper's Listing 3 (+ sim knobs)."""

    batch_size: int = 512
    prefetch_buffers: int = 8
    io_threads: int = 8
    conns_per_thread: int = 2
    out_of_order: bool = True
    incremental_ramp: bool = True
    ramp_every: int = 4
    # route tier name (local | low | med | high) or a RouteProfile — e.g. a
    # schedule-carrying dynamic route from core/scenarios.py
    route: "str | object" = "high"
    backend: str = "scylla"         # scylla | cassandra
    n_nodes: int = 1
    replication_factor: int = 1
    # seconds, None, or "auto" (delay = controller min-RTT x
    # hedge_rtt_multiple; needs flow_control="adaptive")
    hedge_after: "Optional[float | str]" = None
    seed: int = 0
    shard_id: int = 0               # per-host / per-GPU shard of the UUID list
    num_shards: int = 1
    materialize: bool = False       # deliver real payload bytes
    virtual_clock: bool = True
    # Token-aware placement: bias routing toward these storage nodes (the
    # subset this host's shard keys were replica-skewed toward).  None keeps
    # the unbiased least-loaded-replica routing.
    preferred_nodes: Optional[Tuple[str, ...]] = None
    # "static" keeps the paper's fixed prefetch depth (default, bit-identical
    # to pre-flow-control behaviour); "adaptive" wires a BDP-tracking
    # FlowController (core/flowctl.py) between the pool and the prefetcher.
    flow_control: str = "static"
    flow: Optional[FlowControlConfig] = None
    # Per-key route admission in the prefetcher (see PrefetchConfig):
    # defer keys whose serving route is at its measured budget.
    route_admission: bool = False
    # Wire codec (core/wirefmt.py): rows travel encoded — the node pays
    # encode CPU, the wire carries fewer bytes, the client pays decode CPU.
    # "none" (default) is bit-identical to the pre-codec loader.
    wire_codec: str = "none"
    # Controller-driven issue parallelism (needs flow_control="adaptive"):
    # routing concentrates on a budget-sized active prefix of connections.
    io_scaling: bool = False
    # Pinned-arena batch assembly (materialize mode): decoded rows land in
    # reused contiguous slabs (core/arena.py) instead of per-sample bytes +
    # a fresh buffer per batch; the device feed uploads whole slabs.
    use_arena: bool = False
    arena_slot_bytes: Optional[int] = None   # None = max row size in shard


class CassandraLoader:
    """Iterable over AssembledBatch with checkpointable position."""

    def __init__(self, store: KVStore, uuids: List[_uuid.UUID],
                 cfg: LoaderConfig, clock: Optional[Clock] = None,
                 cluster: Optional[Cluster] = None,
                 plan: Optional[EpochPlan] = None,
                 pool=None, ingress=None, flow_limiter=None) -> None:
        self.cfg = cfg
        self.clock = clock or (VirtualClock() if cfg.virtual_clock else RealClock())
        self.cluster = cluster or Cluster(
            self.clock, store, backend=cfg.backend, n_nodes=cfg.n_nodes,
            rf=cfg.replication_factor, seed=cfg.seed + 5)
        # Pool randomness is decorrelated per shard (each host sees its own
        # network weather); the *plan* seed must stay shared across shards so
        # every host computes the same global shuffle.  An externally-built
        # pool (e.g. a FederatedConnectionPool spanning several clusters,
        # each with its own route) replaces the single-route default.
        # ``ingress`` shares one client NIC across co-located loaders
        # (multi-host shared_client_ingress); None keeps a private NIC.
        self.pool = pool or ConnectionPool(
            self.clock, self.cluster, cfg.route,
            io_threads=cfg.io_threads, conns_per_thread=cfg.conns_per_thread,
            seed=cfg.seed + 11 + 7919 * cfg.shard_id,
            hedge_after=cfg.hedge_after,
            materialize=cfg.materialize,
            preferred_nodes=cfg.preferred_nodes,
            ingress=ingress,
            wire_codec=cfg.wire_codec,
            io_scaling=cfg.io_scaling)
        # An externally-built plan (placement policies, elastic reflow)
        # overrides the default contiguous-strip sharding.
        self.plan = plan or EpochPlan(uuids, seed=cfg.seed,
                                      shard_id=cfg.shard_id,
                                      num_shards=cfg.num_shards)
        pcfg = PrefetchConfig(batch_size=cfg.batch_size,
                              num_buffers=cfg.prefetch_buffers,
                              out_of_order=cfg.out_of_order,
                              incremental_ramp=cfg.incremental_ramp,
                              ramp_every=cfg.ramp_every,
                              flow_control=cfg.flow_control,
                              flow=cfg.flow,
                              route_admission=cfg.route_admission)
        # Adaptive flow control: the pool measures (RTT + delivery rate per
        # completion), the controller budgets, the prefetcher obeys.  A pool
        # that already carries a controller (MultiHostRun's shared-ingress
        # fairness cap attaches one before building the loader) is reused.
        self.flow_controller = None
        if cfg.flow_control == "adaptive":
            self.flow_controller = (
                self.pool.controller
                or self.pool.attach_flow_control(cfg.flow or FlowControlConfig(),
                                                 cfg.batch_size,
                                                 limiter=flow_limiter))
        # Pinned-arena assembly: real copies land in reused contiguous slabs
        # sized for the largest row this shard can see; the device feed
        # uploads whole slabs (see data/pipeline.ImageFeed).
        self.arena = None
        assembler = None
        if cfg.use_arena and cfg.materialize:
            slot = cfg.arena_slot_bytes or max(
                (store.get_data(u).size for u in uuids), default=1)
            self.arena = PinnedArena(cfg.batch_size, slot, initial_slabs=2)
            assembler = BatchAssembler(self.clock, real_copy=True,
                                       arena=self.arena)
        self.prefetcher = make_prefetcher(self.clock, self.pool, self.plan, pcfg,
                                          real_copy=cfg.materialize,
                                          controller=self.flow_controller,
                                          assembler=assembler)

    # -- iteration ---------------------------------------------------------
    def start(self, epoch: int = 0, cursor: int = 0) -> "CassandraLoader":
        self.prefetcher.start(epoch, cursor)
        return self

    def next_batch(self, timeout: float = 600.0):
        return self.prefetcher.next_batch(timeout=timeout)

    def __iter__(self):
        while True:
            yield self.next_batch()

    @property
    def started(self) -> bool:
        """True once the prefetcher is running (public — consumers such as
        ``DeviceFeed`` must not reach into ``prefetcher._started``)."""
        return self.prefetcher.started

    @property
    def ready_batches(self) -> int:
        """Assembled batches ``next_batch`` would return without blocking."""
        return self.prefetcher.ready_batches

    # -- checkpointing ------------------------------------------------------
    def state(self, rewind_batches: int = 0) -> dict:
        """Checkpointable position; ``rewind_batches`` backs off batches a
        downstream buffer already pulled but the consumer never saw."""
        return self.prefetcher.state(rewind_batches=rewind_batches)

    def flow_snapshot(self) -> Optional[dict]:
        """Flow-controller state to ride a checkpoint (None in static mode) —
        a restore passes it back through :meth:`restore_flow` so adaptive
        runs resume at the measured operating point instead of
        re-slow-starting."""
        if self.flow_controller is None:
            return None
        return self.flow_controller.snapshot()

    def restore_flow(self, state: Optional[dict]) -> None:
        """Re-seed the flow controller from a checkpoint snapshot (no-op in
        static mode or when the checkpoint predates flow control)."""
        if self.flow_controller is not None and state:
            self.flow_controller.restore(state)

    @property
    def stats(self):
        return self.prefetcher.stats

    def batches_per_epoch(self) -> int:
        return len(self.plan) // self.cfg.batch_size

    def close(self) -> None:
        if isinstance(self.clock, RealClock):
            self.clock.close()


def tight_loop(loader: CassandraLoader, n_batches: int,
               timeout: float = 600.0) -> dict:
    """Paper Sec. 4.2.1: consume as fast as possible, no decode/GPU work."""
    loader.start()
    for _ in range(n_batches):
        loader.next_batch(timeout=timeout)
    st = loader.stats
    skip = max(0, min(2, n_batches - 2))   # short runs: never a negative slice
    return {
        "throughput_Bps": st.throughput(skip=skip),
        "batches": n_batches,
        "batch_times": st.batch_times(skip=1),
        "disk_bytes": loader.cluster.total_disk_bytes(),
        "net_bytes": loader.pool.bytes_received,          # wire (encoded)
        "payload_bytes": loader.pool.payload_bytes_received,
    }


def consume_with_step_time(loader: CassandraLoader, n_batches: int,
                           step_time: float, timeout: float = 600.0) -> dict:
    """Training-consumer model: one fixed-cost step per batch (Sec. 4.2.2)."""
    loader.start()
    for _ in range(n_batches):
        loader.next_batch(timeout=timeout)
        loader.clock.sleep(step_time)
    st = loader.stats
    skip = max(0, min(2, n_batches - 2))   # short runs: never a negative slice
    return {
        "samples_per_s": st.samples_per_second(loader.cfg.batch_size,
                                               skip=skip),
        "batch_times": st.batch_times(skip=1),
    }


__all__ = ["LoaderConfig", "CassandraLoader", "tight_loop",
           "consume_with_step_time"]
