"""Pluggable on-the-wire row codecs (ROADMAP: wire-format hot path).

Rows travel the simulated network *encoded*: the storage node spends CPU to
encode (charged to ``SimServerNode.cpu`` on the virtual clock), every wire
stage (node egress FIFO, AIMD transfer, client-ingress NIC) carries the
encoded byte count, and the client spends CPU to decode before delivery
(charged via ``ConnectionPool``'s host-decode resource).  The flow
controller is fed *wire* bytes, so its delivery-rate/BDP estimates — and
the ``SharedIngressLimiter`` / per-tenant egress accounting — see the
route's effective bandwidth gain, while ``LoaderStats`` keeps reporting
decoded (payload) bytes.  That split is what makes compression a real,
measurable CPU-vs-bandwidth knob per route: a 150 ms WAN route buys
throughput with cheap CPU; a local route mostly buys queueing.

Codecs:

* ``none``        — identity.  Zero cost, zero extra simulator events:
  byte accounting stays bit-identical to the pre-codec loader (asserted by
  ``bench_wirefmt``).
* ``byteshuffle`` — lz4-style lossless filter: a byte transpose (stride
  swept per payload — 4 groups the high bytes of int32/float32 streams
  into long runs, 3 de-interleaves RGB uint8 frames into channel planes)
  followed by run-length encoding, with a store-raw escape when encoding
  would expand.  Mirrors the shuffle+LZ blocks of Blosc/LZ4 at simulator
  speed.
* ``int8``        — lossy block quantization of float32 payloads, the
  numpy mirror of ``train/compression.py``'s ``quantize_int8`` idiom
  (per-block amax scale, round, clip to ±127).  Bounded error:
  ``|x - decode(encode(x))| <= amax_block / 127`` per element.  Non-float
  payloads (length not a multiple of 4) take the store-raw escape.

For *lazy* rows (size-only benchmark datasets, no real bytes) each codec
also provides a deterministic ``encoded_size`` model calibrated against its
real encoder on synthetic image-like entropy, so virtual-clock benchmarks
bill the same ratios the real path would.
"""

from __future__ import annotations

import struct
from typing import Dict, Optional

import numpy as np

# Wire frame header: magic, codec id, flags, raw length.
_MAGIC = b"WF"
_HEADER = struct.Struct("<2sBBI")          # magic, codec_id, flags, raw_len
_FLAG_RAW = 0x01                           # store-raw escape (no transform)

# Node-side encode parallelism: a storage node encodes on this many cores
# (Scylla-style shard-per-core, a slice of the node reserved for the codec).
# One request's encode still runs on ONE core — serve() charges the full
# single-core latency but only 1/cores of serialized FIFO time — so encode
# adds latency everywhere but only caps throughput at cores x rate.
NODE_CODEC_CORES = 5
# Client-side decode parallelism (the io-threads double as decode workers).
HOST_CODEC_CORES = 8


class WireCodec:
    """One wire format: real encode/decode + deterministic cost models."""

    name = "abstract"
    codec_id = 0xFF
    lossless = True
    # Modelled compressed fraction for lazy (size-only) rows.
    model_ratio = 1.0
    # Single-core throughputs, bytes of *raw* payload per second.
    encode_Bps: Optional[float] = None     # None = free (codec "none")
    decode_Bps: Optional[float] = None

    # -- real path ---------------------------------------------------------
    def encode(self, raw: bytes) -> bytes:
        raise NotImplementedError

    def decode(self, blob: bytes) -> bytes:
        raise NotImplementedError

    # -- models (lazy rows / virtual clock) --------------------------------
    def encoded_size(self, raw_len: int) -> int:
        """Deterministic wire size for a lazy row of ``raw_len`` bytes."""
        return max(int(raw_len * self.model_ratio), _HEADER.size + 1)

    def encode_seconds(self, raw_len: int) -> float:
        """Single-core node CPU seconds to encode ``raw_len`` raw bytes."""
        return 0.0 if self.encode_Bps is None else raw_len / self.encode_Bps

    def decode_seconds(self, raw_len: int) -> float:
        """Single-core host CPU seconds to decode back ``raw_len`` bytes."""
        return 0.0 if self.decode_Bps is None else raw_len / self.decode_Bps

    # -- frame helpers -----------------------------------------------------
    def _frame(self, flags: int, raw_len: int, body: bytes) -> bytes:
        return _HEADER.pack(_MAGIC, self.codec_id, flags, raw_len) + body

    def _unframe(self, blob: bytes):
        magic, codec_id, flags, raw_len = _HEADER.unpack_from(blob)
        if magic != _MAGIC or codec_id != self.codec_id:
            raise ValueError(f"not a {self.name} wire frame")
        return flags, raw_len, blob[_HEADER.size:]


class NoneCodec(WireCodec):
    """Identity codec: the pre-codec wire format, bit for bit."""

    name = "none"
    codec_id = 0
    model_ratio = 1.0

    def encode(self, raw: bytes) -> bytes:
        return raw

    def decode(self, blob: bytes) -> bytes:
        return blob

    def encoded_size(self, raw_len: int) -> int:
        return raw_len


# -- byteshuffle helpers -----------------------------------------------------

# Candidate shuffle strides: the transpose only creates runs when the stride
# matches the data's element period — 4 for int32/float32 streams, 3 for
# interleaved RGB uint8, 2 for int16, 1 for already-flat byte planes.  The
# encoder sweeps these and records the winner in the frame's flags byte.
_SHUFFLE_STRIDES = (1, 2, 3, 4, 8)


def _shuffle(x: np.ndarray, stride: int) -> np.ndarray:
    pad = (-x.size) % stride
    if pad:
        x = np.concatenate((x, np.zeros(pad, dtype=np.uint8)))
    return x.reshape(-1, stride).T.ravel()


def _rle_encode(x: np.ndarray) -> bytes:
    """Run-length encode a uint8 vector as (len<=255, value) pairs."""
    if x.size == 0:
        return b""
    change = np.flatnonzero(x[1:] != x[:-1])
    starts = np.concatenate(([0], change + 1))
    lengths = np.diff(np.concatenate((starts, [x.size])))
    vals = x[starts]
    reps = (lengths + 254) // 255          # chunks per run (runs may be >255)
    out_vals = np.repeat(vals, reps)
    out_lens = np.full(out_vals.size, 255, dtype=np.int64)
    out_lens[np.cumsum(reps) - 1] = lengths - (reps - 1) * 255
    pairs = np.empty((out_vals.size, 2), dtype=np.uint8)
    pairs[:, 0] = out_lens
    pairs[:, 1] = out_vals
    return pairs.tobytes()


def _rle_decode(blob: bytes, n: int) -> np.ndarray:
    pairs = np.frombuffer(blob, dtype=np.uint8).reshape(-1, 2)
    out = np.repeat(pairs[:, 1], pairs[:, 0])
    if out.size < n:
        raise ValueError("truncated RLE stream")
    return out[:n]


class ByteShuffleCodec(WireCodec):
    """Lossless byte shuffle + RLE (lz4-style, with raw escape).

    The encoder sweeps ``_SHUFFLE_STRIDES`` and keeps the shortest body —
    stride 4 wins on int32/float32 streams, stride 3 on interleaved RGB
    uint8 frames — storing the winning stride in the frame's flags byte
    (``flags >> 1``; bit 0 stays the raw escape).  The sweep is a few extra
    vectorized passes, inside the modelled lz4-class encode rate.
    """

    name = "byteshuffle"
    codec_id = 1
    lossless = True
    # Calibrated on DataRow.materialize()'s uint64-seeded payloads restricted
    # to image-like low-entropy lanes; see tests/test_wirefmt.py.
    model_ratio = 0.55
    encode_Bps = 1.2e9                     # lz4-class compress, one core
    decode_Bps = 2.4e9                     # decompress is ~2x faster

    def encode(self, raw: bytes) -> bytes:
        n = len(raw)
        x = np.frombuffer(raw, dtype=np.uint8)
        best_body, best_stride = None, 0
        for stride in _SHUFFLE_STRIDES:
            body = _rle_encode(_shuffle(x, stride))
            if best_body is None or len(body) < len(best_body):
                best_body, best_stride = body, stride
        if len(best_body) >= n:            # incompressible: store raw
            return self._frame(_FLAG_RAW, n, raw)
        return self._frame(best_stride << 1, n, best_body)

    def decode(self, blob: bytes) -> bytes:
        flags, raw_len, body = self._unframe(blob)
        if flags & _FLAG_RAW:
            return bytes(body[:raw_len])
        stride = flags >> 1
        if stride not in _SHUFFLE_STRIDES:
            raise ValueError(f"corrupt byteshuffle frame: stride {stride}")
        padded = raw_len + ((-raw_len) % stride)
        shuffled = _rle_decode(body, padded)
        x = shuffled.reshape(stride, -1).T.ravel()
        return x[:raw_len].tobytes()


class Int8QuantCodec(WireCodec):
    """Lossy per-block int8 quantization of float32 payloads.

    The numpy mirror of ``train.compression.quantize_int8``: per ``BLOCK``
    floats, ``scale = max(amax, 1e-12)/127``; values round+clip to int8.
    Wire layout: frame header, float count, per-block f32 scales, int8 data
    — ~0.26x the raw bytes.  Payloads whose length is not a multiple of 4
    (not a float stream) are stored raw.
    """

    name = "int8"
    codec_id = 2
    lossless = False
    BLOCK = 1024
    # 1/4 data + 4/BLOCK scales + header slack.
    model_ratio = 0.26
    encode_Bps = 2.0e9                     # one vectorized pass, one core
    decode_Bps = 2.0e9

    def encode(self, raw: bytes) -> bytes:
        n = len(raw)
        if n % 4 != 0 or n == 0:
            return self._frame(_FLAG_RAW, n, raw)
        x = np.frombuffer(raw, dtype="<f4")
        if not np.all(np.isfinite(x)):     # not a float stream after all
            return self._frame(_FLAG_RAW, n, raw)
        nfloat = x.size
        pad = (-nfloat) % self.BLOCK
        xp = np.concatenate((x, np.zeros(pad, dtype="<f4"))) if pad else x
        blocks = xp.reshape(-1, self.BLOCK)
        amax = np.abs(blocks).max(axis=1, keepdims=True)
        scale = np.maximum(amax, 1e-12) / 127.0
        q = np.clip(np.round(blocks / scale), -127, 127).astype(np.int8)
        body = (struct.pack("<I", nfloat)
                + scale.astype("<f4").tobytes()
                + q.tobytes()[:nfloat])    # drop pad-element bytes
        if len(body) >= n:
            return self._frame(_FLAG_RAW, n, raw)
        return self._frame(0, n, body)

    def decode(self, blob: bytes) -> bytes:
        flags, raw_len, body = self._unframe(blob)
        if flags & _FLAG_RAW:
            return bytes(body[:raw_len])
        (nfloat,) = struct.unpack_from("<I", body)
        nblocks = (nfloat + self.BLOCK - 1) // self.BLOCK
        off = 4
        scale = np.frombuffer(body, dtype="<f4", count=nblocks, offset=off)
        off += 4 * nblocks
        q = np.frombuffer(body, dtype=np.int8, count=nfloat, offset=off)
        pad = nblocks * self.BLOCK - nfloat
        qp = (np.concatenate((q, np.zeros(pad, dtype=np.int8))) if pad
              else q)
        x = qp.reshape(-1, self.BLOCK).astype(np.float32) * scale[:, None]
        return x.ravel()[:nfloat].astype("<f4").tobytes()


_CODECS: Dict[str, WireCodec] = {}


def register_codec(codec: WireCodec) -> WireCodec:
    _CODECS[codec.name] = codec
    return codec


NONE = register_codec(NoneCodec())
BYTESHUFFLE = register_codec(ByteShuffleCodec())
INT8 = register_codec(Int8QuantCodec())

WIRE_CODECS = tuple(_CODECS)


def get_codec(name: "str | WireCodec | None") -> WireCodec:
    """Resolve a codec by name (None -> the identity codec)."""
    if name is None:
        return NONE
    if isinstance(name, WireCodec):
        return name
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(f"unknown wire codec {name!r} "
                         f"(choose from {WIRE_CODECS})") from None


__all__ = ["WireCodec", "NoneCodec", "ByteShuffleCodec", "Int8QuantCodec",
           "get_codec", "register_codec", "WIRE_CODECS",
           "NODE_CODEC_CORES", "HOST_CODEC_CORES", "NONE", "BYTESHUFFLE",
           "INT8"]
