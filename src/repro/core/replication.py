"""Hot-key cross-cluster replication: runtime data-placement for skew.

The federation layer (``core/federation.py``) serves every key from its
*home* cluster — the one the weighted ownership map assigns it to.  Under the
uniform per-epoch sampling of ordinary training that is fine: load spreads
over every member in proportion to its weight.  Under a *skewed* access
distribution (feature-store reads, curriculum re-sampling, preemptible
multi-tenant consumers replaying hot shards — the non-uniform workloads the
loader-landscape survey shows collapsing throughput) a handful of hot keys
pin their home cluster's replica nodes and, when that home sits behind the
intercontinental route, the WAN becomes the whole run's bottleneck.  This
module is the repo's first layer that *mutates placement at runtime*:

``HotKeyTracker``
    Space-saving top-k counters (Metwally et al.) over the access stream —
    memory stays O(k) no matter how many distinct keys flow past — each
    tracked key carrying windowed access counts aggregated through the
    shared :func:`repro.core.stats.windowed_series` helper, so "hot" means a
    *recent rate*, not an all-time count, and keys cool off when the skew
    moves.

``ReplicaCache``
    The set of keys currently replicated off their home cluster, with the
    member cluster each replica lives on and the key *version* it was copied
    at.  Entries go live only when the promotion copy lands
    (``begin_promotion`` / ``commit_promotion``); write-through invalidation
    (``FederatedCluster.write_through``) drops them, and a version check at
    serve time blocks the race where a read starts between a write and its
    invalidation — a replica never serves a stale version (property-tested
    across cluster-outage injection in ``tests/test_replication.py``).

``Replication``
    The bundle a ``FederatedCluster`` attaches: one tracker + one cache +
    promotion accounting, shared by every host's
    ``FederatedConnectionPool`` (hotness is a property of the workload, not
    of one host).  Snapshots ride the multi-host checkpoint and restore
    across elastic N->M resizes unchanged — the cache is cluster-side state,
    independent of the host count.

``ZipfPlan``
    The skewed-access workload class itself: a drop-in ``EpochPlan``
    duck-type whose per-epoch "permutation" is a seeded Zipf(s) sample
    *with replacement* over the global key list, identical ranks on every
    host (hot keys are globally hot).  Exactly-once per epoch deliberately
    does NOT hold for this plan — sampling with replacement is the point —
    so elastic restores of a Zipf run resume at the epoch boundary without
    reflow (there is no delivery set to preserve).  Epoch length matches the
    host's uniform strip so lockstep batch accounting is unchanged.

Ownership *rebalancing* — the other half of runtime placement — lives on
``FederatedRing.rebalance`` (``core/federation.py``), fed by the flow
controllers' spare bandwidth-delay product (``core/flowctl.py``): clusters
whose measured budget exceeds their measured in-flight load have WAN
headroom, and the ring shifts weighted ownership toward them while staying a
deterministic, checkpoint-serializable map.
"""

from __future__ import annotations

import math
import uuid as _uuid
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from .placement import global_order, strip_bounds
from .stats import windowed_series


@dataclass(frozen=True)
class ReplicationConfig:
    """Knobs of hot-key promotion (defaults sized for benchmark scale)."""

    track_k: int = 128          # space-saving counters: memory is O(track_k)
    window: float = 2.0         # access-rate horizon, seconds
    hot_rate: float = 4.0       # accesses/s over a window bucket => hot
    min_count: int = 8          # total observed accesses before promotion
    capacity: int = 512         # max keys replicated at once (LRU eviction)
    # Serving fan-out on the target cluster: a hot key is cached on this
    # many of the region cluster's nodes (0 = all of them), so its traffic
    # spreads instead of re-concentrating on an rf-sized replica set — the
    # point of promoting is that a handful of keys saturating two nodes'
    # NICs becomes k keys spread over the whole region cluster.
    replica_rf: int = 0
    # Hotset-shift demotion: a live replica whose key has cooled below the
    # hot threshold AND not served a read for this many seconds is dropped
    # (``ReplicaCache.demote_cold``), freeing capacity for the keys the
    # workload moved on to — instead of waiting for LRU eviction pressure,
    # which only fires once the cache is full.  None = never demote.
    demote_after: Optional[float] = None

    def __post_init__(self) -> None:
        if self.replica_rf < 0:
            raise ValueError(f"replica_rf must be >= 0, "
                             f"got {self.replica_rf}")
        if self.demote_after is not None and self.demote_after <= 0.0:
            raise ValueError(f"demote_after must be positive, "
                             f"got {self.demote_after}")
        if self.track_k < 1:
            raise ValueError(f"track_k must be >= 1, got {self.track_k}")
        if self.window <= 0.0:
            raise ValueError(f"window must be positive, got {self.window}")
        if self.hot_rate <= 0.0:
            raise ValueError(f"hot_rate must be positive, "
                             f"got {self.hot_rate}")
        if self.min_count < 1:
            raise ValueError(f"min_count must be >= 1, got {self.min_count}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


class _KeyStat:
    """One space-saving counter: total count, over-estimate error, and
    bucketed access timestamps for the windowed rate."""

    __slots__ = ("count", "error", "buckets")

    def __init__(self, count: int, error: int) -> None:
        self.count = count
        self.error = error
        # [bucket_start, accesses] aggregates, newest last — the same
        # bounded-deque shape the flow controller's rate filter uses.
        self.buckets: Deque[List[float]] = deque()


class HotKeyTracker:
    """Windowed top-k access tracker with O(k) memory.

    Space-saving semantics: a tracked key's count only grows; an untracked
    key evicts the minimum counter and inherits its count as ``error`` (the
    classic over-estimate bound).  Hotness is judged on the *windowed* rate —
    the max bucket of :func:`repro.core.stats.windowed_series` over the last
    ``cfg.window`` seconds — so a key that was hot an epoch ago and went
    quiet stops qualifying.
    """

    def __init__(self, cfg: ReplicationConfig, clock) -> None:
        self.cfg = cfg
        self._clock = clock
        self._stats: Dict[_uuid.UUID, _KeyStat] = {}
        self._bucket_width = cfg.window / 4.0
        self.recorded = 0

    def __len__(self) -> int:
        return len(self._stats)

    # -- intake -------------------------------------------------------------
    def record(self, key: _uuid.UUID) -> None:
        self.recorded += 1
        now = self._clock.now()
        st = self._stats.get(key)
        if st is None:
            if len(self._stats) < self.cfg.track_k:
                st = _KeyStat(count=1, error=0)
            else:
                # evict the minimum counter (deterministic tie-break on the
                # key's int — no per-entry string allocation: under a skewed
                # workload most accesses are cold-tail misses, so this scan
                # runs per fetch); the newcomer inherits its count + 1
                victim = min(self._stats,
                             key=lambda k: (self._stats[k].count, k.int))
                floor = self._stats.pop(victim).count
                st = _KeyStat(count=floor + 1, error=floor)
            self._stats[key] = st
        else:
            st.count += 1
        w = self._bucket_width
        b = math.floor(now / w) * w
        if st.buckets and st.buckets[-1][0] == b:
            st.buckets[-1][1] += 1.0
        else:
            st.buckets.append([b, 1.0])
            horizon = b - self.cfg.window
            while st.buckets[0][0] < horizon:
                st.buckets.popleft()

    # -- queries ------------------------------------------------------------
    def rate(self, key: _uuid.UUID) -> float:
        """Peak windowed access rate (accesses/s) over the horizon."""
        st = self._stats.get(key)
        if st is None:
            return 0.0
        now = self._clock.now()
        events = [(t, n) for t, n in st.buckets
                  if t >= now - self.cfg.window]
        if not events:
            return 0.0
        series = windowed_series(events, self._bucket_width,
                                 start=events[0][0])
        return max(r for _, r in series)

    def is_hot(self, key: _uuid.UUID) -> bool:
        st = self._stats.get(key)
        if st is None or st.count - st.error < self.cfg.min_count:
            return False
        return self.rate(key) >= self.cfg.hot_rate

    def top(self, n: int = 10) -> List[Tuple[_uuid.UUID, int, float]]:
        """(key, count, windowed rate), hottest first — report material."""
        ranked = sorted(self._stats,
                        key=lambda k: (-self._stats[k].count, str(k)))
        return [(k, self._stats[k].count, self.rate(k)) for k in ranked[:n]]

    # -- checkpoint ---------------------------------------------------------
    def snapshot(self) -> Dict[str, int]:
        return {str(k): st.count for k, st in self._stats.items()}

    def restore(self, state: Optional[Dict[str, int]]) -> None:
        """Re-seed the counts (rates restart cold: windowed buckets are
        meaningless across a restore's time discontinuity)."""
        if not state:
            return
        for k, count in state.items():
            key = _uuid.UUID(k)
            st = self._stats.get(key)
            if st is None:
                self._stats[key] = _KeyStat(count=int(count), error=0)
            else:
                st.count = max(st.count, int(count))
        # keep the space-saving bound across merged snapshots
        while len(self._stats) > self.cfg.track_k:
            victim = min(self._stats,
                         key=lambda k: (self._stats[k].count, k.int))
            del self._stats[victim]


@dataclass
class ReplicaEntry:
    """One replicated key: where its copy lives and what version it holds."""

    cluster: str
    version: int
    live: bool = False          # False while the promotion copy is in flight
    token: int = 0              # reservation id: stale copy callbacks no-op
    last_hit: float = 0.0
    hits: int = 0


class ReplicaCache:
    """Keys currently replicated off their home cluster (capacity-bounded).

    The cache is *routing* state: an entry says "cluster X holds a copy of
    key U at version V".  Serving checks the version against the keyspace's
    current one, so an invalidation lost to a race still cannot produce a
    stale read — the entry is dropped and the fetch falls through to the
    home cluster.
    """

    def __init__(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: Dict[_uuid.UUID, ReplicaEntry] = {}
        self.hits = 0
        self.misses = 0
        self.stale_blocked = 0
        self.promotions = 0         # copies committed (entry went live)
        self.invalidations = 0
        self.evictions = 0
        self.demotions = 0          # live entries dropped on hotset shift
        self._next_token = 1

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: _uuid.UUID) -> Optional[ReplicaEntry]:
        return self._entries.get(key)

    def keys(self) -> List[_uuid.UUID]:
        return list(self._entries.keys())

    # -- serving ------------------------------------------------------------
    def serving_cluster(self, key: _uuid.UUID, version: int, now: float,
                        usable=None) -> Optional[str]:
        """Cluster holding a *live, current-version* replica of ``key``, or
        None.  A version mismatch (write raced the read) blocks the entry
        and drops it — never a stale read.  ``usable(cluster) -> bool``
        lets the caller veto an unreachable replica cluster (outage)
        without consuming a hit or refreshing the entry's LRU recency —
        the entry itself survives, still valid for when the cluster
        returns."""
        e = self._entries.get(key)
        if e is None or not e.live:
            self.misses += 1
            return None
        if e.version != version:
            self.stale_blocked += 1
            del self._entries[key]
            return None
        if usable is not None and not usable(e.cluster):
            self.misses += 1
            return None
        e.last_hit = now
        e.hits += 1
        self.hits += 1
        return e.cluster

    # -- promotion lifecycle -------------------------------------------------
    def begin_promotion(self, key: _uuid.UUID, cluster: str, version: int,
                        now: float) -> Optional[int]:
        """Reserve an entry for ``key`` (copy in flight): returns the
        reservation token the copy's completion must present, or None when
        the key is already cached/promoting or no live entry can be
        evicted.  The token makes a copy whose reservation was invalidated
        and re-issued mid-flight unable to commit (or release) the newer
        reservation."""
        if key in self._entries:
            return None
        if len(self._entries) >= self.capacity:
            live = [k for k, e in self._entries.items() if e.live]
            if not live:
                return None             # everything in flight: back off
            coldest = min(live, key=lambda k: (self._entries[k].last_hit,
                                               str(k)))
            del self._entries[coldest]
            self.evictions += 1
        token = self._next_token
        self._next_token += 1
        self._entries[key] = ReplicaEntry(cluster=cluster, version=version,
                                          token=token, last_hit=now)
        return token

    def commit_promotion(self, key: _uuid.UUID, token: int) -> None:
        """The copy landed: the entry starts serving.  A no-op when the
        reservation was invalidated (or evicted and re-issued) while the
        copy was in flight."""
        e = self._entries.get(key)
        if e is not None and not e.live and e.token == token:
            e.live = True
            self.promotions += 1

    def release(self, key: _uuid.UUID, token: int) -> None:
        """Abort a reservation (promotion copy failed); token-guarded like
        :meth:`commit_promotion`."""
        e = self._entries.get(key)
        if e is not None and not e.live and e.token == token:
            del self._entries[key]

    def invalidate(self, key: _uuid.UUID) -> bool:
        """Write-through hook: drop the replica (live or in-flight)."""
        if key in self._entries:
            del self._entries[key]
            self.invalidations += 1
            return True
        return False

    def demote_cold(self, now: float, is_hot, demote_after: float) -> int:
        """Drop live replicas the hotset has moved away from: entries whose
        key is no longer hot (``is_hot(key)`` — the tracker's windowed
        judgment) and whose last served read is older than ``demote_after``.
        In-flight promotions are never touched (their commit callback still
        owns the reservation token).  Dropping an entry is always safe for
        consistency — the next access just falls through to the home
        cluster — so demotion can only reclaim capacity, never introduce a
        stale read.  Returns the number demoted."""
        cold = [k for k, e in self._entries.items()
                if e.live and now - e.last_hit >= demote_after
                and not is_hot(k)]
        for k in cold:
            del self._entries[k]
        self.demotions += len(cold)
        return len(cold)

    # -- checkpoint ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        """Live entries only — an in-flight copy does not survive a restore
        (its completion callback dies with the old simulator)."""
        return {str(k): {"cluster": e.cluster, "version": e.version,
                         "hits": e.hits}
                for k, e in self._entries.items() if e.live}

    def restore(self, state: Optional[Dict[str, Dict]]) -> None:
        if not state:
            return
        for k, e in state.items():
            if len(self._entries) >= self.capacity:
                break
            self._entries[_uuid.UUID(k)] = ReplicaEntry(
                cluster=e["cluster"], version=int(e["version"]), live=True,
                hits=int(e.get("hits", 0)))


class Replication:
    """Tracker + cache + promotion accounting for one federation.

    Attached via ``FederatedCluster.attach_replication`` and shared by every
    host's pool: accesses aggregate across hosts (a key is hot because the
    *workload* hammers it) and a promotion by one host serves them all.
    """

    def __init__(self, cfg: ReplicationConfig, clock) -> None:
        self.cfg = cfg
        self.tracker = HotKeyTracker(cfg, clock)
        self.cache = ReplicaCache(cfg.capacity)
        self.promotion_wan_bytes = 0    # copy traffic (the cost of promotion)
        self.promotions_aborted = 0     # home cluster dark mid-copy

    def demote_cold(self, now: float) -> int:
        """Demote replicas the tracked hotset has shifted away from (no-op
        unless ``cfg.demote_after`` is set).  Called on the multi-host run's
        round cadence; any caller with a clock may invoke it directly."""
        if self.cfg.demote_after is None:
            return 0
        return self.cache.demote_cold(now, self.tracker.is_hot,
                                      self.cfg.demote_after)

    def report(self) -> Dict:
        c = self.cache
        return {
            "cached_keys": len(c),
            "tracked_keys": len(self.tracker),
            "hits": c.hits,
            "misses": c.misses,
            "stale_blocked": c.stale_blocked,
            "promotions": c.promotions,
            "promotions_aborted": self.promotions_aborted,
            "invalidations": c.invalidations,
            "evictions": c.evictions,
            "demotions": c.demotions,
            "promotion_wan_bytes": self.promotion_wan_bytes,
        }

    def snapshot(self) -> Dict:
        return {"tracker": self.tracker.snapshot(),
                "cache": self.cache.snapshot()}

    def restore(self, state: Optional[Dict]) -> None:
        if not state:
            return
        self.tracker.restore(state.get("tracker"))
        self.cache.restore(state.get("cache"))


class ZipfPlan:
    """Skewed-access plan: Zipf(s) sampling with replacement, EpochPlan
    duck-type.

    Rank r (0-based) of the seeded global shuffle gets probability
    proportional to ``1/(r+1)**s`` — every host uses the *same* rank->key
    map, seeded by the seed ALONE (not ``(seed, num_shards)`` like the
    uniform strips): the skew must survive an elastic N->M resize, so hot
    keys stay the same keys and a restored replica cache keeps serving
    them.  Each shard draws its own sample stream over that shared map.
    ``epoch_length`` equals the host's uniform strip size, keeping lockstep
    round/batch accounting identical to the uniform plans.

    Exactly-once per epoch does NOT hold here (with-replacement sampling is
    the workload).  Consequently elastic restores resume at an epoch
    boundary without reflow, and per-epoch overrides are rejected.

    ``shift_every`` models a *moving* hotset (curriculum phases, tenant
    churn): every ``shift_every`` epochs the rank->key map rotates by a
    fixed stride larger than any tracked top-k, so the previous hot keys go
    cold and a disjoint set becomes hot — the workload that exercises
    replica demotion (``ReplicaCache.demote_cold``).  The rotation is a
    pure function of ``(seed, epoch)``, so it is deterministic, identical
    on every host, and survives elastic resizes like the base map does.
    """

    def __init__(self, uuids: List[_uuid.UUID], seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1,
                 s: float = 1.05, shift_every: Optional[int] = None) -> None:
        if num_shards < 1 or not 0 <= shard_id < num_shards:
            raise ValueError(f"bad shard spec {shard_id}/{num_shards}")
        if s <= 0.0:
            raise ValueError(f"zipf exponent must be positive, got {s}")
        if not uuids:
            raise ValueError("ZipfPlan needs a non-empty dataset")
        if shift_every is not None and shift_every < 1:
            raise ValueError(f"shift_every must be >= 1, got {shift_every}")
        self._uuids = global_order(uuids, seed, 1)   # resize-invariant map
        self._seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.s = s
        self.shift_every = shift_every
        # golden-ratio-conjugate stride: consecutive rotations land far
        # apart, so hotsets stay disjoint for many shifts before wrapping
        self._shift_stride = max(1, int(round(len(self._uuids) * 0.381966)))
        lo, hi = strip_bounds(len(uuids), num_shards)[shard_id]
        self._epoch_len = hi - lo
        if self._epoch_len == 0:
            raise ValueError("ZipfPlan shard is empty — more shards than "
                             "samples")
        ranks = np.arange(1, len(self._uuids) + 1, dtype=np.float64)
        p = ranks ** -s
        self._p = p / p.sum()

    def __len__(self) -> int:
        return self._epoch_len

    def epoch_length(self, epoch: int) -> int:
        return self._epoch_len

    # -- EpochPlan surface ---------------------------------------------------
    def permutation(self, epoch: int) -> List[_uuid.UUID]:
        rng = np.random.default_rng((self._seed, self.shard_id, epoch))
        idx = rng.choice(len(self._uuids), size=self._epoch_len, p=self._p)
        if self.shift_every:
            n = len(self._uuids)
            offset = (epoch // self.shift_every) * self._shift_stride % n
            if offset:
                return [self._uuids[(i + offset) % n] for i in idx]
        return [self._uuids[i] for i in idx]

    def iter_from(self, epoch: int, cursor: int):
        e = epoch
        while True:
            perm = self.permutation(e)
            for i in range(cursor, len(perm)):
                yield e, perm[i]
            cursor = 0
            e += 1

    def advance(self, epoch: int, cursor: int, n_samples: int = 0) -> tuple:
        if cursor < 0:
            raise ValueError(f"negative cursor {cursor}")
        c = cursor + n_samples
        return epoch + c // self._epoch_len, c % self._epoch_len

    def install_overrides(self, overrides: Dict) -> None:
        raise ValueError("Zipf plans sample with replacement — there is no "
                         "exactly-once delivery set to reflow, so per-epoch "
                         "overrides are meaningless here")

    def pending_overrides(self, from_epoch: int) -> Dict:
        return {}


SAMPLING_MODES = ("uniform", "zipf")

__all__ = ["ReplicationConfig", "HotKeyTracker", "ReplicaCache",
           "ReplicaEntry", "Replication", "ZipfPlan", "SAMPLING_MODES"]
