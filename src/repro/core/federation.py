"""Multi-cluster federation: one training run spanning several storage
clusters over heterogeneous WAN routes.

The paper's headline result is sustaining training throughput when the image
store sits behind a high-latency route (local vs medium vs intercontinental,
Sec. 4.2).  This module models the next step on that axis: a *single* run
whose dataset is spread across N storage clusters — each with its own token
ring, node set, replication factor and WAN route — so data can live in the
region where it was produced.

Pieces, bottom up:

``ClusterSpec``
    Declarative description of one member cluster: name, route tier (a
    ``netsim.TIERS`` key or a ``RouteProfile``), backend, node count,
    replication factor, ownership ``weight`` and per-node bandwidths.

``FederatedRing``
    The keyspace-level routing object.  Every uuid belongs to exactly one
    member cluster — the dataset->cluster *ownership map*, computed
    deterministically from the key's token and the members' weights — and
    ``replicas(key)`` returns only the owning cluster's replica nodes,
    qualified as ``"<cluster>/<node>"``.  Because it quacks like a
    ``TokenRing``, the existing ``split_token_aware`` placement runs over it
    unchanged and becomes *cluster-aware*: prefer the key's same-region
    cluster, then a replica-local node within it.  A ring can be rebuilt
    from checkpoint metadata alone (``FederatedRing.from_metadata``), so
    elastic restores never need the original simulator objects.

``FederatedCluster``
    Composes N ``Cluster`` instances behind one keyspace (one shared
    ``KVStore``: the logical contents are global; per-node simulation state —
    disk, NIC egress, GC — stays per cluster, so routing decisions have
    performance consequences).  Duck-types the slice of the ``Cluster``
    surface that ``MultiHostRun`` consumes (``nodes``, ``ring``, ``rf``,
    ``node_names``, ``load_report``, ``schedule_failure``...), plus
    cluster-level failure injection (``schedule_cluster_outage``) and a
    cluster-of-node reverse map for per-cluster egress accounting.

``FederatedConnectionPool``
    One *per-cluster* ``ConnectionPool`` per member — each with the member's
    own ``RouteProfile`` and AIMD processes, all sharing one client-ingress
    NIC (a host has one NIC no matter how many clusters it talks to).
    ``fetch`` routes each key to its owning cluster; when that cluster has
    no live node (cluster-level outage), or when every connection to it has
    failed mid-flight, the request *degrades* to the next cluster in
    failover order — possible because the keyspace is shared, exactly the
    replica-cluster degradation the federation benchmark exercises.  A
    once-guard keeps delivery exactly-once even when a hedge and a
    cross-cluster failover race.

Runtime placement (this PR's layer, see ``core/replication.py``):

* **Hot-key replication** — ``attach_replication`` gives the federation a
  shared ``HotKeyTracker`` + ``ReplicaCache``; every pool records accesses,
  serves live same-version replicas from the host's *region* cluster before
  the home cluster, and promotes hot off-region keys with a real WAN copy
  (home replica node disk+egress plus the home route's RTT and transfer
  time).  ``write_through`` bumps the key's version and invalidates its
  replica, so a stale copy can never serve.
* **Bandwidth-aware rebalancing** — ``FederatedRing.rebalance`` emits a new
  deterministic ownership map shifted toward members with spare
  bandwidth-delay product (measured by the flow controllers,
  ``FlowController.spare_bdp_samples``); ``install_ownership`` swaps it in
  as the *routing* ring while the declared ring keeps defining placement
  strips, and checkpoints carry both.

Exactly-once per epoch is a *plan* property (``EpochPlan`` strips are
disjoint and jointly covering; see ``core/prefetcher.py``), not a routing
one — so it holds across the federation, through cluster outages, elastic
N->M resizes, replica serving and ownership rebalances, without this module
doing anything special.
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import Cluster, TokenRing
from .connection import ConnectionPool
from .flowctl import (FlowControlConfig, FlowControllerGroup,
                      SharedIngressLimiter)
from .kvstore import DataRow, KVStore, MetaRow, token_of
from .netsim import (DISK_BANDWIDTH, NIC_BANDWIDTH, Clock, RateResource,
                     RouteProfile, TIERS)
from .placement import preferred_node_subsets
from .replication import Replication, ReplicationConfig

# A route is "WAN" when its RTT clears this threshold — separates the paper's
# local/low tiers (same building / same region) from med/high (cross-region /
# intercontinental) for the wan_bytes_share accounting.
WAN_RTT_THRESHOLD = 0.005


@dataclass(frozen=True)
class ClusterSpec:
    """One member cluster of a federation."""

    name: str
    route: str | RouteProfile = "local"  # TIERS key or explicit profile
    backend: str = "scylla"
    n_nodes: int = 4
    replication_factor: int = 2
    weight: int = 1                      # ownership share of the keyspace
    node_egress_bandwidth: float = NIC_BANDWIDTH
    node_disk_bandwidth: float = DISK_BANDWIDTH

    def route_profile(self) -> RouteProfile:
        return TIERS[self.route] if isinstance(self.route, str) else self.route

    @property
    def is_wan(self) -> bool:
        return self.route_profile().rtt > WAN_RTT_THRESHOLD


class FederatedRing:
    """Keyspace-level ring: per-cluster token rings + weighted ownership.

    ``owner_of(key)`` maps a key's token onto the member clusters by
    cumulative weight (md5 tokens are uniform, so shares converge to the
    weights); ``replicas(key)`` walks only the owning cluster's ring with
    that cluster's replication factor.  Both are pure functions of
    ``metadata()``, which is what checkpoints record.
    """

    def __init__(self, names: Sequence[str], rings: Dict[str, TokenRing],
                 rfs: Dict[str, int], weights: Dict[str, int],
                 ring_seeds: Dict[str, int],
                 n_nodes: Dict[str, int]) -> None:
        if not names:
            raise ValueError("a federation needs at least one cluster")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cluster names in {list(names)}")
        if any(weights[n] < 1 for n in names):
            raise ValueError("cluster ownership weights must be >= 1")
        self.names = list(names)
        self._rings = rings
        self._rfs = rfs
        self._weights = weights
        self._ring_seeds = ring_seeds
        self._n_nodes = n_nodes
        self._total_weight = sum(weights[n] for n in names)
        self._cum: List[Tuple[int, str]] = []
        acc = 0
        for n in names:
            acc += weights[n]
            self._cum.append((acc, n))

    @classmethod
    def from_clusters(cls, specs: Sequence[ClusterSpec],
                      clusters: Dict[str, Cluster]) -> "FederatedRing":
        names = [s.name for s in specs]
        return cls(names,
                   rings={s.name: clusters[s.name].ring for s in specs},
                   rfs={s.name: clusters[s.name].rf for s in specs},
                   weights={s.name: s.weight for s in specs},
                   ring_seeds={s.name: clusters[s.name].ring_seed
                               for s in specs},
                   n_nodes={s.name: s.n_nodes for s in specs})

    @classmethod
    def from_metadata(cls, meta: Sequence[Dict]) -> "FederatedRing":
        """Rebuild the ring from checkpoint metadata (see :meth:`metadata`) —
        strips are deterministic functions of it, so elastic restores can
        reconstruct an old federation's sharding without its simulator."""
        names = [m["name"] for m in meta]
        rings = {m["name"]: TokenRing(
            [f"{m['name']}/node{i}" for i in range(m["n_nodes"])],
            seed=m["ring_seed"]) for m in meta}
        return cls(names, rings,
                   rfs={m["name"]: m["rf"] for m in meta},
                   weights={m["name"]: m["weight"] for m in meta},
                   ring_seeds={m["name"]: m["ring_seed"] for m in meta},
                   n_nodes={m["name"]: m["n_nodes"] for m in meta})

    def metadata(self) -> List[Dict]:
        """Everything strip construction depends on, JSON-serializable."""
        return [{"name": n, "n_nodes": self._n_nodes[n],
                 "ring_seed": self._ring_seeds[n], "rf": self._rfs[n],
                 "weight": self._weights[n]} for n in self.names]

    @property
    def weights(self) -> Dict[str, int]:
        return dict(self._weights)

    # -- bandwidth-aware rebalancing -----------------------------------------
    # Rebalanced weights are expressed in finer grains than the declared
    # ones so a fractional ownership shift stays an integer weight map.
    REBALANCE_GRAIN = 16

    def rebalance(self, spare: Dict[str, float],
                  step: float = 0.25) -> "FederatedRing":
        """A new ring with ownership shifted toward spare capacity.

        ``spare`` is per-cluster spare bandwidth-delay product (samples of
        unused in-flight headroom, see ``FlowController.spare_bdp_samples``).
        The new weight map moves ``step`` of the total weight from the
        current shares toward the spare-BDP shares:

            w'[c] ∝ (1 - step) * w[c] + step * total * spare[c] / Σ spare

        rounded largest-remainder with deterministic (name-ordered) tie
        breaks, every weight clamped to >= 1, and the total conserved — so
        the result is a pure function of ``(weights, spare, step)``: two
        hosts computing it from the same inputs get byte-identical ownership
        maps, and ``metadata()`` checkpoints it exactly like the declared
        ring (property-tested in ``tests/test_replication.py``).  With no
        spare anywhere the ring is returned unchanged.
        """
        if not 0.0 <= step <= 1.0:
            raise ValueError(f"step must be in [0, 1], got {step}")
        s_total = sum(max(spare.get(n, 0.0), 0.0) for n in self.names)
        if step == 0.0 or s_total <= 0.0:
            return self
        grains = {n: self._weights[n] * self.REBALANCE_GRAIN
                  for n in self.names}
        total = sum(grains.values())
        targets = {n: ((1.0 - step) * grains[n]
                       + step * total * max(spare.get(n, 0.0), 0.0) / s_total)
                   for n in self.names}
        new = {n: max(1, int(targets[n])) for n in self.names}
        # largest-remainder distribution of the leftover grains, then — if
        # the >=1 clamp overshot — take grains back from the largest weights
        remainder = total - sum(new.values())
        order = sorted(self.names,
                       key=lambda n: (-(targets[n] - int(targets[n])), n))
        i = 0
        while remainder > 0:
            new[order[i % len(order)]] += 1
            remainder -= 1
            i += 1
        give_back = sorted(self.names, key=lambda n: (-new[n], n))
        i = 0
        while remainder < 0:
            n = give_back[i % len(give_back)]
            if new[n] > 1:
                new[n] -= 1
                remainder += 1
            i += 1
        return FederatedRing(self.names, self._rings, self._rfs, new,
                             self._ring_seeds, self._n_nodes)

    # -- ownership ----------------------------------------------------------
    def owner_of(self, key: _uuid.UUID) -> str:
        slot = token_of(key) % self._total_weight
        for acc, name in self._cum:
            if slot < acc:
                return name
        return self._cum[-1][1]          # unreachable; defensive

    def failover_order(self, owner: str) -> List[str]:
        """Owner first, then the remaining clusters in declaration order —
        the degradation path when a whole cluster goes dark."""
        return [owner] + [n for n in self.names if n != owner]

    # -- TokenRing surface ---------------------------------------------------
    def replicas(self, key: _uuid.UUID, rf: int = 0) -> List[str]:
        """Replica nodes of ``key`` *within its owning cluster* (qualified
        names).  ``rf`` is accepted for TokenRing compatibility but each
        cluster's own replication factor governs."""
        owner = self.owner_of(key)
        return self._rings[owner].replicas(key, self._rfs[owner])


def federated_preferred_subsets(node_names_by_cluster: Dict[str, List[str]],
                                n_hosts: int) -> List[Tuple[str, ...]]:
    """Per-host preference map spanning every member cluster.

    The union of per-cluster round-robin subsets
    (:func:`repro.core.placement.preferred_node_subsets`), so every host has
    a preferred node in every cluster that has one to give.  A flat
    round-robin over the concatenated node list would leave some hosts with
    no preferred node in some cluster whenever the host count doesn't divide
    the per-cluster node counts — and a host with no local preference in the
    intercontinental cluster would receive none of its keys in pass 1,
    skewing the WAN work onto the other hosts.
    """
    out: List[Tuple[str, ...]] = [() for _ in range(n_hosts)]
    for names in node_names_by_cluster.values():
        for j, subset in enumerate(preferred_node_subsets(names, n_hosts)):
            out[j] = out[j] + subset
    return out


class FederatedCluster:
    """N member ``Cluster`` instances behind one keyspace.

    Presents the ``Cluster`` surface ``MultiHostRun`` relies on (merged
    ``nodes`` dict with qualified names, a ``ring``, ``rf``,
    ``load_report()``, ``schedule_failure()``), plus federation-only
    operations: the ownership map, cluster-level outage injection, and
    per-cluster load/egress summaries.
    """

    def __init__(self, clock: Clock, store: KVStore,
                 specs: Sequence[ClusterSpec], seed: int = 1234) -> None:
        specs = tuple(specs)
        if not specs:
            raise ValueError("a federation needs at least one ClusterSpec")
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("duplicate cluster names in federation")
        for s in specs:
            if "/" in s.name:
                raise ValueError(f"cluster name {s.name!r} may not contain "
                                 "'/' (reserved for node qualification)")
        self.clock = clock
        self.store = store
        self.specs = specs
        self.ring_seed = seed
        self.clusters: Dict[str, Cluster] = {
            s.name: Cluster(clock, store, backend=s.backend,
                            n_nodes=s.n_nodes, rf=s.replication_factor,
                            seed=seed + 101 * i,
                            disk_bandwidth=s.node_disk_bandwidth,
                            egress_bandwidth=s.node_egress_bandwidth,
                            node_prefix=f"{s.name}/")
            for i, s in enumerate(specs)
        }
        self.routes: Dict[str, RouteProfile] = {
            s.name: s.route_profile() for s in specs}
        # ``ring`` is the *declared* keyspace map — what placement strips are
        # derived from and what ``checkpoint["federation"]`` records.
        # ``routing_ring`` is what serving consults; it starts as the same
        # object and diverges when bandwidth-aware rebalancing installs a
        # shifted ownership map (checkpointed separately as "ownership").
        # The keyspace is shared, so routing off the declared map is always
        # safe — rebalance changes performance, never correctness.
        self.ring = FederatedRing.from_clusters(specs, self.clusters)
        self.routing_ring = self.ring
        # Hot-key replication (core/replication.py): attached on demand;
        # None keeps every fetch on its home cluster.
        self.replication: Optional[Replication] = None
        # Keyspace write versions: bumped by write_through so a replica
        # copied before a write can never serve after it (the cache checks).
        self._versions: Dict[_uuid.UUID, int] = {}

    # -- ownership / topology ------------------------------------------------
    def owner_of(self, key: _uuid.UUID) -> str:
        return self.routing_ring.owner_of(key)

    def install_ownership(self, ring: FederatedRing) -> None:
        """Swap in a rebalanced ownership map (same members, new weights).
        Replicas promoted under the old map stay valid — the cache pins the
        serving cluster per key, and version checks guard staleness."""
        if list(ring.names) != [s.name for s in self.specs]:
            raise ValueError(f"ownership map members {list(ring.names)} != "
                             f"federation members "
                             f"{[s.name for s in self.specs]}")
        self.routing_ring = ring

    def ownership_counts(self, uuids: Sequence[_uuid.UUID]) -> Dict[str, int]:
        counts = {s.name: 0 for s in self.specs}
        for u in uuids:
            counts[self.owner_of(u)] += 1
        return counts

    def serving_cluster(self, key: _uuid.UUID,
                        exclude: frozenset = frozenset()) -> Optional[str]:
        """First *live* cluster in the owner's failover order, skipping
        ``exclude``; ``None`` when every candidate is dark.  The single
        authority on degradation order — routing and mid-flight failover
        both go through here (keyspace is shared, so any member can serve
        any key)."""
        for name in self.routing_ring.failover_order(self.owner_of(key)):
            if name not in exclude and self.clusters[name].alive_nodes():
                return name
        return None

    # -- hot-key replication -------------------------------------------------
    def attach_replication(self,
                           cfg: Optional[ReplicationConfig] = None
                           ) -> Replication:
        """Switch hot-key replication on (idempotent): one shared tracker +
        replica cache for every host's pool (hotness is a workload property,
        and one host's promotion serves them all)."""
        if self.replication is None:
            self.replication = Replication(cfg or ReplicationConfig(),
                                           self.clock)
        return self.replication

    def version_of(self, key: _uuid.UUID) -> int:
        return self._versions.get(key, 0)

    def write_through(self, data: DataRow, meta: MetaRow) -> None:
        """Keyspace write: update the shared store, bump the key's version
        and invalidate any replica of it — write-through semantics, so the
        home cluster always has the new value and a stale copy can never be
        served (the version check catches even an invalidation lost to a
        concurrent promotion)."""
        self.store.insert_atomic(data, meta)
        self._versions[data.uuid] = self._versions.get(data.uuid, 0) + 1
        if self.replication is not None:
            self.replication.cache.invalidate(data.uuid)

    def promote(self, key: _uuid.UUID, on_done, on_abort) -> None:
        """Promotion copy of ``key``'s row out of its home cluster (the
        destination is pinned by the caller's ``ReplicaCache`` entry): one
        real WAN transfer — the home replica node serves the bytes (disk +
        egress load where the data lives) and the copy crosses the home
        cluster's route before the replica entry may go live.  ``on_abort``
        fires instead when no home replica node is up."""
        row = self.store.get_data(key)
        owner = self.owner_of(key)
        cl = self.clusters[owner]
        live = [n for n in cl.ring.replicas(key, cl.rf)
                if not cl.nodes[n].down]
        if not live:
            on_abort()
            return
        route = self.routes[owner]
        now = self.clock.now()
        t_leave = cl.nodes[live[0]].serve(now, row.size)
        delay = max(t_leave - now, 0.0) + route.rtt \
            + row.size / route.conn_capacity
        if self.replication is not None:
            self.replication.promotion_wan_bytes += row.size
        self.clock.schedule(delay, on_done)

    def cluster_of_node(self, qualified_name: str) -> str:
        return qualified_name.split("/", 1)[0]

    def node_names_by_cluster(self) -> Dict[str, List[str]]:
        return {s.name: self.clusters[s.name].node_names()
                for s in self.specs}

    def wan_clusters(self) -> frozenset:
        return frozenset(s.name for s in self.specs if s.is_wan)

    # -- Cluster-compatible surface -----------------------------------------
    @property
    def nodes(self) -> Dict:
        merged = {}
        for s in self.specs:
            merged.update(self.clusters[s.name].nodes)
        return merged

    @property
    def rf(self) -> int:
        # only consulted by TokenRing-compatible call sites; the federated
        # ring applies each member's own rf regardless.
        return max(self.clusters[s.name].rf for s in self.specs)

    def node_names(self) -> List[str]:
        return [n for s in self.specs
                for n in self.clusters[s.name].node_names()]

    def alive_nodes(self) -> List[str]:
        return [n for s in self.specs
                for n in self.clusters[s.name].alive_nodes()]

    def total_disk_bytes(self) -> int:
        return sum(self.clusters[s.name].total_disk_bytes()
                   for s in self.specs)

    # -- failure injection ---------------------------------------------------
    def schedule_failure(self, qualified_name: str, after: float,
                         recover_after: Optional[float] = None) -> None:
        cname = self.cluster_of_node(qualified_name)
        self.clusters[cname].schedule_failure(qualified_name, after,
                                              recover_after)

    def schedule_cluster_outage(self, name: str, after: float,
                                recover_after: Optional[float] = None) -> None:
        """Take a whole member cluster dark (region outage / WAN partition):
        every node fails at once, so reads degrade to the replica cluster."""
        for node in self.clusters[name].node_names():
            self.clusters[name].schedule_failure(node, after, recover_after)

    # -- load reporting -----------------------------------------------------
    def load_report(self) -> Dict[str, Dict[str, float]]:
        """Per-node report over qualified names (merged member reports)."""
        merged: Dict[str, Dict[str, float]] = {}
        for s in self.specs:
            merged.update(self.clusters[s.name].load_report())
        return merged

    def cluster_report(self) -> Dict[str, Dict[str, float]]:
        """Per-cluster rollup: egress, requests, route tier, liveness."""
        out: Dict[str, Dict[str, float]] = {}
        total_egress = max(sum(n.egress_bytes for n in self.nodes.values()), 1)
        for s in self.specs:
            cl = self.clusters[s.name]
            egress = sum(n.egress_bytes for n in cl.nodes.values())
            out[s.name] = {
                "route": s.route if isinstance(s.route, str)
                         else s.route_profile().name,
                "rtt": self.routes[s.name].rtt,
                "wan": float(s.is_wan),
                "egress_bytes": egress,
                "egress_share": egress / total_egress,
                "requests": sum(n.requests_served for n in cl.nodes.values()),
                "nodes_down": sum(1 for n in cl.nodes.values() if n.down),
                "n_nodes": s.n_nodes,
            }
        return out


class FederatedConnectionPool:
    """All connections of one training host to every member cluster.

    Mirrors the ``ConnectionPool`` surface the prefetcher and the multi-host
    coordinator consume (``fetch``, ``bytes_received``, ``requests_sent``,
    ``failovers``, ``served_by_node``, ``inflight``), aggregating over one
    sub-pool per member cluster.  Each sub-pool runs the member's own
    ``RouteProfile`` (own RTT, own AIMD bandwidth processes); all sub-pools
    share one client-ingress NIC.
    """

    def __init__(self, clock: Clock, federation: FederatedCluster,
                 io_threads: int = 8, conns_per_thread: int = 2,
                 seed: int = 99, hedge_after: Optional[float] = None,
                 materialize: bool = False,
                 client_ingress_bandwidth: float = NIC_BANDWIDTH,
                 preferred_nodes: Optional[Sequence[str]] = None,
                 region: Optional[str] = None,
                 wire_codec: "str | Dict[str, str] | None" = None,
                 io_scaling: bool = False) -> None:
        self.clock = clock
        self.federation = federation
        self.cluster = federation          # Cluster-surface alias
        self.ingress = RateResource("client/ingress",
                                    client_ingress_bandwidth)
        # This host's home region: hot keys are promoted into (and served
        # from) this member cluster.  Default: the member with the lowest
        # route RTT — the cluster "next to" the training hosts.
        if region is not None and region not in federation.clusters:
            raise ValueError(f"unknown region cluster {region!r} (members: "
                             f"{[s.name for s in federation.specs]})")
        self.region = region or min(
            federation.specs, key=lambda s: (s.route_profile().rtt, s.name)
        ).name
        self.cluster_failovers = 0         # fetches served off-owner
        self.duplicates_suppressed = 0     # late completions the once-guard ate
        self.replica_hedges = 0            # WAN fetches hedged onto a replica
        # completion-attributed replica accounting: hits and the fetch
        # denominator both count when a fetch *delivers*, so the hit
        # fraction compares like with like (a fetch routed to a replica but
        # diverted mid-flight counts as a completed fetch, not a hit)
        self.fetches = 0                   # completed fetches
        self.replica_hits = 0              # completions served by a replica
        self.wan_bytes_saved = 0           # replica hits whose home was WAN
        self.promotions_issued = 0         # promotion copies this host started
        # Adaptive flow control: one FlowController per member cluster (each
        # fed by that member's sub-pool over that member's route), summed
        # into the host budget by a FlowControllerGroup.
        self.controller: Optional[FlowControllerGroup] = None
        preferred = list(preferred_nodes or ())
        self.pools: Dict[str, ConnectionPool] = {}
        for i, spec in enumerate(federation.specs):
            # this host's preferred nodes *within* this member cluster
            prefix = f"{spec.name}/"
            local_pref = [n for n in preferred if n.startswith(prefix)]
            self.pools[spec.name] = ConnectionPool(
                clock, federation.clusters[spec.name],
                federation.routes[spec.name],
                io_threads=io_threads, conns_per_thread=conns_per_thread,
                seed=seed + 7919 * i, hedge_after=hedge_after,
                materialize=materialize,
                preferred_nodes=local_pref or None,
                ingress=self.ingress,
                on_exhausted=self._make_exhausted(spec.name),
                wire_codec=self._member_codec(wire_codec, spec),
                io_scaling=io_scaling)

    # WAN routes trade cheap node/host CPU for scarce intercontinental
    # bandwidth; sub-millisecond routes have nothing to buy.  ``"auto"``
    # draws the line at this RTT (core/wirefmt.py rationale).
    WAN_CODEC_RTT = 0.010
    AUTO_WAN_CODEC = "byteshuffle"

    def _member_codec(self, wire_codec, spec) -> Optional[str]:
        """Per-member codec: a dict maps member name -> codec, ``"auto"``
        compresses WAN members only, a plain name applies everywhere."""
        if wire_codec is None:
            return None
        if isinstance(wire_codec, dict):
            return wire_codec.get(spec.name, "none")
        if wire_codec == "auto":
            return (self.AUTO_WAN_CODEC
                    if spec.route_profile().rtt >= self.WAN_CODEC_RTT
                    else "none")
        return wire_codec

    def attach_flow_control(self, cfg: FlowControlConfig, batch_size: int,
                            limiter: Optional[SharedIngressLimiter] = None
                            ) -> FlowControllerGroup:
        """One BDP-tracking controller per member cluster — a 150 ms WAN
        member ramps deep while a local member stays shallow — summed into
        the host's in-flight budget.  Idempotent."""
        if self.controller is None:
            members = {}
            for name, pool in self.pools.items():
                ctl = pool.attach_flow_control(cfg, batch_size,
                                               limiter=limiter)
                ctl.name = name            # report by member, not route tier
                members[name] = ctl
            self.controller = FlowControllerGroup(members, batch_size)
        return self.controller

    # -- admission / routing helpers ----------------------------------------
    def _live_replica(self, key: _uuid.UUID,
                      exclude: frozenset = frozenset()) -> Optional[str]:
        """Cluster holding a live, current-version, *reachable* replica of
        ``key`` — without consuming a cache hit or refreshing LRU recency
        (advisory peeks must not distort the serving statistics)."""
        rep = self.federation.replication
        if rep is None:
            return None
        e = rep.cache.get(key)
        if (e is not None and e.live
                and e.version == self.federation.version_of(key)
                and e.cluster not in exclude
                and e.cluster in self.federation.clusters
                and self.federation.clusters[e.cluster].alive_nodes()):
            return e.cluster
        return None

    def _serving_member(self, key: _uuid.UUID) -> str:
        """The member cluster a fetch issued *now* would target: a live
        same-version replica first, then the owner's failover order."""
        cl = self._live_replica(key)
        if cl is not None:
            return cl
        return (self.federation.serving_cluster(key)
                or self.federation.owner_of(key))

    def admit(self, key: _uuid.UUID) -> bool:
        """Per-key route admission (``PrefetchConfig.route_admission``),
        resolved against the *serving member's* budget: a key whose home
        sits behind a saturated WAN member is deferred while a key served
        by the local member (or a local replica) is admitted — so issue
        order follows per-route headroom, not plan order.  Advisory, like
        the base pool's: the prefetcher defers bounded and force-issues."""
        return self.pools[self._serving_member(key)].admit(key)

    # -- fetch --------------------------------------------------------------
    def fetch(self, key: _uuid.UUID,
              on_done: Callable) -> None:
        """Route ``key``: a live same-version hot-key replica first (see
        ``core/replication.py``), then its owning cluster (degraded to a
        live replica cluster when the owner is dark).  Delivery is
        exactly-once even when a hedge in a dying cluster races a
        cross-cluster failover — replica-served fetches share the same
        once-guard and exhaustion path as owner-served ones.

        Replica-aware hedging: a fetch sent to a *WAN* member is hedged
        against a live local replica when one exists at hedge time — the
        window where a promotion lands while the WAN read is in flight.
        The hedge delay comes from the WAN member's own pool
        (``ConnectionPool._hedge_delay``: the configured constant, or the
        member controller's measured min-RTT under ``hedge_after="auto"``),
        and the once-guard arbitrates the race."""
        state = {"done": False}

        def once(res, replica_of=None) -> None:
            if state["done"]:
                self.duplicates_suppressed += 1
                return
            state["done"] = True
            self.fetches += 1
            if replica_of is not None and res.node is not None:
                # attribute at completion: a fetch *routed* to a replica but
                # diverted mid-flight (region outage -> exhausted -> home
                # cluster) must not be reported as a replica hit or a WAN
                # saving — the bytes crossed the WAN after all
                served = self.federation.cluster_of_node(res.node)
                if served == replica_of:
                    self.replica_hits += 1
                    if (self.federation.owner_of(key)
                            in self.federation.wan_clusters()
                            and served
                            not in self.federation.wan_clusters()):
                        self.wan_bytes_saved += res.size
            on_done(res)

        owner = self.federation.owner_of(key)
        rep = self.federation.replication
        if rep is not None:
            rep.tracker.record(key)
            # a dark replica cluster is vetoed without consuming the cache
            # hit (the entry survives — the outage path must not
            # mass-invalidate a still-valid cache)
            cached = rep.cache.serving_cluster(
                key, self.federation.version_of(key), self.clock.now(),
                usable=lambda c: (c in self.federation.clusters
                                  and self.federation.clusters[c]
                                  .alive_nodes()))
            if cached is not None:
                # replica serving fans out across the target cluster
                # (cfg.replica_rf nodes, 0 = all), so hot traffic spreads
                # instead of re-pinning an rf-sized node set
                rf = (rep.cfg.replica_rf
                      or len(self.federation.clusters[cached].nodes))
                self.pools[cached].fetch(
                    key, lambda res: once(res, replica_of=cached), rf=rf)
                return
            self._maybe_promote(key, owner, rep)
        # total blackout: keep targeting the owner, whose pool backs off and
        # retries (so a recovering cluster is picked up automatically)
        target = self.federation.serving_cluster(key) or owner
        if target != owner:
            self.cluster_failovers += 1
        self.pools[target].fetch(key, once)

        # replica-aware hedge: the replica is checked at *fire* time, so a
        # promotion that lands while the WAN read is in flight gets used
        if rep is not None and target in self.federation.wan_clusters():
            delay = self.pools[target]._hedge_delay()
            if delay is not None:
                def maybe_replica_hedge() -> None:
                    if state["done"]:
                        return
                    cl = self._live_replica(key,
                                            exclude=frozenset((target,)))
                    if cl is None:
                        return
                    self.replica_hedges += 1
                    ctl = self.pools[target].controller
                    if ctl is not None:
                        ctl.on_hedge()   # the WAN member is the slow one
                    rf = (rep.cfg.replica_rf
                          or len(self.federation.clusters[cl].nodes))
                    self.pools[cl].fetch(
                        key, lambda res: once(res, replica_of=cl), rf=rf)

                self.clock.schedule(delay, maybe_replica_hedge)

    def _maybe_promote(self, key: _uuid.UUID, owner: str, rep) -> None:
        """Start a promotion copy when ``key`` is hot, lives off-region, and
        the cache takes the reservation.  The entry serves only after the
        WAN copy lands (``FederatedCluster.promote``); an abort (home
        cluster dark) releases the reservation."""
        if owner == self.region or not rep.tracker.is_hot(key):
            return
        if not self.federation.clusters[self.region].alive_nodes():
            return
        version = self.federation.version_of(key)
        token = rep.cache.begin_promotion(key, self.region, version,
                                          self.clock.now())
        if token is None:
            return
        self.promotions_issued += 1

        def landed() -> None:
            rep.cache.commit_promotion(key, token)

        def aborted() -> None:
            rep.promotions_aborted += 1
            rep.cache.release(key, token)

        self.federation.promote(key, on_done=landed, on_abort=aborted)

    def _make_exhausted(self, cname: str):
        """Cluster-level failover: when every connection to ``cname`` has
        failed for a request, hand it to the next live cluster.  Returns
        False (keep backing off in place) when no other cluster is alive,
        so a total blackout still surfaces as the caller's timeout and a
        recovering cluster is picked up automatically."""
        def handler(key: _uuid.UUID, on_done: Callable) -> bool:
            target = self.federation.serving_cluster(
                key, exclude=frozenset((cname,)))
            if target is None:
                return False
            if target != self.federation.owner_of(key):
                self.cluster_failovers += 1
            self.pools[target].fetch(key, on_done)
            return True
        return handler

    # -- aggregated counters (ConnectionPool surface) ------------------------
    @property
    def bytes_received(self) -> int:
        return sum(p.bytes_received for p in self.pools.values())

    @property
    def payload_bytes_received(self) -> int:
        return sum(p.payload_bytes_received for p in self.pools.values())

    @property
    def requests_sent(self) -> int:
        return sum(p.requests_sent for p in self.pools.values())

    @property
    def failovers(self) -> int:
        return sum(p.failovers for p in self.pools.values())

    @property
    def served_by_node(self) -> Dict[str, int]:
        merged: Dict[str, int] = {}
        for p in self.pools.values():
            for name, count in p.served_by_node.items():
                merged[name] = merged.get(name, 0) + count
        return merged

    @property
    def inflight(self) -> int:
        return sum(p.inflight for p in self.pools.values())

    def throughput_traces(self, window: float = 0.5):
        return {name: p.throughput_traces(window)
                for name, p in self.pools.items()}


__all__ = ["ClusterSpec", "FederatedRing", "FederatedCluster",
           "FederatedConnectionPool", "federated_preferred_subsets",
           "WAN_RTT_THRESHOLD", "Replication", "ReplicationConfig"]
