"""Multi-cluster federation: one training run spanning several storage
clusters over heterogeneous WAN routes.

The paper's headline result is sustaining training throughput when the image
store sits behind a high-latency route (local vs medium vs intercontinental,
Sec. 4.2).  This module models the next step on that axis: a *single* run
whose dataset is spread across N storage clusters — each with its own token
ring, node set, replication factor and WAN route — so data can live in the
region where it was produced.

Pieces, bottom up:

``ClusterSpec``
    Declarative description of one member cluster: name, route tier (a
    ``netsim.TIERS`` key or a ``RouteProfile``), backend, node count,
    replication factor, ownership ``weight`` and per-node bandwidths.

``FederatedRing``
    The keyspace-level routing object.  Every uuid belongs to exactly one
    member cluster — the dataset->cluster *ownership map*, computed
    deterministically from the key's token and the members' weights — and
    ``replicas(key)`` returns only the owning cluster's replica nodes,
    qualified as ``"<cluster>/<node>"``.  Because it quacks like a
    ``TokenRing``, the existing ``split_token_aware`` placement runs over it
    unchanged and becomes *cluster-aware*: prefer the key's same-region
    cluster, then a replica-local node within it.  A ring can be rebuilt
    from checkpoint metadata alone (``FederatedRing.from_metadata``), so
    elastic restores never need the original simulator objects.

``FederatedCluster``
    Composes N ``Cluster`` instances behind one keyspace (one shared
    ``KVStore``: the logical contents are global; per-node simulation state —
    disk, NIC egress, GC — stays per cluster, so routing decisions have
    performance consequences).  Duck-types the slice of the ``Cluster``
    surface that ``MultiHostRun`` consumes (``nodes``, ``ring``, ``rf``,
    ``node_names``, ``load_report``, ``schedule_failure``...), plus
    cluster-level failure injection (``schedule_cluster_outage``) and a
    cluster-of-node reverse map for per-cluster egress accounting.

``FederatedConnectionPool``
    One *per-cluster* ``ConnectionPool`` per member — each with the member's
    own ``RouteProfile`` and AIMD processes, all sharing one client-ingress
    NIC (a host has one NIC no matter how many clusters it talks to).
    ``fetch`` routes each key to its owning cluster; when that cluster has
    no live node (cluster-level outage), or when every connection to it has
    failed mid-flight, the request *degrades* to the next cluster in
    failover order — possible because the keyspace is shared, exactly the
    replica-cluster degradation the federation benchmark exercises.  A
    once-guard keeps delivery exactly-once even when a hedge and a
    cross-cluster failover race.

Exactly-once per epoch is a *plan* property (``EpochPlan`` strips are
disjoint and jointly covering; see ``core/prefetcher.py``), not a routing
one — so it holds across the federation, through cluster outages and
through elastic N->M resizes, without this module doing anything special.
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import Cluster, TokenRing
from .connection import ConnectionPool
from .flowctl import (FlowControlConfig, FlowControllerGroup,
                      SharedIngressLimiter)
from .kvstore import KVStore, token_of
from .netsim import (DISK_BANDWIDTH, NIC_BANDWIDTH, Clock, RateResource,
                     RouteProfile, TIERS)
from .placement import preferred_node_subsets

# A route is "WAN" when its RTT clears this threshold — separates the paper's
# local/low tiers (same building / same region) from med/high (cross-region /
# intercontinental) for the wan_bytes_share accounting.
WAN_RTT_THRESHOLD = 0.005


@dataclass(frozen=True)
class ClusterSpec:
    """One member cluster of a federation."""

    name: str
    route: str | RouteProfile = "local"  # TIERS key or explicit profile
    backend: str = "scylla"
    n_nodes: int = 4
    replication_factor: int = 2
    weight: int = 1                      # ownership share of the keyspace
    node_egress_bandwidth: float = NIC_BANDWIDTH
    node_disk_bandwidth: float = DISK_BANDWIDTH

    def route_profile(self) -> RouteProfile:
        return TIERS[self.route] if isinstance(self.route, str) else self.route

    @property
    def is_wan(self) -> bool:
        return self.route_profile().rtt > WAN_RTT_THRESHOLD


class FederatedRing:
    """Keyspace-level ring: per-cluster token rings + weighted ownership.

    ``owner_of(key)`` maps a key's token onto the member clusters by
    cumulative weight (md5 tokens are uniform, so shares converge to the
    weights); ``replicas(key)`` walks only the owning cluster's ring with
    that cluster's replication factor.  Both are pure functions of
    ``metadata()``, which is what checkpoints record.
    """

    def __init__(self, names: Sequence[str], rings: Dict[str, TokenRing],
                 rfs: Dict[str, int], weights: Dict[str, int],
                 ring_seeds: Dict[str, int],
                 n_nodes: Dict[str, int]) -> None:
        if not names:
            raise ValueError("a federation needs at least one cluster")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate cluster names in {list(names)}")
        if any(weights[n] < 1 for n in names):
            raise ValueError("cluster ownership weights must be >= 1")
        self.names = list(names)
        self._rings = rings
        self._rfs = rfs
        self._weights = weights
        self._ring_seeds = ring_seeds
        self._n_nodes = n_nodes
        self._total_weight = sum(weights[n] for n in names)
        self._cum: List[Tuple[int, str]] = []
        acc = 0
        for n in names:
            acc += weights[n]
            self._cum.append((acc, n))

    @classmethod
    def from_clusters(cls, specs: Sequence[ClusterSpec],
                      clusters: Dict[str, Cluster]) -> "FederatedRing":
        names = [s.name for s in specs]
        return cls(names,
                   rings={s.name: clusters[s.name].ring for s in specs},
                   rfs={s.name: clusters[s.name].rf for s in specs},
                   weights={s.name: s.weight for s in specs},
                   ring_seeds={s.name: clusters[s.name].ring_seed
                               for s in specs},
                   n_nodes={s.name: s.n_nodes for s in specs})

    @classmethod
    def from_metadata(cls, meta: Sequence[Dict]) -> "FederatedRing":
        """Rebuild the ring from checkpoint metadata (see :meth:`metadata`) —
        strips are deterministic functions of it, so elastic restores can
        reconstruct an old federation's sharding without its simulator."""
        names = [m["name"] for m in meta]
        rings = {m["name"]: TokenRing(
            [f"{m['name']}/node{i}" for i in range(m["n_nodes"])],
            seed=m["ring_seed"]) for m in meta}
        return cls(names, rings,
                   rfs={m["name"]: m["rf"] for m in meta},
                   weights={m["name"]: m["weight"] for m in meta},
                   ring_seeds={m["name"]: m["ring_seed"] for m in meta},
                   n_nodes={m["name"]: m["n_nodes"] for m in meta})

    def metadata(self) -> List[Dict]:
        """Everything strip construction depends on, JSON-serializable."""
        return [{"name": n, "n_nodes": self._n_nodes[n],
                 "ring_seed": self._ring_seeds[n], "rf": self._rfs[n],
                 "weight": self._weights[n]} for n in self.names]

    # -- ownership ----------------------------------------------------------
    def owner_of(self, key: _uuid.UUID) -> str:
        slot = token_of(key) % self._total_weight
        for acc, name in self._cum:
            if slot < acc:
                return name
        return self._cum[-1][1]          # unreachable; defensive

    def failover_order(self, owner: str) -> List[str]:
        """Owner first, then the remaining clusters in declaration order —
        the degradation path when a whole cluster goes dark."""
        return [owner] + [n for n in self.names if n != owner]

    # -- TokenRing surface ---------------------------------------------------
    def replicas(self, key: _uuid.UUID, rf: int = 0) -> List[str]:
        """Replica nodes of ``key`` *within its owning cluster* (qualified
        names).  ``rf`` is accepted for TokenRing compatibility but each
        cluster's own replication factor governs."""
        owner = self.owner_of(key)
        return self._rings[owner].replicas(key, self._rfs[owner])


def federated_preferred_subsets(node_names_by_cluster: Dict[str, List[str]],
                                n_hosts: int) -> List[Tuple[str, ...]]:
    """Per-host preference map spanning every member cluster.

    The union of per-cluster round-robin subsets
    (:func:`repro.core.placement.preferred_node_subsets`), so every host has
    a preferred node in every cluster that has one to give.  A flat
    round-robin over the concatenated node list would leave some hosts with
    no preferred node in some cluster whenever the host count doesn't divide
    the per-cluster node counts — and a host with no local preference in the
    intercontinental cluster would receive none of its keys in pass 1,
    skewing the WAN work onto the other hosts.
    """
    out: List[Tuple[str, ...]] = [() for _ in range(n_hosts)]
    for names in node_names_by_cluster.values():
        for j, subset in enumerate(preferred_node_subsets(names, n_hosts)):
            out[j] = out[j] + subset
    return out


class FederatedCluster:
    """N member ``Cluster`` instances behind one keyspace.

    Presents the ``Cluster`` surface ``MultiHostRun`` relies on (merged
    ``nodes`` dict with qualified names, a ``ring``, ``rf``,
    ``load_report()``, ``schedule_failure()``), plus federation-only
    operations: the ownership map, cluster-level outage injection, and
    per-cluster load/egress summaries.
    """

    def __init__(self, clock: Clock, store: KVStore,
                 specs: Sequence[ClusterSpec], seed: int = 1234) -> None:
        specs = tuple(specs)
        if not specs:
            raise ValueError("a federation needs at least one ClusterSpec")
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("duplicate cluster names in federation")
        for s in specs:
            if "/" in s.name:
                raise ValueError(f"cluster name {s.name!r} may not contain "
                                 "'/' (reserved for node qualification)")
        self.clock = clock
        self.store = store
        self.specs = specs
        self.ring_seed = seed
        self.clusters: Dict[str, Cluster] = {
            s.name: Cluster(clock, store, backend=s.backend,
                            n_nodes=s.n_nodes, rf=s.replication_factor,
                            seed=seed + 101 * i,
                            disk_bandwidth=s.node_disk_bandwidth,
                            egress_bandwidth=s.node_egress_bandwidth,
                            node_prefix=f"{s.name}/")
            for i, s in enumerate(specs)
        }
        self.routes: Dict[str, RouteProfile] = {
            s.name: s.route_profile() for s in specs}
        self.ring = FederatedRing.from_clusters(specs, self.clusters)

    # -- ownership / topology ------------------------------------------------
    def owner_of(self, key: _uuid.UUID) -> str:
        return self.ring.owner_of(key)

    def ownership_counts(self, uuids: Sequence[_uuid.UUID]) -> Dict[str, int]:
        counts = {s.name: 0 for s in self.specs}
        for u in uuids:
            counts[self.owner_of(u)] += 1
        return counts

    def serving_cluster(self, key: _uuid.UUID,
                        exclude: frozenset = frozenset()) -> Optional[str]:
        """First *live* cluster in the owner's failover order, skipping
        ``exclude``; ``None`` when every candidate is dark.  The single
        authority on degradation order — routing and mid-flight failover
        both go through here (keyspace is shared, so any member can serve
        any key)."""
        for name in self.ring.failover_order(self.owner_of(key)):
            if name not in exclude and self.clusters[name].alive_nodes():
                return name
        return None

    def cluster_of_node(self, qualified_name: str) -> str:
        return qualified_name.split("/", 1)[0]

    def node_names_by_cluster(self) -> Dict[str, List[str]]:
        return {s.name: self.clusters[s.name].node_names()
                for s in self.specs}

    def wan_clusters(self) -> frozenset:
        return frozenset(s.name for s in self.specs if s.is_wan)

    # -- Cluster-compatible surface -----------------------------------------
    @property
    def nodes(self) -> Dict:
        merged = {}
        for s in self.specs:
            merged.update(self.clusters[s.name].nodes)
        return merged

    @property
    def rf(self) -> int:
        # only consulted by TokenRing-compatible call sites; the federated
        # ring applies each member's own rf regardless.
        return max(self.clusters[s.name].rf for s in self.specs)

    def node_names(self) -> List[str]:
        return [n for s in self.specs
                for n in self.clusters[s.name].node_names()]

    def alive_nodes(self) -> List[str]:
        return [n for s in self.specs
                for n in self.clusters[s.name].alive_nodes()]

    def total_disk_bytes(self) -> int:
        return sum(self.clusters[s.name].total_disk_bytes()
                   for s in self.specs)

    # -- failure injection ---------------------------------------------------
    def schedule_failure(self, qualified_name: str, after: float,
                         recover_after: Optional[float] = None) -> None:
        cname = self.cluster_of_node(qualified_name)
        self.clusters[cname].schedule_failure(qualified_name, after,
                                              recover_after)

    def schedule_cluster_outage(self, name: str, after: float,
                                recover_after: Optional[float] = None) -> None:
        """Take a whole member cluster dark (region outage / WAN partition):
        every node fails at once, so reads degrade to the replica cluster."""
        for node in self.clusters[name].node_names():
            self.clusters[name].schedule_failure(node, after, recover_after)

    # -- load reporting -----------------------------------------------------
    def load_report(self) -> Dict[str, Dict[str, float]]:
        """Per-node report over qualified names (merged member reports)."""
        merged: Dict[str, Dict[str, float]] = {}
        for s in self.specs:
            merged.update(self.clusters[s.name].load_report())
        return merged

    def cluster_report(self) -> Dict[str, Dict[str, float]]:
        """Per-cluster rollup: egress, requests, route tier, liveness."""
        out: Dict[str, Dict[str, float]] = {}
        total_egress = max(sum(n.egress_bytes for n in self.nodes.values()), 1)
        for s in self.specs:
            cl = self.clusters[s.name]
            egress = sum(n.egress_bytes for n in cl.nodes.values())
            out[s.name] = {
                "route": s.route if isinstance(s.route, str)
                         else s.route_profile().name,
                "rtt": self.routes[s.name].rtt,
                "wan": float(s.is_wan),
                "egress_bytes": egress,
                "egress_share": egress / total_egress,
                "requests": sum(n.requests_served for n in cl.nodes.values()),
                "nodes_down": sum(1 for n in cl.nodes.values() if n.down),
                "n_nodes": s.n_nodes,
            }
        return out


class FederatedConnectionPool:
    """All connections of one training host to every member cluster.

    Mirrors the ``ConnectionPool`` surface the prefetcher and the multi-host
    coordinator consume (``fetch``, ``bytes_received``, ``requests_sent``,
    ``failovers``, ``served_by_node``, ``inflight``), aggregating over one
    sub-pool per member cluster.  Each sub-pool runs the member's own
    ``RouteProfile`` (own RTT, own AIMD bandwidth processes); all sub-pools
    share one client-ingress NIC.
    """

    def __init__(self, clock: Clock, federation: FederatedCluster,
                 io_threads: int = 8, conns_per_thread: int = 2,
                 seed: int = 99, hedge_after: Optional[float] = None,
                 materialize: bool = False,
                 client_ingress_bandwidth: float = NIC_BANDWIDTH,
                 preferred_nodes: Optional[Sequence[str]] = None) -> None:
        self.clock = clock
        self.federation = federation
        self.cluster = federation          # Cluster-surface alias
        self.ingress = RateResource("client/ingress",
                                    client_ingress_bandwidth)
        self.cluster_failovers = 0         # fetches served off-owner
        self.duplicates_suppressed = 0     # late completions the once-guard ate
        # Adaptive flow control: one FlowController per member cluster (each
        # fed by that member's sub-pool over that member's route), summed
        # into the host budget by a FlowControllerGroup.
        self.controller: Optional[FlowControllerGroup] = None
        preferred = list(preferred_nodes or ())
        self.pools: Dict[str, ConnectionPool] = {}
        for i, spec in enumerate(federation.specs):
            # this host's preferred nodes *within* this member cluster
            prefix = f"{spec.name}/"
            local_pref = [n for n in preferred if n.startswith(prefix)]
            self.pools[spec.name] = ConnectionPool(
                clock, federation.clusters[spec.name],
                federation.routes[spec.name],
                io_threads=io_threads, conns_per_thread=conns_per_thread,
                seed=seed + 7919 * i, hedge_after=hedge_after,
                materialize=materialize,
                preferred_nodes=local_pref or None,
                ingress=self.ingress,
                on_exhausted=self._make_exhausted(spec.name))

    def attach_flow_control(self, cfg: FlowControlConfig, batch_size: int,
                            limiter: Optional[SharedIngressLimiter] = None
                            ) -> FlowControllerGroup:
        """One BDP-tracking controller per member cluster — a 150 ms WAN
        member ramps deep while a local member stays shallow — summed into
        the host's in-flight budget.  Idempotent."""
        if self.controller is None:
            members = {}
            for name, pool in self.pools.items():
                ctl = pool.attach_flow_control(cfg, batch_size,
                                               limiter=limiter)
                ctl.name = name            # report by member, not route tier
                members[name] = ctl
            self.controller = FlowControllerGroup(members, batch_size)
        return self.controller

    # -- fetch --------------------------------------------------------------
    def fetch(self, key: _uuid.UUID,
              on_done: Callable) -> None:
        """Route ``key`` to its owning cluster (degraded to a live replica
        cluster when the owner is dark).  Delivery is exactly-once even when
        a hedge in a dying cluster races the cross-cluster failover."""
        state = {"done": False}

        def once(res) -> None:
            if state["done"]:
                self.duplicates_suppressed += 1
                return
            state["done"] = True
            on_done(res)

        owner = self.federation.owner_of(key)
        # total blackout: keep targeting the owner, whose pool backs off and
        # retries (so a recovering cluster is picked up automatically)
        target = self.federation.serving_cluster(key) or owner
        if target != owner:
            self.cluster_failovers += 1
        self.pools[target].fetch(key, once)

    def _make_exhausted(self, cname: str):
        """Cluster-level failover: when every connection to ``cname`` has
        failed for a request, hand it to the next live cluster.  Returns
        False (keep backing off in place) when no other cluster is alive,
        so a total blackout still surfaces as the caller's timeout and a
        recovering cluster is picked up automatically."""
        def handler(key: _uuid.UUID, on_done: Callable) -> bool:
            target = self.federation.serving_cluster(
                key, exclude=frozenset((cname,)))
            if target is None:
                return False
            if target != self.federation.owner_of(key):
                self.cluster_failovers += 1
            self.pools[target].fetch(key, on_done)
            return True
        return handler

    # -- aggregated counters (ConnectionPool surface) ------------------------
    @property
    def bytes_received(self) -> int:
        return sum(p.bytes_received for p in self.pools.values())

    @property
    def requests_sent(self) -> int:
        return sum(p.requests_sent for p in self.pools.values())

    @property
    def failovers(self) -> int:
        return sum(p.failovers for p in self.pools.values())

    @property
    def served_by_node(self) -> Dict[str, int]:
        merged: Dict[str, int] = {}
        for p in self.pools.values():
            for name, count in p.served_by_node.items():
                merged[name] = merged.get(name, 0) + count
        return merged

    @property
    def inflight(self) -> int:
        return sum(p.inflight for p in self.pools.values())

    def throughput_traces(self, window: float = 0.5):
        return {name: p.throughput_traces(window)
                for name, p in self.pools.items()}


__all__ = ["ClusterSpec", "FederatedRing", "FederatedCluster",
           "FederatedConnectionPool", "federated_preferred_subsets",
           "WAN_RTT_THRESHOLD"]
