"""repro.core — the paper's contribution: network data loading with
out-of-order, incremental prefetching over NoSQL storage."""

from .arena import ArenaSlab, PinnedArena
from .batch_loader import AssembledBatch, BatchAssembler
from .cluster import Cluster, TokenRing
from .connection import ConnectionPool, FetchResult
from .federation import (ClusterSpec, FederatedCluster,
                         FederatedConnectionPool, FederatedRing,
                         federated_preferred_subsets)
from .flowctl import (FlowControlConfig, FlowController,
                      FlowControllerGroup, SharedIngressLimiter,
                      merge_snapshots)
from .kvstore import DataRow, KVStore, MetaRow, make_uuid, token_of
from .loader import CassandraLoader, LoaderConfig, consume_with_step_time, tight_loop
from .multihost import MultiHostConfig, MultiHostRun
from .netsim import (BACKENDS, CASSANDRA, SCYLLA, TIERS, Clock, EventHandle,
                     RealClock, RouteProfile, RouteSchedule, VirtualClock,
                     route_bdp_samples)
from .placement import (PLACEMENT_POLICIES, global_order,
                        preferred_node_subsets, replica_local_fraction,
                        split_strips)
from .prefetcher import (EpochPlan, InOrderPrefetcher, OutOfOrderPrefetcher,
                         PrefetchConfig, compute_reflow, make_prefetcher)
from .replication import (SAMPLING_MODES, HotKeyTracker, ReplicaCache,
                          Replication, ReplicationConfig, ZipfPlan)
from .scenarios import (MODES, QUICK_MATRIX, SCENARIOS,
                        OracleDepthController, Scenario, matrix, run_cell)
from .splits import SplitSpec, check_entity_independence, create_splits
from .stack import FEED_KINDS, Stack, build_stack
from .tenancy import QOS_CLASSES, TenantScheduler, TenantSpec
from .wirefmt import (WIRE_CODECS, ByteShuffleCodec, Int8QuantCodec,
                      NoneCodec, WireCodec, get_codec)

__all__ = [
    "ArenaSlab", "PinnedArena",
    "WIRE_CODECS", "WireCodec", "NoneCodec", "ByteShuffleCodec",
    "Int8QuantCodec", "get_codec",
    "AssembledBatch", "BatchAssembler", "Cluster", "TokenRing",
    "ConnectionPool", "FetchResult", "ClusterSpec", "FederatedCluster",
    "FederatedConnectionPool", "FederatedRing",
    "federated_preferred_subsets", "FlowControlConfig", "FlowController",
    "FlowControllerGroup", "SharedIngressLimiter", "merge_snapshots",
    "DataRow", "KVStore", "MetaRow",
    "make_uuid", "token_of", "CassandraLoader", "LoaderConfig",
    "MultiHostConfig", "MultiHostRun",
    "consume_with_step_time", "tight_loop", "BACKENDS", "CASSANDRA", "SCYLLA",
    "TIERS", "Clock", "RealClock", "RouteProfile", "RouteSchedule",
    "route_bdp_samples", "VirtualClock", "EventHandle", "EpochPlan",
    "FEED_KINDS", "Stack", "build_stack",
    "Scenario", "SCENARIOS", "QUICK_MATRIX", "MODES",
    "OracleDepthController", "matrix", "run_cell",
    "compute_reflow", "PLACEMENT_POLICIES", "global_order",
    "preferred_node_subsets", "replica_local_fraction", "split_strips",
    "InOrderPrefetcher", "OutOfOrderPrefetcher", "PrefetchConfig",
    "make_prefetcher", "SAMPLING_MODES", "HotKeyTracker", "ReplicaCache",
    "Replication", "ReplicationConfig", "ZipfPlan", "SplitSpec",
    "check_entity_independence", "create_splits",
    "QOS_CLASSES", "TenantScheduler", "TenantSpec",
]
