"""Deterministic network / storage simulator.

The paper's phenomena are produced by real WAN links (heterogeneous TCP
throughput, congestion, high RTT) and real database nodes (service latency,
GC pauses, disk read amplification).  This container has neither a WAN nor a
database cluster, so we model them explicitly with a discrete-event simulator
that the *actual loader code* runs against: the loader is callback-driven
(as the paper's C++ loader is), and the simulator fires those callbacks either
in virtual time (fast, perfectly reproducible benchmarks) or in real time
(threaded timers; used by the JAX-integration tests and examples).

Key modelled effects, each traceable to a paper observation:
  * per-connection AIMD (CUBIC-like) bandwidth processes with Poisson
    congestion events  -> Fig. 5/6 heterogeneous per-connection throughput;
  * FIFO wire occupancy per connection + shared NIC egress  -> burst overload
    when prefetch buffers are filled eagerly (Sec. 3.4);
  * backend service models (Scylla: shard-per-core, low variance;
    Cassandra: JVM GC pauses + block-read disk amplification)  -> Fig. 7.
"""

from __future__ import annotations

import heapq
import itertools
import math
import threading
import time as _time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from .stats import windowed_series

# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------


class Clock:
    """Abstract clock: schedule callbacks, advance time, block on predicates."""

    def now(self) -> float:
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        raise NotImplementedError

    def run_until(self, predicate: Callable[[], bool], timeout: float = 120.0) -> bool:
        """Advance/wait until ``predicate()`` is true. Returns success."""
        raise NotImplementedError

    def sleep(self, duration: float) -> None:
        deadline = self.now() + duration
        self.schedule(duration, lambda: None)   # wake event: a virtual clock
        # only advances through events, so the deadline must be one.
        self.run_until(lambda: self.now() >= deadline, timeout=duration + 60.0)


class EventHandle:
    """Cancellation handle returned by ``schedule_cancellable``.

    Holds the event record plus the sequence number it was issued under —
    records are recycled through a freelist, so the seq check is what keeps
    a stale handle from cancelling whoever inherited the record."""

    __slots__ = ("_rec", "_seq")

    def __init__(self, rec: list, seq: int) -> None:
        self._rec = rec
        self._seq = seq

    def cancel(self) -> bool:
        """Cancel the event if it has not fired; True if this call killed it.
        A cancelled record stays in its bucket (removing it would cost a
        heap rebuild) and is skipped + recycled when its time comes."""
        rec = self._rec
        if rec is None:
            return False
        self._rec = None
        if rec[1] != self._seq or rec[2] is None:
            return False                      # already fired / recycled
        rec[2] = None
        rec[3] = None
        return True

    @property
    def cancelled(self) -> bool:
        rec = self._rec
        return rec is None or rec[1] != self._seq or rec[2] is None


class VirtualClock(Clock):
    """Single-threaded discrete-event clock. Deterministic and fast.

    Calendar-queue / heap hybrid.  Pop order is exactly ``(time, seq)`` —
    bit-identical to a single binary heap of ``(time, seq, fn, args)``
    tuples (the pre-calendar implementation, still what ``RealClock``
    uses) — but the hot path does O(1)-ish amortized work per event and
    allocates nothing per event in steady state:

    * **Event records are reusable lists** ``[time, seq, fn, args]`` drawn
      from a freelist — ``heapq`` compares them elementwise and ``seq`` is
      unique, so ``fn`` is never reached by a comparison, and unlike tuples
      they can be recycled after firing.
    * **Near-horizon slotted buckets**: a power-of-two ring of
      ``_N_SLOTS`` lists, each covering ``_SLOT_WIDTH`` seconds.  An insert
      into a future bucket is a plain ``list.append``; only inserts into
      the *current* bucket pay a ``heappush``.  A bucket is ``heapify``-ed
      (one C call) when it becomes current, which restores the exact
      ``(time, seq)`` order — equal times always map to the same bucket,
      so cross-bucket order is time order and within-bucket order is the
      heap's.
    * **Lazy far-future heap**: events beyond the ring horizon
      (``_N_SLOTS * _SLOT_WIDTH`` ahead) sit in one overflow heap and
      spill into the ring as the horizon advances past them.  When the
      ring drains empty the clock jumps straight to the overflow head's
      bucket instead of walking empty slots.

    ``events_processed`` counts fired events — the events/sec floor the
    1000-host scale benchmark asserts reads it.
    """

    # 512 buckets x 2 ms = a 1.024 s horizon: covers every RTT tier and
    # transfer time the simulator produces; multi-second timers (hedge
    # delays, scheduled failures, training step sleeps) take the far heap.
    _N_SLOTS = 512
    _SLOT_WIDTH = 0.002

    def __init__(self) -> None:
        self._t = 0.0
        self._seq = 0
        self._width = self._SLOT_WIDTH
        self._inv_width = 1.0 / self._SLOT_WIDTH
        self._mask = self._N_SLOTS - 1
        self._slots: List[list] = [[] for _ in range(self._N_SLOTS)]
        self._bucket0 = 0                      # bucket index of _cur
        self._bucket_hi = self._N_SLOTS        # first bucket beyond the ring
        self._horizon_t = self._N_SLOTS * self._SLOT_WIDTH
        self._cur: list = self._slots[0]       # current bucket, heap-ordered
        self._ring_count = 0                   # events resident in the ring
        self._far: list = []                   # overflow heap, (time, seq) order
        self._free: list = []                  # recycled event records
        self.events_processed = 0
        self._lock = threading.RLock()  # loader code may touch from one thread only,
        # but keep it safe for accidental cross-thread use in tests.

    def now(self) -> float:
        return self._t

    # -- scheduling ---------------------------------------------------------
    def _new_record(self, delay: float, fn: Callable, args: tuple) -> list:
        t = self._t + delay if delay > 0.0 else self._t
        seq = self._seq
        self._seq = seq + 1
        if self._free:
            rec = self._free.pop()
            rec[0] = t
            rec[1] = seq
            rec[2] = fn
            rec[3] = args
        else:
            rec = [t, seq, fn, args]
        if t >= self._horizon_t:               # also catches inf timers
            heapq.heappush(self._far, rec)
        else:
            self._place(rec)
        return rec

    def _place(self, rec: list) -> None:
        """Insert a record with time < horizon into the ring."""
        b = int(rec[0] * self._inv_width)
        if b <= self._bucket0:
            heapq.heappush(self._cur, rec)
        else:
            if b >= self._bucket_hi:           # float boundary: clamp into
                b = self._bucket_hi - 1        # the last ring slot
            self._slots[b & self._mask].append(rec)
        self._ring_count += 1

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        with self._lock:
            self._new_record(delay, fn, args)

    def schedule_cancellable(self, delay: float, fn: Callable,
                             *args) -> EventHandle:
        """Like ``schedule`` but returns a cancellation handle.  Separate
        entry point so the plain hot path never allocates a handle."""
        with self._lock:
            rec = self._new_record(delay, fn, args)
            return EventHandle(rec, rec[1])

    # -- popping ------------------------------------------------------------
    def _pop_live(self):
        """Next live record in exact (time, seq) order, or None.  Cancelled
        records are skipped and recycled without advancing time."""
        free = self._free
        while True:
            cur = self._cur
            while not cur:
                if self._ring_count:
                    # advance one bucket; the horizon gains one bucket too,
                    # so overdue far-heap events spill into the ring
                    b = self._bucket0 + 1
                    self._bucket0 = b
                    self._bucket_hi += 1
                    self._horizon_t += self._width
                    cur = self._cur = self._slots[b & self._mask]
                    heapq.heapify(cur)
                    far = self._far
                    while far and far[0][0] < self._horizon_t:
                        self._place(heapq.heappop(far))
                else:
                    far = self._far
                    if not far:
                        return None
                    t0 = far[0][0]
                    if t0 == math.inf:         # never-firing timers only
                        rec = heapq.heappop(far)
                        if rec[2] is not None:
                            return rec
                        free.append(rec)       # cancelled inf timer
                        continue
                    # ring is empty: jump straight to the far head's bucket
                    b = int(t0 * self._inv_width)
                    self._bucket0 = b
                    self._bucket_hi = b + self._N_SLOTS
                    self._horizon_t = self._bucket_hi * self._width
                    cur = self._cur = self._slots[b & self._mask]
                    while far and far[0][0] < self._horizon_t:
                        self._place(heapq.heappop(far))
            rec = heapq.heappop(cur)
            self._ring_count -= 1
            if rec[2] is not None:
                return rec
            free.append(rec)                   # cancelled: recycle, no fire

    def step(self) -> bool:
        """Fire the next event. Returns False if none pending."""
        with self._lock:
            rec = self._pop_live()
            if rec is None:
                return False
            t = rec[0]
            if t > self._t:
                self._t = t
            fn = rec[2]
            args = rec[3]
            rec[2] = None
            rec[3] = None
            self._free.append(rec)
            self.events_processed += 1
        fn(*args)
        return True

    def run_until(self, predicate: Callable[[], bool], timeout: float = 120.0) -> bool:
        # timeout is in *virtual* seconds to keep benchmarks deterministic.
        deadline = self._t + timeout
        while not predicate():
            if self._t > deadline or not self.step():
                return predicate()
        return True

    def drain(self, max_events: int = 100_000_000) -> None:
        n = 0
        while self.step():
            n += 1
            if n >= max_events:
                raise RuntimeError("virtual clock drain exceeded event budget")


class RealClock(Clock):
    """Wall-clock implementation backed by a timer thread."""

    def __init__(self) -> None:
        self._heap: list = []
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._stop = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._epoch = _time.monotonic()
        self._thread.start()

    def now(self) -> float:
        return _time.monotonic() - self._epoch

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        with self._cv:
            heapq.heappush(self._heap, (self.now() + max(delay, 0.0), next(self._seq), fn, args))
            self._cv.notify_all()

    def _loop(self) -> None:
        while True:
            with self._cv:
                if self._stop:
                    return
                if not self._heap:
                    self._cv.wait(timeout=0.05)
                    continue
                t, _, fn, args = self._heap[0]
                dt = t - self.now()
                if dt > 0:
                    self._cv.wait(timeout=min(dt, 0.05))
                    continue
                heapq.heappop(self._heap)
            try:
                fn(*args)
            except Exception:  # pragma: no cover - surfaced via stats in tests
                import traceback

                traceback.print_exc()
            with self._cv:
                self._cv.notify_all()

    def run_until(self, predicate: Callable[[], bool], timeout: float = 120.0) -> bool:
        deadline = _time.monotonic() + timeout
        with self._cv:
            while not predicate():
                remaining = deadline - _time.monotonic()
                if remaining <= 0:
                    return predicate()
                self._cv.wait(timeout=min(remaining, 0.05))
        return True

    def notify(self) -> None:
        with self._cv:
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=1.0)


# ---------------------------------------------------------------------------
# Latency tiers (paper Sec. 4.2) + time-varying route schedules
# ---------------------------------------------------------------------------


# Deterministic random-walk streams for RouteSchedule(kind="random_walk"):
# cumulative standard-normal walks, generated in blocks and cached per seed so
# a frozen schedule can be sampled at arbitrary times in O(1) without carrying
# mutable state.  Block RNGs are seeded by (salt, seed, block) so extending
# the cache never changes earlier values.
_WALK_SALT = 0x52575357  # "RWSW"
_WALK_BLOCK = 1024
_WALK_CACHE: dict = {}


def _walk_level(seed: int, k: int) -> float:
    """Value of walk ``seed`` after ``k`` unit steps (k=0 -> 0.0)."""
    if k <= 0:
        return 0.0
    cum = _WALK_CACHE.get(seed)
    if cum is None:
        cum = [0.0]
        _WALK_CACHE[seed] = cum
    while len(cum) <= k:
        block = len(cum) // _WALK_BLOCK
        rng = np.random.default_rng((_WALK_SALT, seed, block))
        for step in rng.standard_normal(_WALK_BLOCK):
            cum.append(cum[-1] + float(step))
    return cum[k]


SCHEDULE_PARAMS = ("bandwidth", "latency", "loss")
SCHEDULE_KINDS = ("step", "ramp", "sinusoid", "random_walk")


@dataclass(frozen=True)
class RouteSchedule:
    """One time-varying term of a route parameter.

    A schedule is a pure function of time returning a multiplier applied to
    the route's static ``param`` ("bandwidth" scales the per-connection
    capacity ceiling, "latency" scales the RTT, "loss" scales the congestion
    event rate).  Multiple schedules on the same parameter compose by
    multiplication.  Kinds:

    * ``step``     — ``factor`` on ``[at, until)``, 1.0 outside (link
      degradation with a known end, e.g. a maintenance window);
    * ``ramp``     — linear from 1.0 at ``at`` to ``factor`` at ``until``,
      holding ``factor`` afterwards (slow congestion onset);
    * ``sinusoid`` — ``1 + amplitude * sin(2*pi*(t - phase)/period)``
      (diurnal-style oscillation);
    * ``random_walk`` — ``exp(sigma * W(t / interval))`` for a standard
      normal walk ``W`` seeded by ``seed`` (deterministic; same seed + time
      always gives the same multiplier).

    Multipliers are clamped to ``[MIN_MULT, MAX_MULT]`` so no schedule can
    drive a parameter to zero or infinity — outages are modelled separately
    as ``RouteProfile.outages`` windows, not as zero bandwidth.
    """

    param: str                       # "bandwidth" | "latency" | "loss"
    kind: str                        # "step" | "ramp" | "sinusoid" | "random_walk"
    factor: float = 1.0              # step/ramp target multiplier
    at: float = 0.0                  # step/ramp start time, s
    until: float = math.inf          # step end / ramp completion time, s
    period: float = 60.0             # sinusoid period, s
    amplitude: float = 0.0           # sinusoid relative swing, |a| < 1
    phase: float = 0.0               # sinusoid time offset, s
    sigma: float = 0.25              # random-walk per-step log deviation
    interval: float = 1.0            # random-walk step duration, s
    seed: int = 0                    # random-walk stream seed

    MIN_MULT = 0.02
    MAX_MULT = 50.0

    def __post_init__(self) -> None:
        if self.param not in SCHEDULE_PARAMS:
            raise ValueError(f"param must be one of {SCHEDULE_PARAMS}")
        if self.kind not in SCHEDULE_KINDS:
            raise ValueError(f"kind must be one of {SCHEDULE_KINDS}")
        if self.factor <= 0:
            raise ValueError("factor must be > 0")
        if self.kind == "ramp" and not (math.isfinite(self.until)
                                        and self.until > self.at):
            raise ValueError("ramp needs a finite until > at")
        if self.until <= self.at and self.kind == "step":
            raise ValueError("step needs until > at")
        if self.period <= 0:
            raise ValueError("period must be > 0")
        if abs(self.amplitude) >= 1.0:
            raise ValueError("|amplitude| must be < 1")
        if self.sigma < 0 or self.interval <= 0:
            raise ValueError("sigma must be >= 0 and interval > 0")

    def multiplier(self, t: float) -> float:
        if self.kind == "step":
            m = self.factor if self.at <= t < self.until else 1.0
        elif self.kind == "ramp":
            if t <= self.at:
                m = 1.0
            elif t >= self.until:
                m = self.factor
            else:
                frac = (t - self.at) / (self.until - self.at)
                m = 1.0 + (self.factor - 1.0) * frac
        elif self.kind == "sinusoid":
            m = 1.0 + self.amplitude * math.sin(
                2.0 * math.pi * (t - self.phase) / self.period)
        else:  # random_walk
            m = math.exp(self.sigma * _walk_level(self.seed,
                                                  int(t / self.interval)))
        return min(max(m, self.MIN_MULT), self.MAX_MULT)


@dataclass(frozen=True)
class RouteProfile:
    """One client<->server route, mirroring the paper's experimental tiers.

    Static by default; attach ``schedules`` / ``outages`` to make the route
    time-varying.  ``SimConnection`` and ``AIMDBandwidth`` sample the
    multipliers at *event time* (never at connection setup), so a route's
    behaviour under a schedule is a property of the clock, not of when the
    connection happened to be created.  Routes with no schedules and no
    outages take exactly the pre-schedule code paths (bit-identical runs).
    """

    name: str
    rtt: float                      # round-trip time, seconds
    conn_capacity: float            # per-TCP-stream ceiling, bytes/s
    loss_per_byte: float            # Poisson congestion-event rate, events/byte
    loss_spread: float = 4.0        # log-uniform spread of per-connection loss
    jitter: float = 0.05            # relative latency jitter
    # Time-correlated congestion (paper Fig. 5: some routes congested for
    # sustained periods): Markov on/off bursts multiplying the loss rate.
    burst_factor: float = 1.0       # loss multiplier while congested
    burst_on_mean: float = 0.0      # mean congested duration, s
    burst_off_mean: float = float("inf")  # mean clear duration, s
    # Time-varying dynamics (empty = static route).
    schedules: Tuple[RouteSchedule, ...] = ()
    outages: Tuple[Tuple[float, float], ...] = ()  # (start, duration), s

    def __post_init__(self) -> None:
        # Tolerate lists from declarative configs; store as hashable tuples.
        if not isinstance(self.schedules, tuple):
            object.__setattr__(self, "schedules", tuple(self.schedules))
        if not isinstance(self.outages, tuple):
            object.__setattr__(self, "outages",
                               tuple((float(s), float(d))
                                     for s, d in self.outages))
        for start, duration in self.outages:
            if duration <= 0:
                raise ValueError("outage duration must be > 0")

    @property
    def is_static(self) -> bool:
        return not self.schedules and not self.outages

    def multiplier(self, param: str, t: float) -> float:
        m = 1.0
        for s in self.schedules:
            if s.param == param:
                m *= s.multiplier(t)
        return m

    def bandwidth_multiplier(self, t: float) -> float:
        return self.multiplier("bandwidth", t)

    def latency_multiplier(self, t: float) -> float:
        return self.multiplier("latency", t)

    def loss_multiplier(self, t: float) -> float:
        return self.multiplier("loss", t)

    def down_at(self, t: float) -> bool:
        return any(start <= t < start + duration
                   for start, duration in self.outages)


# Paper: Oregon / N.California / Stockholm from an Oregon p4d.24xlarge
# (public NIC 50 Gb/s = 6.25e9 B/s).  Per-stream ceilings and loss rates are
# chosen so the simulator reproduces the paper's measured aggregates
# (see benchmarks/bench_tightloop.py).
TIERS = {
    "local": RouteProfile("local", rtt=0.00005, conn_capacity=2.0e9, loss_per_byte=0.0),
    "low": RouteProfile("low", rtt=0.0008, conn_capacity=1.0e9, loss_per_byte=1e-11),
    "med": RouteProfile("med", rtt=0.020, conn_capacity=0.7e9, loss_per_byte=5e-11,
                        burst_factor=10.0, burst_on_mean=2.0, burst_off_mean=60.0),
    # Clear-state AIMD equilibrium ~= sqrt(incr / (0.3*lpb*rtt)) ~= 370 MB/s
    # per stream; Markov congestion bursts (~20% duty) drop a stream to
    # ~40 MB/s (random-walking toward the 5 MB/s floor) for seconds at a
    # time — the sustained stragglers of Fig. 5 that gate in-order assembly.
    "high": RouteProfile("high", rtt=0.150, conn_capacity=0.5e9,
                         loss_per_byte=4e-10, loss_spread=6.0,
                         burst_factor=100.0, burst_on_mean=5.0,
                         burst_off_mean=20.0),
}

NIC_BANDWIDTH = 6.25e9  # 50 Gb/s public interface, bytes/s


# ---------------------------------------------------------------------------
# AIMD per-connection bandwidth process
# ---------------------------------------------------------------------------


class AIMDBandwidth:
    """CUBIC-flavoured AIMD rate process, advanced per transfer.

    Congestion events arrive as a Poisson process in bytes sent; each event
    multiplies the rate by ``beta``; otherwise the rate grows additively per
    RTT (so high-RTT routes recover slowly, as the paper observes citing
    [13, 8]).
    """

    def __init__(self, rng: np.random.Generator, route: RouteProfile,
                 congestion_scale: float = 1.0) -> None:
        self._rng = rng
        self._route = route
        # Heterogeneous routes: some connections traverse congested paths.
        spread = route.loss_spread
        self._loss_per_byte = route.loss_per_byte * congestion_scale * float(
            np.exp(rng.uniform(-np.log(spread), np.log(spread))))
        self.capacity = route.conn_capacity * float(rng.uniform(0.85, 1.0))
        self.rate = self.capacity * (0.5 if route.loss_per_byte > 0 else 1.0)
        self._beta = 0.7
        # additive increase per RTT: reach capacity in ~200 RTTs from half.
        self._incr_per_rtt = self.capacity / 200.0
        self._dynamic = not route.is_static
        # Markov congestion state
        self._congested = False
        self._t_switch = (rng.exponential(route.burst_off_mean)
                          if np.isfinite(route.burst_off_mean) else float("inf"))

    def _advance_state(self, now: float) -> None:
        route = self._route
        while now >= self._t_switch:
            self._congested = not self._congested
            mean = route.burst_on_mean if self._congested else route.burst_off_mean
            self._t_switch += float(self._rng.exponential(max(mean, 1e-9)))

    def transfer_seconds(self, nbytes: int, now: float = 0.0,
                         backlog_rtts: float = 0.0) -> float:
        """Advance the process by one transfer of ``nbytes``; return duration.

        ``backlog_rtts``: queueing delay ahead of this transfer in RTT units.
        Deep queues (bufferbloat from request bursts) raise the drop
        probability — the paper's Sec. 3.4 burst-overload effect that the
        incremental prefetch ramp avoids."""
        if nbytes <= 0:
            return 0.0
        self._advance_state(now)
        if self._dynamic:
            # Sample the route state at event time: a bandwidth schedule caps
            # the usable rate for this transfer (the AIMD state itself is
            # untouched, so the link recovers instantly when the cap lifts),
            # a loss schedule scales the congestion-event rate, and a latency
            # schedule stretches the RTT the additive increase is paced by.
            cap_t = self.capacity * self._route.bandwidth_multiplier(now)
            rate_eff = min(self.rate, cap_t)
            rtt_eff = self._route.rtt * self._route.latency_multiplier(now)
            loss_mult = self._route.loss_multiplier(now)
        else:
            rate_eff = self.rate
            rtt_eff = self._route.rtt
            loss_mult = 1.0
        t = nbytes / rate_eff
        lpb = self._loss_per_byte * (self._route.burst_factor if self._congested
                                     else 1.0) * loss_mult
        if backlog_rtts > 2.0:
            lpb *= 1.0 + 0.4 * (backlog_rtts - 2.0)
        if lpb > 0.0:
            events = self._rng.poisson(lpb * nbytes)
            if events > 0:
                self.rate = max(self.rate * (self._beta ** min(events, 8)),
                                self.capacity * 0.01)
            else:
                rtts = t / max(rtt_eff, 1e-6)
                self.rate = min(self.rate + self._incr_per_rtt * rtts, self.capacity)
        return t


# ---------------------------------------------------------------------------
# Shared FIFO resources (NIC egress, disks, node CPU)
# ---------------------------------------------------------------------------


class FifoResource:
    """A serial resource: work items occupy it back-to-back.

    ``acquire(t, seconds)`` returns the completion time of a job arriving at
    ``t`` that needs the resource for ``seconds``.

    Pure float bookkeeping — no clock events, no allocation — and slotted:
    at 1000-host scale a run holds tens of thousands of these (one wire
    FIFO per connection), so the per-instance dict is worth dropping.
    """

    __slots__ = ("name", "_busy_until", "busy_seconds")

    def __init__(self, name: str) -> None:
        self.name = name
        self._busy_until = 0.0
        self.busy_seconds = 0.0

    def acquire(self, t: float, seconds: float) -> float:
        start = max(t, self._busy_until)
        self._busy_until = start + seconds
        self.busy_seconds += seconds
        return self._busy_until

    @property
    def busy_until(self) -> float:
        return self._busy_until


class RateResource:
    """A shared bandwidth pipe approximated as FIFO at a fixed rate."""

    __slots__ = ("fifo", "rate", "bytes_total")

    def __init__(self, name: str, rate: float) -> None:
        self.fifo = FifoResource(name)
        self.rate = rate
        self.bytes_total = 0

    def acquire(self, t: float, nbytes: int) -> float:
        self.bytes_total += nbytes
        return self.fifo.acquire(t, nbytes / self.rate)


# ---------------------------------------------------------------------------
# Backend service models (paper Sec. 2.3 / Fig. 7)
# ---------------------------------------------------------------------------


@dataclass
class BackendModel:
    """Performance model of a Cassandra-compatible storage node."""

    name: str
    base_service: float            # median per-request service time, s
    service_sigma: float           # lognormal sigma of service time
    read_amplification: float      # disk bytes read per payload byte
    gc_rate: float                 # GC pauses per second (0 = none)
    gc_pause: float                # mean GC pause duration, s
    disk_efficiency: float = 1.0   # fraction of raw NVMe bw its access pattern gets

    def service_seconds(self, rng: np.random.Generator) -> float:
        return float(self.base_service * rng.lognormal(0.0, self.service_sigma))


# Calibrated so the tight-loop benchmark reproduces the paper's Fig. 7:
# ScyllaDB ~4.0 GB/s vs Cassandra ~1.6 GB/s at the high-latency tier, with
# Cassandra's disk I/O ~2.25x its network throughput (block-read strategy) and
# its small-chunk access pattern extracting less of the striped NVMe bandwidth.
SCYLLA = BackendModel("scylla", base_service=0.0004, service_sigma=0.3,
                      read_amplification=1.0, gc_rate=0.0, gc_pause=0.0,
                      disk_efficiency=1.0)
CASSANDRA = BackendModel("cassandra", base_service=0.0011, service_sigma=0.8,
                         read_amplification=2.25, gc_rate=2.0, gc_pause=0.060,
                         disk_efficiency=0.45)

BACKENDS = {"scylla": SCYLLA, "cassandra": CASSANDRA}

DISK_BANDWIDTH = 8.0e9  # 4x NVMe striped volume, bytes/s (paper: 7.4 GB/s observed)

# Mean of AIMDBandwidth's per-connection capacity draw (uniform 0.85-1.0) —
# what an analytic "expected bottleneck rate" should multiply capacities by.
EXPECTED_CONN_CAPACITY_DRAW = 0.925


def route_bdp_samples(route: "RouteProfile | str", n_conns: int,
                      sample_bytes: float,
                      backend: "BackendModel" = None,
                      t: Optional[float] = None) -> float:
    """True route BDP in *samples*, from first principles (the analytic
    yardstick the flow-control tests and benchmarks measure the controller
    against — not the controller's own estimate): expected bottleneck rate
    (connections, client NIC, node disk) times the effective round trip
    (propagation + median service + one transfer).

    With ``t`` given, any route schedules are applied at that instant — the
    schedule-aware *oracle* BDP that ``bench_scenarios`` compares the
    adaptive controller against.  Callers should treat outage windows
    (``prof.down_at(t)``) separately: the BDP of a down link is moot."""
    prof = TIERS[route] if isinstance(route, str) else route
    bw_mult = lat_mult = 1.0
    if t is not None and not prof.is_static:
        bw_mult = prof.bandwidth_multiplier(t)
        lat_mult = prof.latency_multiplier(t)
    backend = backend or SCYLLA
    conn_cap = prof.conn_capacity * bw_mult
    rate_Bps = min(n_conns * conn_cap * EXPECTED_CONN_CAPACITY_DRAW,
                   NIC_BANDWIDTH, DISK_BANDWIDTH)
    rtt_eff = (prof.rtt * lat_mult + backend.base_service
               + sample_bytes / conn_cap)
    return rate_Bps / sample_bytes * rtt_eff


# ---------------------------------------------------------------------------
# Simulated server node + TCP connection
# ---------------------------------------------------------------------------


class SimServerNode:
    """One storage node: CPU service + striped disk + NIC egress.

    A node can be taken *down* (failure injection for multi-host runs): while
    down it serves nothing — in-flight requests that reach it fail, and the
    client side is expected to fail over to another replica.
    """

    def __init__(self, name: str, backend: BackendModel, rng: np.random.Generator,
                 disk_bandwidth: float = DISK_BANDWIDTH,
                 egress_bandwidth: float = NIC_BANDWIDTH,
                 cpu_cores: int = 0) -> None:
        self.name = name
        self.backend = backend
        self._rng = rng
        self.disk = RateResource(f"{name}/disk",
                                 disk_bandwidth * backend.disk_efficiency)
        self.egress = RateResource(f"{name}/egress", egress_bandwidth)
        # Wire-codec encode pool (core/wirefmt.py): ``cpu_cores`` parallel
        # encode workers modelled as one FIFO carrying 1/cores of each job's
        # single-core seconds (aggregate throughput = cores x codec rate)
        # while serve() adds the full single-core seconds as latency.  0
        # cores defers to the caller's default at serve time.
        self.cpu = FifoResource(f"{name}/cpu")
        self.cpu_cores = cpu_cores
        self.encode_cpu_seconds = 0.0      # true core-seconds spent encoding
        self._gc_until = 0.0
        self._next_gc = (self._rng.exponential(1.0 / backend.gc_rate)
                         if backend.gc_rate > 0 else float("inf"))
        self.down = False
        self.requests_served = 0

    def fail(self) -> None:
        self.down = True

    def recover(self) -> None:
        self.down = False

    def serve(self, t: float, nbytes: int, wire_bytes: Optional[int] = None,
              encode_seconds: float = 0.0) -> float:
        """Return the time at which the response starts leaving the node.

        With a wire codec active the disk still reads *raw* bytes (storage
        holds rows uncompressed; encoding happens at send time), the encode
        burns ``encode_seconds`` of one CPU core (serialized through the
        node's encode pool at ``1/cpu_cores`` weight, so aggregate encode
        throughput caps at ``cores x codec rate``), and the egress NIC
        carries the *encoded* ``wire_bytes``.  The default arguments take
        exactly the pre-codec path — zero extra resource touches.
        """
        # JVM GC model: periodic stop-the-world pauses that delay everything.
        if self.backend.gc_rate > 0 and t >= self._next_gc:
            pause = self._rng.exponential(self.backend.gc_pause)
            self._gc_until = max(self._gc_until, self._next_gc + pause)
            self._next_gc += self._rng.exponential(1.0 / self.backend.gc_rate)
        t = max(t, self._gc_until)
        t += self.backend.service_seconds(self._rng)
        disk_bytes = int(nbytes * self.backend.read_amplification)
        t = self.disk.acquire(t, disk_bytes)
        if encode_seconds > 0.0:
            from .wirefmt import NODE_CODEC_CORES
            cores = self.cpu_cores or NODE_CODEC_CORES
            self.encode_cpu_seconds += encode_seconds
            t = max(self.cpu.acquire(t, encode_seconds / cores),
                    t + encode_seconds)
        self.requests_served += 1
        return self.egress.acquire(t, wire_bytes if wire_bytes is not None
                                   else nbytes)

    @property
    def disk_bytes(self) -> int:
        return self.disk.bytes_total

    @property
    def egress_bytes(self) -> int:
        return self.egress.bytes_total


class SimConnection:
    """One TCP connection: request fan-out, FIFO wire, AIMD bandwidth.

    A request dispatched at ``t`` completes at
        max(t + rtt/2 + server service/disk/egress, wire free) + payload/bw + rtt/2
    The per-connection wire FIFO is what makes slow connections *straggle*
    (their queue grows), which is precisely the effect OOO prefetching hides.
    """

    MAX_INFLIGHT = 1024  # paper Sec. 3.3

    def __init__(self, conn_id: int, clock: Clock, node: SimServerNode,
                 route: RouteProfile, rng: np.random.Generator,
                 client_ingress: RateResource) -> None:
        self.conn_id = conn_id
        self._clock = clock
        self._node = node
        self._route = route
        self._dynamic = not route.is_static
        self._rng = rng
        self._bw = AIMDBandwidth(rng, route)
        self._wire = FifoResource(f"conn{conn_id}/wire")
        self._client_ingress = client_ingress
        self.inflight = 0
        self.bytes_done = 0
        self.failed_requests = 0
        self._pending: list = []  # queued beyond MAX_INFLIGHT
        self.trace: List = []  # (t_done, nbytes) for Fig. 5/6 style traces

    @property
    def node_name(self) -> str:
        return self._node.name

    @property
    def node_down(self) -> bool:
        return self._node.down

    def request(self, nbytes: int, on_done: Callable[[float], None],
                on_fail: Optional[Callable[[float], None]] = None,
                wire_bytes: Optional[int] = None,
                encode_seconds: float = 0.0) -> None:
        """Fetch ``nbytes`` of payload.  With a wire codec active the caller
        passes the *encoded* ``wire_bytes`` (what egress/wire/ingress carry
        and ``bytes_done`` counts) plus the node-side ``encode_seconds``;
        the defaults are the exact pre-codec path."""
        if self.inflight >= self.MAX_INFLIGHT:
            self._pending.append((nbytes, on_done, on_fail,
                                  wire_bytes, encode_seconds))
            return
        self._dispatch(nbytes, on_done, on_fail, wire_bytes, encode_seconds)

    def _dispatch(self, nbytes: int, on_done: Callable[[float], None],
                  on_fail: Optional[Callable[[float], None]] = None,
                  wire_bytes: Optional[int] = None,
                  encode_seconds: float = 0.0) -> None:
        # Staged events so every shared resource (disk, NIC egress, wire,
        # client ingress) is acquired in true arrival order — a FIFO advanced
        # with out-of-order timestamps would inflate queue waits.
        self.inflight += 1
        jitter = 1.0 + self._route.jitter * float(self._rng.uniform(-1.0, 1.0))
        self._clock.schedule(self._half_rtt(jitter),
                             self._at_server, nbytes, on_done, on_fail, jitter,
                             wire_bytes, encode_seconds)

    def _half_rtt(self, jitter: float) -> float:
        """Half-RTT flight time, sampling any latency schedule at event time."""
        rtt = self._route.rtt
        if self._dynamic:
            rtt *= self._route.latency_multiplier(self._clock.now())
        return 0.5 * rtt * jitter

    def _at_server(self, nbytes: int, on_done, on_fail, jitter: float,
                   wire_bytes: Optional[int] = None,
                   encode_seconds: float = 0.0) -> None:
        if self._node.down or (self._dynamic
                               and self._route.down_at(self._clock.now())):
            # Connection reset (node down, or the route is inside a scheduled
            # outage window): the error travels back one half-RTT; the caller
            # (ConnectionPool) is responsible for failing over / retrying.
            self._clock.schedule(self._half_rtt(jitter),
                                 self._fail, on_fail)
            return
        t = self._clock.now()
        # service + disk (+ codec encode CPU) + NIC egress; downstream stages
        # (wire FIFO, AIMD transfer, client ingress) carry the encoded bytes.
        t_out = self._node.serve(t, nbytes, wire_bytes, encode_seconds)
        w = wire_bytes if wire_bytes is not None else nbytes
        self._clock.schedule(t_out - t, self._at_wire, w, on_done, jitter)

    def _fail(self, on_fail: Optional[Callable[[float], None]]) -> None:
        self.inflight -= 1
        self.failed_requests += 1
        self._drain_pending()
        if on_fail is not None:
            on_fail(self._clock.now())

    def _at_wire(self, nbytes: int, on_done, jitter: float) -> None:
        t = self._clock.now()
        backlog = (max(self._wire.busy_until - t, 0.0)
                   + max(self._client_ingress.fifo.busy_until - t, 0.0))
        dt = self._bw.transfer_seconds(
            nbytes, t, backlog_rtts=backlog / max(self._route.rtt, 1e-6))
        t_sent = self._wire.acquire(t, dt)
        self._clock.schedule(t_sent - t, self._at_ingress, nbytes, on_done, jitter)

    def _at_ingress(self, nbytes: int, on_done, jitter: float) -> None:
        t = self._clock.now()
        t_recv = self._client_ingress.acquire(t, nbytes)
        t_done = t_recv + self._half_rtt(jitter)   # response flight tail
        self._clock.schedule(t_done - t, self._complete, nbytes, on_done)

    def _complete(self, nbytes: int, on_done: Callable[[float], None]) -> None:
        self.inflight -= 1
        self.bytes_done += nbytes
        now = self._clock.now()
        self.trace.append((now, nbytes))
        self._drain_pending()
        on_done(now)

    def _drain_pending(self) -> None:
        if self._pending and self.inflight < self.MAX_INFLIGHT:
            nb, cb, fb, wb, enc = self._pending.pop(0)
            self._dispatch(nb, cb, fb, wb, enc)

    def throughput_series(self, window: float = 0.5):
        """Windowed throughput trace (t, bytes/s) — reproduces Fig. 5/6."""
        return windowed_series(self.trace, window)


__all__ = [
    "Clock", "VirtualClock", "RealClock", "EventHandle",
    "RouteProfile", "RouteSchedule",
    "SCHEDULE_PARAMS", "SCHEDULE_KINDS", "TIERS",
    "AIMDBandwidth", "FifoResource", "RateResource", "BackendModel",
    "SCYLLA", "CASSANDRA", "BACKENDS", "SimServerNode", "SimConnection",
    "NIC_BANDWIDTH", "DISK_BANDWIDTH", "EXPECTED_CONN_CAPACITY_DRAW",
    "route_bdp_samples",
]
