"""Automatic split creation from metadata (paper Sec. 3.2).

Splits are lists of UUIDs generated from the ``metadata`` table under two
constraints:
  * entity independence — all samples of one entity (patient, session, ...)
    land in the same split (no leakage);
  * target proportions — both split fractions and per-class balance are
    matched as closely as entity granularity allows.

Greedy balanced assignment: entities are processed in seeded-shuffled order
(largest first for better packing) and each is assigned to the split that
minimizes a weighted deviation from the split-size and class-mix targets.
"""

from __future__ import annotations

import uuid as _uuid
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .kvstore import MetaRow


@dataclass
class SplitSpec:
    fractions: Sequence[float]                  # e.g. (0.8, 0.1, 0.1)
    names: Optional[Sequence[str]] = None
    class_weights: Optional[Dict[int, float]] = None  # target class mix (all splits)
    seed: int = 0

    def __post_init__(self) -> None:
        tot = float(sum(self.fractions))
        self.fractions = [f / tot for f in self.fractions]
        if self.names is None:
            base = ["train", "val", "test", "extra"]
            self.names = [base[i] if i < len(base) else f"split{i}"
                          for i in range(len(self.fractions))]


def create_splits(meta_rows: List[MetaRow], spec: SplitSpec
                  ) -> Dict[str, List[_uuid.UUID]]:
    """Return {split_name: [uuid, ...]} satisfying the constraints."""
    by_entity: Dict[str, List[MetaRow]] = defaultdict(list)
    for row in meta_rows:
        by_entity[row.entity_id].append(row)

    entities = list(by_entity.keys())
    rng = np.random.default_rng(spec.seed)
    rng.shuffle(entities)
    entities.sort(key=lambda e: -len(by_entity[e]))  # stable: big groups first

    n_splits = len(spec.fractions)
    total = len(meta_rows)
    split_counts = np.zeros(n_splits)
    classes = sorted({r.label for r in meta_rows})
    cls_index = {c: i for i, c in enumerate(classes)}
    split_cls = np.zeros((n_splits, len(classes)))
    if spec.class_weights:
        w = np.asarray([spec.class_weights.get(c, 0.0) for c in classes])
        target_mix = w / max(w.sum(), 1e-12)
    else:
        counts = np.zeros(len(classes))
        for r in meta_rows:
            counts[cls_index[r.label]] += 1
        target_mix = counts / counts.sum()

    fracs = np.asarray(spec.fractions)
    target_counts = np.maximum(fracs * total, 1e-9)
    target_cls_counts = np.maximum(np.outer(fracs, target_mix) * total, 1e-9)

    out: Dict[str, List[_uuid.UUID]] = {name: [] for name in spec.names}
    for ent in entities:
        rows = by_entity[ent]
        ent_cls = np.zeros(len(classes))
        for r in rows:
            ent_cls[cls_index[r.label]] += 1
        # assign to the split with the largest *relative deficit* — this fills
        # all splits proportionally; the class term steers entities toward
        # splits whose class mix they improve.
        best, best_score = 0, -float("inf")
        ent_frac = ent_cls / len(rows)
        for s in range(n_splits):
            rel_deficit = (target_counts[s] - split_counts[s]) / target_counts[s]
            rel_cls_def = (target_cls_counts[s] - split_cls[s]) / target_cls_counts[s]
            score = rel_deficit + 0.5 * float(ent_frac @ rel_cls_def)
            if score > best_score:
                best, best_score = s, score
        split_counts[best] += len(rows)
        split_cls[best] += ent_cls
        out[spec.names[best]].extend(r.uuid for r in rows)

    import zlib

    for name in out:  # deterministic within-split shuffle
        rng_s = np.random.default_rng((spec.seed, zlib.crc32(name.encode())))
        order = rng_s.permutation(len(out[name]))
        out[name] = [out[name][i] for i in order]
    return out


def check_entity_independence(meta_rows: List[MetaRow],
                              splits: Dict[str, List[_uuid.UUID]]) -> bool:
    owner: Dict[str, str] = {}
    by_uuid = {r.uuid: r for r in meta_rows}
    for name, uuids in splits.items():
        for u in uuids:
            ent = by_uuid[u].entity_id
            if owner.setdefault(ent, name) != name:
                return False
    return True


__all__ = ["SplitSpec", "create_splits", "check_entity_independence"]
