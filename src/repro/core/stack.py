"""One-call stack construction: config object -> running data stack.

Nine PRs of growth left every bench, example, and test hand-wiring the same
chain — ``Cluster``/``FederatedCluster`` -> ``ConnectionPool`` ->
``CassandraLoader`` -> ``DeviceFeed``/``ImageFeed`` — each slightly
differently.  :func:`build_stack` is the one blessed spelling:

    from repro.core import LoaderConfig, build_stack

    stack = build_stack(store=store, uuids=uuids,
                        config=LoaderConfig(route="high", materialize=True),
                        feed="device", seq_len=64)
    batch, meta = next(stack.feed)
    ...
    stack.close()

The config object decides the shape of the stack:

* a :class:`~repro.core.loader.LoaderConfig` builds the single-host chain
  (clock -> cluster -> pool -> loader, plus an optional feed); the loader's
  own defaulting is reused, so a ``build_stack`` stack is bit-identical to
  the equivalent hand-wired one;
* a :class:`~repro.core.multihost.MultiHostConfig` builds a
  :class:`~repro.core.multihost.MultiHostRun` — N sharded loaders against
  one shared cluster or a federation (``clusters=`` gives a
  ``FederatedCluster`` with per-member routes/rings/RF).

Everything is keyword-only and validated up front: unknown feed kinds,
missing feed parameters, or feed requests that the config cannot serve
(token feeds need ``materialize=True``; per-host feeds over a
``MultiHostConfig`` are not built here) raise ``ValueError``/``TypeError``
at construction, not deep inside the first ``next_batch``.

Old hand-wiring keeps working — this module only composes public
constructors and adds no behaviour of its own.
"""

from __future__ import annotations

import uuid as _uuid
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from .kvstore import KVStore
from .loader import CassandraLoader, LoaderConfig
from .multihost import MultiHostConfig, MultiHostRun
from .netsim import Clock

FEED_KINDS = (None, "device", "image")


@dataclass
class Stack:
    """What :func:`build_stack` returns — every layer, individually usable.

    ``loader``/``feed`` are populated for a ``LoaderConfig`` stack, ``run``
    for a ``MultiHostConfig`` stack; the rest are always present (for a
    multi-host stack, ``loaders`` lists every per-host loader and
    ``cluster``/``pool`` refer to host 0's view).
    """

    config: "LoaderConfig | MultiHostConfig"
    clock: Clock
    cluster: object
    pool: object
    loader: Optional[CassandraLoader] = None
    feed: Optional[object] = None
    run: Optional[MultiHostRun] = None
    loaders: List[CassandraLoader] = field(default_factory=list)

    def next_batch(self, timeout: float = 600.0):
        """Single-host convenience passthrough to the loader."""
        if self.loader is None:
            raise RuntimeError("next_batch() is a single-host convenience; "
                               "use stack.run for a MultiHostConfig stack")
        return self.loader.next_batch(timeout=timeout)

    def close(self) -> None:
        for ld in (self.loaders or
                   ([self.loader] if self.loader is not None else [])):
            ld.close()


def _build_feed(kind: str, loader: CassandraLoader, *,
                seq_len: Optional[int],
                image_shape: Optional[Tuple[int, int, int]],
                out_shape: Optional[Tuple[int, int]],
                feed_prefetch: int, step_stats, mean, std, feed_seed: int):
    from repro.data.pipeline import DeviceFeed, ImageFeed
    if kind == "device":
        if seq_len is None:
            raise ValueError("feed='device' needs seq_len=")
        return DeviceFeed(loader, seq_len, prefetch=feed_prefetch,
                          step_stats=step_stats)
    if seq_len is not None:
        raise ValueError("seq_len= only applies to feed='device'")
    if image_shape is None or out_shape is None:
        raise ValueError("feed='image' needs image_shape=(h, w, c) and "
                         "out_shape=(out_h, out_w)")
    h, w, c = image_shape
    out_h, out_w = out_shape
    return ImageFeed(loader, h, w, c, out_h, out_w, mean=mean, std=std,
                     seed=feed_seed, prefetch=feed_prefetch,
                     step_stats=step_stats)


def build_stack(*, store: KVStore, uuids: Sequence[_uuid.UUID],
                config: "LoaderConfig | MultiHostConfig",
                clock: Optional[Clock] = None,
                cluster: Optional[object] = None,
                ingress: Optional[object] = None,
                start: bool = False,
                feed: Optional[str] = None,
                seq_len: Optional[int] = None,
                image_shape: Optional[Tuple[int, int, int]] = None,
                out_shape: Optional[Tuple[int, int]] = None,
                feed_prefetch: int = 2,
                step_stats=None,
                mean=None, std=None, feed_seed: int = 0) -> Stack:
    """Assemble the full data stack from one config object.

    Parameters
    ----------
    store, uuids
        The KV store and the sample keys to load (as everywhere else).
    config
        ``LoaderConfig`` for the single-host chain, ``MultiHostConfig`` for
        an N-host run (federated when ``config.clusters`` is set).
    clock, cluster, ingress
        Optional externally-owned pieces for co-located loaders (single-host
        only; multi-host runs own theirs so checkpoints stay self-contained):
        several ``build_stack`` calls sharing one clock + cluster + client
        ``RateResource`` model N GPUs on one machine contending for the NIC.
    start
        Start the prefetchers (``loader.start()`` / ``run.start()``) before
        returning.  Feeds start their loader on first ``next()`` anyway.
    feed
        ``None`` (default), ``"device"`` (token batches; needs ``seq_len``
        and ``config.materialize=True``) or ``"image"`` (uint8 image rows;
        needs ``image_shape``/``out_shape`` and ``materialize=True``).
    feed_prefetch, step_stats, mean, std, feed_seed
        Passed through to the feed constructor.
    """
    if feed not in FEED_KINDS:
        raise ValueError(f"unknown feed kind {feed!r} "
                         f"(choose from {FEED_KINDS})")

    if isinstance(config, MultiHostConfig):
        if feed is not None:
            raise ValueError("per-host feeds over a MultiHostConfig are not "
                             "built here — build the MultiHostRun stack and "
                             "wrap stack.loaders[i] yourself")
        if clock is not None or cluster is not None or ingress is not None:
            raise ValueError("MultiHostRun owns its clock/cluster/ingress; "
                             "clock=/cluster=/ingress= are single-host only")
        run = MultiHostRun(store, list(uuids), config)
        if start:
            run.start()
        host0 = run.loaders[0]
        return Stack(config=config, clock=run.clock, cluster=run.cluster,
                     pool=host0.pool, run=run, loaders=list(run.loaders))

    if not isinstance(config, LoaderConfig):
        raise TypeError(f"config must be a LoaderConfig or MultiHostConfig, "
                        f"got {type(config).__name__}")
    if feed is not None and not config.materialize:
        raise ValueError(f"feed={feed!r} consumes real payload bytes — set "
                         "materialize=True on the LoaderConfig")

    loader = CassandraLoader(store, list(uuids), config, clock=clock,
                             cluster=cluster, ingress=ingress)
    feed_obj = None
    if feed is not None:
        feed_obj = _build_feed(feed, loader, seq_len=seq_len,
                               image_shape=image_shape, out_shape=out_shape,
                               feed_prefetch=feed_prefetch,
                               step_stats=step_stats, mean=mean, std=std,
                               feed_seed=feed_seed)
    if start:
        loader.start()
    return Stack(config=config, clock=loader.clock, cluster=loader.cluster,
                 pool=loader.pool, loader=loader, feed=feed_obj,
                 loaders=[loader])


__all__ = ["FEED_KINDS", "Stack", "build_stack"]
