"""Prefetching strategies (paper Sec. 3.4).

``InOrderPrefetcher``  — classic k-buffer prefetch: batch i is assembled from
exactly the samples of permutation slice i, so every batch waits for its
slowest connection.

``OutOfOrderPrefetcher`` — the paper's contribution: requests for up to k
batches' worth of samples are in flight simultaneously and output batches are
filled with whichever samples *arrive first*.  Valid because (a) training is
robust to uniformly random permutations and (b) labels travel with features,
so any sample is self-contained.

Both support the *incremental ramp* (staggered buffer filling): instead of
front-loading k batches of requests at t=0 (bursting the network to k× the
steady rate), request one extra batch every ``ramp_every`` consumed — a
transient of only +1/ramp_every (25% for the paper's value of 4).

Both also support **adaptive flow control**
(``PrefetchConfig.flow_control="adaptive"``): a BDP-tracking
``FlowController`` (``core/flowctl.py``) replaces the fixed depth k and the
fixed ramp — the in-flight budget slow-starts to the measured
bandwidth-delay product of the route and backs off on queueing-delay
inflation, so no ``num_buffers`` hand-tuning is needed.  ``"static"`` (the
default) is bit-identical to the pre-flow-control behaviour.

Sharding / restart invariants carried by ``EpochPlan`` (property-tested in
``tests/test_resharding.py``; the multi-host and federation layers build on
them, see ``core/multihost.py``):

* **Contiguous-strip-of-shuffle** — with ``num_shards > 1`` every host
  computes the same global shuffle (seeded by ``(seed, num_shards)``) and
  takes its *contiguous strip* of it; strips are disjoint, jointly cover
  the dataset, and differ in size by at most one.  Never a strided slice
  of the raw uuid list — strides of an unshuffled list are biased samples.
* **Exactly-once per epoch** — each epoch delivers every dataset uuid
  exactly once across all shards.  Per-epoch *overrides* preserve this
  through elastic N->M resizes: ``compute_reflow`` collects every epoch's
  undelivered tail at a coordinated checkpoint boundary, the placement
  policy splits each tail into M balanced strips, and those strips pin the
  transition epochs of the M fresh plans; later epochs fall back to plain
  M-host strips (indistinguishable from a fresh M-host run).
* **M == N bit-identity** — restoring onto the same shard count with the
  same strip-defining metadata replays the identical per-epoch
  permutations; ``advance`` is the exact (epoch, cursor) odometer even
  when override epochs have different lengths.
"""

from __future__ import annotations

import uuid as _uuid
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import numpy as np

from .batch_loader import AssembledBatch, BatchAssembler, BatchRequest
from .connection import ConnectionPool, FetchResult
from .flowctl import FLOW_CONTROL_MODES, FlowControlConfig
from .netsim import Clock
from .placement import global_order, split_contiguous
from .stats import LoaderStats


@dataclass
class PrefetchConfig:
    batch_size: int = 512
    num_buffers: int = 8            # prefetch depth k (paper: e.g. 8 per GPU)
    out_of_order: bool = True       # the paper's key optimization
    incremental_ramp: bool = True   # staggered buffer filling
    ramp_every: int = 4             # +1 extra batch every N consumed
    # "static": the paper's fixed depth k + incremental ramp (default,
    # bit-identical to pre-flow-control behaviour).  "adaptive": a
    # BDP-tracking FlowController (core/flowctl.py) sets the in-flight
    # budget from measured RTT and delivery rate; num_buffers and the ramp
    # knobs are ignored (the controller's slow start is the ramp).
    flow_control: str = "static"
    flow: Optional[FlowControlConfig] = None
    # Per-key route admission (out-of-order + adaptive only): before issuing
    # a key, ask ``pool.admit(key)`` whether its *serving route* has
    # in-flight headroom; keys whose route is at budget are deferred (up to
    # one batch of lookahead) and plan-later keys on uncongested routes
    # issue first — issue order is no longer forced to equal plan order.
    # Deferral reorders, never drops: deferred keys re-try first on every
    # fill, and when nothing is admissible the oldest is force-issued, so
    # delivery (and the exactly-once plan property) is untouched.
    route_admission: bool = False

    def __post_init__(self) -> None:
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, "
                             f"got {self.batch_size}")
        if self.route_admission:
            if self.flow_control != "adaptive":
                raise ValueError("route_admission needs "
                                 "flow_control='adaptive' (admission "
                                 "consults per-route controller budgets)")
            if not self.out_of_order:
                raise ValueError("route_admission needs out_of_order=True "
                                 "(in-order assembly consumes in plan "
                                 "order, so reordered issue just stalls "
                                 "the head batch)")
        if self.num_buffers < 1:
            raise ValueError(f"num_buffers must be >= 1, "
                             f"got {self.num_buffers}")
        if self.ramp_every < 1:
            raise ValueError(f"ramp_every must be >= 1, "
                             f"got {self.ramp_every}")
        if self.flow_control not in FLOW_CONTROL_MODES:
            raise ValueError(f"unknown flow_control mode "
                             f"{self.flow_control!r} (choose from "
                             f"{FLOW_CONTROL_MODES})")


class EpochPlan:
    """Seeded uniform permutation per epoch — the 'predetermined' future
    requests that make prefetching possible (Sec. 3.4).

    With ``num_shards > 1`` every host constructs the same global shuffle
    (seeded by ``(seed, num_shards)``) and takes its contiguous strip, so the
    N shards are disjoint, jointly cover the dataset, and differ in size by
    at most one sample when N does not divide the dataset.  Each shard then
    reshuffles *its own strip* per epoch.

    A plan can additionally carry per-epoch *overrides* — fixed sample lists
    that replace the shuffled strip for specific epochs.  Overrides are how
    an elastic N->M restart reflows the unfinished part of the interrupted
    epoch(s) onto M new hosts (see :func:`compute_reflow`): the transition
    epochs are pinned to explicit strips of the leftover samples, and every
    later epoch falls back to the plan's own strip.
    """

    def __init__(self, uuids: List[_uuid.UUID], seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1) -> None:
        if num_shards < 1 or not 0 <= shard_id < num_shards:
            raise ValueError(f"bad shard spec {shard_id}/{num_shards}")
        if num_shards > 1:
            # per-host shard of the global UUID list (multi-host loading):
            # contiguous strips of the *shuffled* list stay unbiased.
            shuffled = global_order(uuids, seed, num_shards)
            self._uuids = split_contiguous(shuffled, num_shards)[shard_id]
        else:
            self._uuids = list(uuids)
        self._seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self._overrides: Dict[int, List[_uuid.UUID]] = {}

    @classmethod
    def from_samples(cls, samples: List[_uuid.UUID], seed: int = 0,
                     shard_id: int = 0, num_shards: int = 1) -> "EpochPlan":
        """A shard whose strip was assigned externally (placement policies,
        strip reflow) instead of carved from the global shuffle here."""
        plan = cls(list(samples), seed=seed)
        plan.shard_id = shard_id
        plan.num_shards = num_shards
        return plan

    def __len__(self) -> int:
        return len(self._uuids)

    # -- per-epoch overrides (elastic-reshard transitions) ------------------
    def install_overrides(self,
                          overrides: Dict[int, List[_uuid.UUID]]) -> None:
        """Pin specific epochs to fixed sample lists."""
        for e, samples in overrides.items():
            self._overrides[int(e)] = list(samples)

    def pending_overrides(self, from_epoch: int) -> Dict[int, List[_uuid.UUID]]:
        """Overrides not yet fully consumed at ``from_epoch`` — the part a
        checkpoint must carry for the restore to replay the transition."""
        return {e: list(s) for e, s in self._overrides.items()
                if e >= from_epoch}

    def epoch_length(self, epoch: int) -> int:
        ov = self._overrides.get(epoch)
        return len(self._uuids) if ov is None else len(ov)

    def advance(self, epoch: int, cursor: int, n_samples: int = 0) -> tuple:
        """Normalize ``(epoch, cursor + n_samples)`` against the per-epoch
        lengths: a position at/past the end of an epoch rolls into later
        epochs.  This is the shard's odometer — exact for override epochs of
        any length, constant-time once past the last override."""
        if cursor < 0:
            raise ValueError(f"negative cursor {cursor}")
        c = cursor + n_samples
        e = epoch
        last_override = max(self._overrides, default=-1)
        while e <= last_override:
            length = self.epoch_length(e)
            if c < length:
                return e, c
            c -= length
            e += 1
        n = len(self._uuids)
        if n == 0:
            raise ValueError("EpochPlan shard is empty — more shards than "
                             "samples (or an empty dataset)")
        return e + c // n, c % n

    # -- per-epoch delivery order -------------------------------------------
    def permutation(self, epoch: int) -> List[_uuid.UUID]:
        ov = self._overrides.get(epoch)
        if ov is not None:
            return list(ov)
        rng = np.random.default_rng((self._seed, epoch))
        order = rng.permutation(len(self._uuids))
        return [self._uuids[i] for i in order]

    def iter_from(self, epoch: int, cursor: int) -> Iterator[tuple]:
        """Infinite (epoch, uuid) stream starting at (epoch, cursor)."""
        e = epoch
        while True:
            perm = self.permutation(e)
            for i in range(cursor, len(perm)):
                yield e, perm[i]
            cursor = 0
            e += 1


def compute_reflow(old_plans: List[EpochPlan],
                   old_positions: List[tuple]) -> tuple:
    """Per-epoch leftovers at a coordinated N-host checkpoint boundary.

    ``old_positions`` holds one ``(epoch, cursor)`` per old shard.  Uneven
    strips drift apart in epoch number over time, so the boundary spans the
    epochs between the slowest and the fastest shard; for each such epoch
    this returns the samples *not yet delivered*, concatenated in shard
    order.  Splitting every epoch's tail into M balanced strips (see
    ``repro.core.placement.split_strips``) and installing them as overrides
    on M fresh plans yields an elastic N->M restart that still delivers
    every sample exactly once per epoch.

    Returns ``(start_epoch, {epoch: [uuid, ...]})`` where ``start_epoch`` is
    the slowest shard's epoch — the position all new shards restart from.
    """
    if len(old_plans) != len(old_positions) or not old_plans:
        raise ValueError("need one (epoch, cursor) position per old plan")
    epochs = [e for e, _ in old_positions]
    e_start, e_end = min(epochs), max(epochs)
    # A prior reshard may have pinned overrides *beyond* every shard's
    # current epoch (multi-epoch transitions); those epochs are still
    # partial globally, so the reflow window must reach them or the new
    # plans would deliver them as full plain epochs (duplicates).
    for plan, (e_i, _) in zip(old_plans, old_positions):
        pending = plan.pending_overrides(e_i)
        if pending:
            e_end = max(e_end, max(pending))
    tails: Dict[int, List[_uuid.UUID]] = {e: [] for e in
                                          range(e_start, e_end + 1)}
    for plan, (e_i, c_i) in zip(old_plans, old_positions):
        for e in range(e_i, e_end + 1):
            perm = plan.permutation(e)
            tails[e].extend(perm[c_i:] if e == e_i else perm)
    return e_start, tails


class _PrefetcherBase:
    def __init__(self, clock: Clock, pool: ConnectionPool, plan: EpochPlan,
                 cfg: PrefetchConfig, assembler: Optional[BatchAssembler] = None,
                 real_copy: bool = False, controller=None) -> None:
        self.clock = clock
        self.pool = pool
        self.plan = plan
        self.cfg = cfg
        # Adaptive flow control (core/flowctl.py): when a controller is
        # wired in, it owns the in-flight budget; the static k-buffer ramp
        # below is the default-compatible path.
        self.controller = controller
        self.assembler = assembler or BatchAssembler(clock, real_copy=real_copy)
        self.stats = LoaderStats(clock)
        self.consumed = 0               # batches handed to the consumer
        self._epoch0 = 0
        self._cursor0 = 0
        self._started = False

    # -- ramp / flow control ----------------------------------------------
    def _target_depth(self) -> int:
        """Allowed number of batches in flight (requests+ready) right now."""
        if self.controller is not None:
            return self.controller.depth(self.cfg.batch_size)
        k = self.cfg.num_buffers
        if not self.cfg.incremental_ramp:
            return k
        # 1 buffer at start; +1 extra every ramp_every consumed.
        return min(k, 1 + self.consumed // self.cfg.ramp_every)

    @property
    def started(self) -> bool:
        """True once ``start()`` has run (public — consumers must not poke
        at ``_started``)."""
        return self._started

    @property
    def ready_batches(self) -> int:
        """Assembled batches a ``next_batch`` call would return without
        blocking — what the device feed consults for buffer-hit accounting."""
        raise NotImplementedError

    # -- checkpoint/restart ------------------------------------------------
    def _set_origin(self, epoch: int, cursor: int) -> None:
        """Normalize a restart position: a cursor at/past the end of this
        shard's epoch (possible when shards divide unevenly and a global
        batch count is mapped onto each shard) rolls into later epochs —
        honouring per-epoch override lengths during reshard transitions."""
        self._epoch0, self._cursor0 = self.plan.advance(epoch, cursor)

    def state(self, rewind_batches: int = 0) -> dict:
        """Loader position for fault-tolerant restart (batch granularity).

        ``rewind_batches`` backs the cursor off by already-pulled batches a
        downstream buffer (e.g. ``DeviceFeed``'s device queue) is holding
        past the consumer: the checkpoint must record the *consumer-facing*
        position, or a restore would silently skip those samples."""
        if rewind_batches < 0:
            raise ValueError(f"negative rewind_batches {rewind_batches}")
        consumed = max(0, self.consumed - rewind_batches)
        epoch, cursor = self.plan.advance(
            self._epoch0, self._cursor0, consumed * self.cfg.batch_size)
        return {"epoch": epoch, "cursor": cursor, "consumed": consumed}

    def describe(self) -> str:
        mode = "OOO" if self.cfg.out_of_order else "in-order"
        if self.controller is not None:
            return (f"{mode}/adaptive depth={self._target_depth()} "
                    f"B={self.cfg.batch_size}")
        ramp = "incremental" if self.cfg.incremental_ramp else "eager"
        return f"{mode}/{ramp} k={self.cfg.num_buffers} B={self.cfg.batch_size}"


class InOrderPrefetcher(_PrefetcherBase):
    """Baseline strategy: per-batch request groups, in-order delivery."""

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self._ready: Dict[int, AssembledBatch] = {}
        self._outstanding = 0
        self._next_issue = 0
        self._next_consume = 0
        self._stream: Optional[Iterator] = None

    @property
    def ready_batches(self) -> int:
        # in-order delivery: only the head-of-line batch counts as ready
        return 1 if self._next_consume in self._ready else 0

    def start(self, epoch: int = 0, cursor: int = 0) -> None:
        self._set_origin(epoch, cursor)
        self._stream = self.plan.iter_from(self._epoch0, self._cursor0)
        self._started = True
        self._fill()

    def _fill(self) -> None:
        while self._outstanding + len(self._ready) < self._target_depth():
            uuids, ep = [], 0
            for _ in range(self.cfg.batch_size):
                ep, u = next(self._stream)
                uuids.append(u)
            seq = self._next_issue
            self._next_issue += 1
            self._outstanding += 1
            self.stats.on_issue(seq, len(uuids))
            BatchRequest(seq, ep, uuids, self.pool, self.assembler, self._on_ready)

    def _on_ready(self, batch: AssembledBatch) -> None:
        self._outstanding -= 1
        self._ready[batch.seq] = batch
        self.stats.on_batch_ready(batch)

    def next_batch(self, timeout: float = 600.0) -> AssembledBatch:
        if not self._started:
            self.start()
        seq = self._next_consume
        ok = self.clock.run_until(lambda: seq in self._ready, timeout=timeout)
        if not ok:
            raise TimeoutError(f"batch {seq} not ready after {timeout}s "
                               f"({self.describe()})")
        batch = self._ready.pop(seq)
        self._next_consume += 1
        self.consumed += 1
        self.stats.on_consume(batch)
        self._fill()
        return batch


class OutOfOrderPrefetcher(_PrefetcherBase):
    """The paper's strategy: sample-level in-flight window, arrival-order
    batch assembly."""

    def __init__(self, *args, **kw) -> None:
        super().__init__(*args, **kw)
        self._pool_arrived: deque = deque()   # FetchResults in arrival order
        self._samples_inflight = 0
        self._ready: deque = deque()          # assembled batches, FIFO
        self._assembling = 0
        self._next_seq = 0
        self._stream: Optional[Iterator] = None
        self._cur_epoch = 0
        # route-admission lookahead: (epoch, uuid) keys whose serving route
        # was at budget when drawn — retried first on every fill
        self._deferred: deque = deque()
        self.deferrals = 0                    # keys deferred at least once
        self.forced_issues = 0                # force-issued (nothing admissible)

    @property
    def ready_batches(self) -> int:
        return len(self._ready)

    def start(self, epoch: int = 0, cursor: int = 0) -> None:
        self._set_origin(epoch, cursor)
        self._cur_epoch = self._epoch0
        self._stream = self.plan.iter_from(self._epoch0, self._cursor0)
        self._started = True
        self._fill()

    def _fill(self) -> None:
        B = self.cfg.batch_size
        budget = self._target_depth() * B
        if not self.cfg.route_admission:
            while (self._samples_inflight + len(self._pool_arrived)
                   + self._assembling * B + len(self._ready) * B) < budget:
                ep, u = next(self._stream)
                self._cur_epoch = ep
                self._samples_inflight += 1
                self.pool.fetch(u, self._on_sample)
            return
        self._fill_with_admission(budget)

    def _fill_with_admission(self, budget: int) -> None:
        """Budget fill with per-key route admission: deferred keys (their
        route was at budget) retry first; fresh keys that fail admission
        join the deferral window; once the window holds a full batch with
        nothing admissible, the oldest key is force-issued — admission
        shapes issue *order*, the global budget alone decides *volume*, so
        the fill can never stall behind one saturated route."""
        B = self.cfg.batch_size

        def issue(ep: int, u: _uuid.UUID) -> None:
            self._cur_epoch = ep
            self._samples_inflight += 1
            self.pool.fetch(u, self._on_sample)

        # Admission verdicts only move with the clock, a completion, or an
        # issue (in-flight counts/EMAs) — none of which happen while keys
        # are merely rotated through the deferral window.  So once a full
        # scan of the window admits nothing, re-scanning it is pure waste
        # until the next issue: skip it (``window_dry``), and let each
        # issue re-arm the scan.  Behavior is unchanged — only the
        # redundant re-checks (quadratic in window size per fill under a
        # deferral storm) are elided.
        window_dry = False
        while (self._samples_inflight + len(self._pool_arrived)
               + self._assembling * B + len(self._ready) * B) < budget:
            issued = False
            if not window_dry:
                for _ in range(len(self._deferred)):
                    ep, u = self._deferred.popleft()
                    if self.pool.admit(u):
                        issue(ep, u)
                        issued = True
                        break
                    self._deferred.append((ep, u))
                window_dry = not issued and bool(self._deferred)
            if issued:
                continue
            if len(self._deferred) >= B:
                self.forced_issues += 1
                issue(*self._deferred.popleft())
                window_dry = False
                continue
            ep, u = next(self._stream)
            if self.pool.admit(u):
                issue(ep, u)
                window_dry = False
            else:
                self.deferrals += 1
                self._deferred.append((ep, u))

    def _on_sample(self, res: FetchResult) -> None:
        self._samples_inflight -= 1
        self._pool_arrived.append(res)
        self.stats.on_sample(res)
        self._maybe_assemble()

    def _maybe_assemble(self) -> None:
        B = self.cfg.batch_size
        while len(self._pool_arrived) >= B:
            samples = [self._pool_arrived.popleft() for _ in range(B)]
            seq = self._next_seq
            self._next_seq += 1
            self._assembling += 1
            self.stats.on_issue(seq, B)
            self.assembler.assemble(seq, self._cur_epoch, samples, self._on_ready)

    def _on_ready(self, batch: AssembledBatch) -> None:
        self._assembling -= 1
        self._ready.append(batch)
        self.stats.on_batch_ready(batch)

    def next_batch(self, timeout: float = 600.0) -> AssembledBatch:
        if not self._started:
            self.start()
        ok = self.clock.run_until(lambda: len(self._ready) > 0, timeout=timeout)
        if not ok:
            raise TimeoutError(f"no batch ready after {timeout}s ({self.describe()})")
        batch = self._ready.popleft()
        self.consumed += 1
        self.stats.on_consume(batch)
        self._fill()
        return batch


def make_prefetcher(clock: Clock, pool: ConnectionPool, plan: EpochPlan,
                    cfg: PrefetchConfig, real_copy: bool = False,
                    controller=None,
                    assembler: Optional[BatchAssembler] = None):
    """``assembler`` overrides the default per-batch assembler — how the
    loader wires in an arena-backed one (``core/arena.py``) so real copies
    land in reused pinned slabs instead of fresh buffers."""
    cls = OutOfOrderPrefetcher if cfg.out_of_order else InOrderPrefetcher
    return cls(clock, pool, plan, cfg, real_copy=real_copy,
               controller=controller, assembler=assembler)


__all__ = ["PrefetchConfig", "EpochPlan", "compute_reflow",
           "InOrderPrefetcher", "OutOfOrderPrefetcher", "make_prefetcher"]
