"""Shard placement policies: which keys each training host owns.

The multi-host coordinator carves one global shuffle of the dataset into one
strip per host.  *How* that carving is done is a placement policy:

``contiguous``
    Balanced contiguous strips of the shuffled key list — the paper-faithful
    default.  Every host's strip touches every storage node roughly equally,
    so every host contends with every other host on every node's egress NIC.

``token_aware``
    Replica-skewed strips.  Each host is given a *preferred subset* of the
    storage nodes (round-robin over the ring, see
    :func:`preferred_node_subsets`) and greedily receives the keys whose
    replica set (``TokenRing.replicas``) intersects that subset.  Strips stay
    exactly balanced (sizes differ by at most one), so sharding semantics —
    disjoint, jointly covering, exactly once per epoch — are identical to
    ``contiguous``; only *which* host owns *which* keys changes.  Each host's
    traffic then concentrates on its preferred nodes, which is what keeps
    client scaling from turning into all-to-all egress contention
    (cf. Krichevsky et al. on locality-blind shard assignment).

The module is deliberately dependency-light: a "ring" is anything with a
``replicas(key, rf) -> List[str]`` method.
"""

from __future__ import annotations

import uuid as _uuid
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

PLACEMENT_POLICIES = ("contiguous", "token_aware")


def global_order(uuids: Sequence[_uuid.UUID], seed: int,
                 num_shards: int) -> List[_uuid.UUID]:
    """The shared global shuffle every host computes identically.

    Seeded by ``(seed, num_shards)`` — the same stream ``EpochPlan`` has
    always used, so contiguous strips of this order are byte-identical to the
    plan's own internal sharding.
    """
    n = len(uuids)
    order = np.random.default_rng((seed, num_shards)).permutation(n)
    return [uuids[i] for i in order]


def strip_bounds(n: int, num_shards: int) -> List[Tuple[int, int]]:
    """Balanced ``[lo, hi)`` bounds: sizes differ by at most one."""
    return [((j * n) // num_shards, ((j + 1) * n) // num_shards)
            for j in range(num_shards)]


def split_contiguous(samples: Sequence, num_shards: int) -> List[List]:
    return [list(samples[lo:hi]) for lo, hi in strip_bounds(len(samples),
                                                            num_shards)]


def preferred_node_subsets(node_names: Sequence[str],
                           n_hosts: int) -> List[Tuple[str, ...]]:
    """Round-robin host -> storage-node preference map.

    With fewer hosts than nodes each host prefers a disjoint stripe of
    nodes; with more hosts than nodes, hosts wrap around and share.  Either
    way every node is preferred by someone.  Aggregate per-node egress is
    even when the host count divides (or is a multiple of) the node count;
    otherwise subsets have unequal sizes and a host preferring two nodes
    spreads one strip's worth of traffic across both, so single-node
    subsets can carry up to 2x the egress — visible in the run report's
    ``egress_imbalance``.
    """
    n = len(node_names)
    if n == 0 or n_hosts < 1:
        raise ValueError(f"bad preference spec: {n} nodes, {n_hosts} hosts")
    if n_hosts <= n:
        return [tuple(node_names[k] for k in range(n) if k % n_hosts == j)
                for j in range(n_hosts)]
    return [(node_names[j % n],) for j in range(n_hosts)]


def split_token_aware(samples: Sequence[_uuid.UUID], num_shards: int, ring,
                      rf: int,
                      preferred: Sequence[Sequence[str]]) -> List[List]:
    """Greedy replica-skewed split with strict balance.

    Pass 1 hands each key (in the given deterministic order) to the
    least-filled host — among those with remaining capacity — whose preferred
    nodes host a replica of the key.  Pass 2 distributes the leftovers to
    whoever still has room.  The result is a partition with the same balanced
    sizes as :func:`split_contiguous`, but replica-local wherever the ring
    allows it.
    """
    if len(preferred) != num_shards:
        raise ValueError(f"{len(preferred)} preference sets for "
                         f"{num_shards} shards")
    caps = [hi - lo for lo, hi in strip_bounds(len(samples), num_shards)]
    pref_sets = [frozenset(p) for p in preferred]
    strips: List[List] = [[] for _ in range(num_shards)]
    leftovers: List = []
    for u in samples:
        replicas = frozenset(ring.replicas(u, rf))
        local = [j for j in range(num_shards)
                 if len(strips[j]) < caps[j] and replicas & pref_sets[j]]
        if local:
            j = min(local, key=lambda j: (len(strips[j]), j))
            strips[j].append(u)
        else:
            leftovers.append(u)
    for u in leftovers:
        j = min((j for j in range(num_shards) if len(strips[j]) < caps[j]),
                key=lambda j: (len(strips[j]), j))
        strips[j].append(u)
    return strips


def split_strips(samples: Sequence[_uuid.UUID], num_shards: int,
                 policy: str = "contiguous", ring=None, rf: int = 1,
                 preferred: Optional[Sequence[Sequence[str]]] = None
                 ) -> List[List]:
    """Split ``samples`` into ``num_shards`` balanced strips per ``policy``."""
    if policy == "contiguous":
        return split_contiguous(samples, num_shards)
    if policy == "token_aware":
        if ring is None or preferred is None:
            raise ValueError("token_aware placement needs a ring and a "
                             "preference map")
        return split_token_aware(samples, num_shards, ring, rf, preferred)
    raise ValueError(f"unknown placement policy {policy!r} "
                     f"(choose from {PLACEMENT_POLICIES})")


def replica_local_fraction(strips: Sequence[Sequence[_uuid.UUID]], ring,
                           rf: int,
                           preferred: Sequence[Sequence[str]]) -> float:
    """Fraction of keys whose owning host prefers one of their replicas."""
    total = sum(len(s) for s in strips)
    if total == 0:
        return 0.0
    hits = sum(1 for j, strip in enumerate(strips) for u in strip
               if frozenset(ring.replicas(u, rf)) & frozenset(preferred[j]))
    return hits / total


__all__ = ["PLACEMENT_POLICIES", "global_order", "strip_bounds",
           "split_contiguous", "split_token_aware", "split_strips",
           "preferred_node_subsets", "replica_local_fraction"]
