"""Shard placement policies: which keys each training host owns.

The multi-host coordinator carves one global shuffle of the dataset into one
strip per host.  *How* that carving is done is a placement policy:

``contiguous``
    Balanced contiguous strips of the shuffled key list — the paper-faithful
    default.  Every host's strip touches every storage node roughly equally,
    so every host contends with every other host on every node's egress NIC.

``token_aware``
    Replica-skewed strips.  Each host is given a *preferred subset* of the
    storage nodes (round-robin over the ring, see
    :func:`preferred_node_subsets`) and greedily receives the keys whose
    replica set (``TokenRing.replicas``) intersects that subset.  Strips stay
    exactly balanced (sizes differ by at most one), so sharding semantics —
    disjoint, jointly covering, exactly once per epoch — are identical to
    ``contiguous``; only *which* host owns *which* keys changes.  Each host's
    traffic then concentrates on its preferred nodes, which is what keeps
    client scaling from turning into all-to-all egress contention
    (cf. Krichevsky et al. on locality-blind shard assignment).

``cluster_aware``
    The federation generalization of ``token_aware`` (see
    ``core/federation.py``).  The ring here is a ``FederatedRing``: every key
    belongs to exactly one member cluster (the dataset->cluster ownership
    map) and ``replicas()`` returns only *that* cluster's replica nodes,
    qualified as ``"<cluster>/<node>"``.  The same greedy balanced split
    therefore prefers the key's same-region cluster first and a replica-local
    node within it second, while the preference map
    (``federated_preferred_subsets``) guarantees every host a preferred node
    in every member cluster — no host ends up with an all-WAN strip, which
    matters because the multi-host driver consumes in lockstep and the
    slowest host gates the round.

``replication_aware``
    ``cluster_aware`` plus hot-key replication at serve time (see
    ``core/replication.py``).  Strip construction is *identical* to
    ``cluster_aware`` — strips must stay a deterministic function of the
    checkpointed (seed, ring) metadata, and the replica cache is runtime
    state that changes as the workload's skew moves — so the "prefer a
    local replica before the home cluster" preference lives in routing:
    every ``FederatedConnectionPool.fetch`` consults the federation's
    ``ReplicaCache`` first and only falls through to the home cluster on a
    miss.  Selecting this policy is what switches that machinery on
    (``MultiHostRun`` attaches a default ``ReplicationConfig`` when none is
    given).

Invariants shared by ALL policies (property-tested in
``tests/test_resharding.py``): strips are pairwise disjoint, jointly cover
the input, and differ in size by at most one.  Those are exactly the
preconditions the prefetcher's exactly-once-per-epoch contract rests on.

The module is deliberately dependency-light: a "ring" is anything with a
``replicas(key, rf) -> List[str]`` method (``cluster_aware`` additionally
expects an ``owner_of(key)`` method, i.e. a federation keyspace).
"""

from __future__ import annotations

import heapq
import uuid as _uuid
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

PLACEMENT_POLICIES = ("contiguous", "token_aware", "cluster_aware",
                      "replication_aware")
# Policies whose strips are ring-derived (need a ring + preference map);
# the federated ones additionally need an ownership map (owner_of).
RING_POLICIES = ("token_aware", "cluster_aware", "replication_aware")
FEDERATED_POLICIES = ("cluster_aware", "replication_aware")


def global_order(uuids: Sequence[_uuid.UUID], seed: int,
                 num_shards: int) -> List[_uuid.UUID]:
    """The shared global shuffle every host computes identically.

    Seeded by ``(seed, num_shards)`` — the same stream ``EpochPlan`` has
    always used, so contiguous strips of this order are byte-identical to the
    plan's own internal sharding.
    """
    n = len(uuids)
    order = np.random.default_rng((seed, num_shards)).permutation(n)
    return [uuids[i] for i in order]


def strip_bounds(n: int, num_shards: int) -> List[Tuple[int, int]]:
    """Balanced ``[lo, hi)`` bounds: sizes differ by at most one."""
    return [((j * n) // num_shards, ((j + 1) * n) // num_shards)
            for j in range(num_shards)]


def split_contiguous(samples: Sequence, num_shards: int) -> List[List]:
    return [list(samples[lo:hi]) for lo, hi in strip_bounds(len(samples),
                                                            num_shards)]


def preferred_node_subsets(node_names: Sequence[str],
                           n_hosts: int) -> List[Tuple[str, ...]]:
    """Round-robin host -> storage-node preference map.

    With fewer hosts than nodes each host prefers a disjoint stripe of
    nodes; with more hosts than nodes, hosts wrap around and share.  Either
    way every node is preferred by someone.  Aggregate per-node egress is
    even when the host count divides (or is a multiple of) the node count;
    otherwise subsets have unequal sizes and a host preferring two nodes
    spreads one strip's worth of traffic across both, so single-node
    subsets can carry up to 2x the egress — visible in the run report's
    ``egress_imbalance``.
    """
    n = len(node_names)
    if n == 0 or n_hosts < 1:
        raise ValueError(f"bad preference spec: {n} nodes, {n_hosts} hosts")
    if n_hosts <= n:
        return [tuple(node_names[k] for k in range(n) if k % n_hosts == j)
                for j in range(n_hosts)]
    return [(node_names[j % n],) for j in range(n_hosts)]


def split_token_aware(samples: Sequence[_uuid.UUID], num_shards: int, ring,
                      rf: int,
                      preferred: Sequence[Sequence[str]]) -> List[List]:
    """Greedy replica-skewed split with strict balance.

    Pass 1 hands each key (in the given deterministic order) to the
    least-filled host — among those with remaining capacity — whose preferred
    nodes host a replica of the key.  Pass 2 distributes the leftovers to
    whoever still has room.  The result is a partition with the same balanced
    sizes as :func:`split_contiguous`, but replica-local wherever the ring
    allows it.

    The candidate scan is indexed by storage node: each node keeps a lazy
    min-heap of the hosts that prefer it, ordered by ``(fill, host)`` — the
    exact greedy tie-break — so choosing a host costs ``O(rf * log hosts)``
    per key instead of a linear sweep of every host.  At 1000 hosts x 48k
    keys that is the difference between ~20 s and ~0.2 s of setup, and the
    resulting partition is identical.
    """
    if len(preferred) != num_shards:
        raise ValueError(f"{len(preferred)} preference sets for "
                         f"{num_shards} shards")
    caps = [hi - lo for lo, hi in strip_bounds(len(samples), num_shards)]
    strips: List[List] = [[] for _ in range(num_shards)]
    fill = [0] * num_shards
    # One heap per storage node, holding (fill-at-push, host) for every host
    # that prefers the node.  ``entry_fill[node][host]`` records the newest
    # entry pushed for that host, so superseded duplicates and full hosts
    # can be discarded lazily at peek time.
    node_heaps: Dict[str, List[Tuple[int, int]]] = {}
    entry_fill: Dict[str, Dict[int, int]] = {}
    for j, pref in enumerate(preferred):
        for name in pref:
            node_heaps.setdefault(name, []).append((0, j))
            entry_fill.setdefault(name, {})[j] = 0
    for heap in node_heaps.values():
        heapq.heapify(heap)

    def peek(name: str) -> Optional[Tuple[int, int]]:
        """Best live (fill, host) among hosts preferring ``name``, or None."""
        heap = node_heaps.get(name)
        if heap is None:
            return None
        ef = entry_fill[name]
        while heap:
            f, j = heap[0]
            if ef.get(j) != f:                 # superseded duplicate
                heapq.heappop(heap)
            elif fill[j] >= caps[j]:           # host is full: retire it
                heapq.heappop(heap)
                del ef[j]
            elif f != fill[j]:                 # stale: refresh in place
                heapq.heapreplace(heap, (fill[j], j))
                ef[j] = fill[j]
            else:
                return (f, j)
        return None

    leftovers: List = []
    for u in samples:
        best = None
        for name in ring.replicas(u, rf):
            cand = peek(name)
            if cand is not None and (best is None or cand < best):
                best = cand
        if best is None:
            leftovers.append(u)
            continue
        j = best[1]
        strips[j].append(u)
        fill[j] += 1
    if leftovers:
        # total capacity equals len(samples), so room always remains
        heap = [(fill[j], j) for j in range(num_shards) if fill[j] < caps[j]]
        heapq.heapify(heap)
        for u in leftovers:
            f, j = heap[0]
            strips[j].append(u)
            fill[j] += 1
            if fill[j] < caps[j]:
                heapq.heapreplace(heap, (fill[j], j))
            else:
                heapq.heappop(heap)
        assert all(len(s) == c for s, c in zip(strips, caps))
    return strips


def split_strips(samples: Sequence[_uuid.UUID], num_shards: int,
                 policy: str = "contiguous", ring=None, rf: int = 1,
                 preferred: Optional[Sequence[Sequence[str]]] = None
                 ) -> List[List]:
    """Split ``samples`` into ``num_shards`` balanced strips per ``policy``."""
    if policy == "contiguous":
        return split_contiguous(samples, num_shards)
    if policy in RING_POLICIES:
        if ring is None or preferred is None:
            raise ValueError(f"{policy} placement needs a ring and a "
                             "preference map")
        if policy in FEDERATED_POLICIES and not hasattr(ring, "owner_of"):
            raise ValueError(f"{policy} placement needs a federated ring "
                             "(one with an owner_of(key) ownership map)")
        # cluster_aware (and replication_aware, whose extra behaviour is
        # routing-time only) IS the token-aware greedy split — run over a
        # FederatedRing, whose replicas() already restricts each key to its
        # owning cluster, it prefers same-region cluster then replica-local
        # node by construction.
        return split_token_aware(samples, num_shards, ring, rf, preferred)
    raise ValueError(f"unknown placement policy {policy!r} "
                     f"(choose from {PLACEMENT_POLICIES})")


def replica_local_fraction(strips: Sequence[Sequence[_uuid.UUID]], ring,
                           rf: int,
                           preferred: Sequence[Sequence[str]]) -> float:
    """Fraction of keys whose owning host prefers one of their replicas."""
    total = sum(len(s) for s in strips)
    if total == 0:
        return 0.0
    hits = sum(1 for j, strip in enumerate(strips) for u in strip
               if frozenset(ring.replicas(u, rf)) & frozenset(preferred[j]))
    return hits / total


__all__ = ["PLACEMENT_POLICIES", "RING_POLICIES", "FEDERATED_POLICIES",
           "global_order", "strip_bounds",
           "split_contiguous", "split_token_aware", "split_strips",
           "preferred_node_subsets", "replica_local_fraction"]
