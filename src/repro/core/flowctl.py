"""Adaptive flow control: a BDP-tracking in-flight budget per route.

The paper hides network latency with a *fixed* prefetch depth ``k`` plus a
fixed incremental ramp — which is only right when the operator hand-tunes
``k`` to the route's bandwidth-delay product.  One static ``num_buffers``
cannot serve a federation mixing 0.05 ms local routes with 150 ms
intercontinental ones, so this module *measures* the depth instead, in the
style of rate-based congestion control (BBR's min-RTT/max-rate filters, TCP's
slow start and AIMD):

Signals in (fed by ``ConnectionPool`` / ``FederatedConnectionPool``):

* per-fetch completion — an RTT sample (``t_done - t_issued``: propagation +
  service + transfer + every queue on the way) and a delivery event for the
  windowed rate estimate (via the shared :func:`repro.core.stats
  .windowed_series` aggregation);
* failovers and hedge fires — loss-style congestion signals.

Budget out (consumed by the prefetchers' ``_target_depth``):

* ``bdp = max_delivery_rate x min_rtt`` over sliding filter windows;
* **slow start** — the probe cap starts at the floor and grows by one sample
  per completion (≈ doubling per RTT, exactly TCP slow start) until the BDP
  estimate takes over or a congestion signal arrives;
* **AIMD** — queueing-delay inflation (smoothed RTT above
  ``rtt_inflation x min_rtt``), a failover or a hedge multiplies the cap by
  ``beta``, with a one-RTT cooldown so a single event backs off once;
  afterwards the cap regrows additively (+1 batch per RTT);
* ``budget = clamp(min(gain x bdp, probe_cap, fair_cap), floor, ceiling)``
  in samples — the floor is one batch (the out-of-order assembler cannot
  make progress below that), the ceiling bounds worst-case buffering, and
  ``fair_cap`` is the :class:`SharedIngressLimiter` share when several
  consumers sit behind one client NIC.

``FlowControllerGroup`` runs one controller per member cluster of a
federation — each fed by that member's sub-pool over that member's route —
and exposes their *sum* as the host's budget, so a 150 ms WAN route ramps
deep while the local route stays shallow.

Controller state snapshots ride the multi-host checkpoint
(:meth:`FlowController.snapshot` / :meth:`restore`,
:func:`merge_snapshots`), so an elastic N->M restore re-seeds the measured
rate/RTT instead of re-slow-starting from the floor.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

from .stats import windowed_series


@dataclass(frozen=True)
class FlowControlConfig:
    """Knobs of the BDP-tracking controller (sane for every route tier)."""

    floor_batches: int = 1        # min budget: one batch keeps assembly alive
    ceiling_batches: int = 64     # hard cap on in-flight batches
    gain: float = 1.75            # budget = gain x BDP estimate; the
    # headroom covers per-connection rate heterogeneity (a min-RTT x
    # max-rate BDP is what the *best* connection needs; stragglers need
    # slack) while staying under the 2x no-over-buffering bound
    beta: float = 0.7             # multiplicative decrease on congestion
    # Smoothed-RTT backoff threshold: back off when the RTT EMA exceeds
    # ``rtt_inflation x (min_rtt + budget / delivery_rate)`` — propagation
    # plus the serialization time of our own standing load.  (Against bare
    # min_rtt, a transfer-dominated route would read its *normal* batch-
    # burst service time as congestion and pin the budget at the floor.)
    rtt_inflation: float = 2.0
    rate_window: float = 0.25     # delivery-rate bucket width, seconds
    rate_buckets: int = 8         # max-filter horizon, in buckets
    rtt_window: float = 10.0      # min-RTT filter horizon, seconds
    # BBR-style PROBE_RTT: the min-RTT anchor only moves *down* on a
    # queue-free sample, so periodically drop the budget to the floor for
    # ~1 RTT — long enough to drain the at-most ``(gain - 1) x BDP``
    # standing queue — to let an improved route show itself.  The interval
    # is a *minimum*: the actual cadence is
    # ``max(probe_rtt_interval, 10 x min_rtt)``, so on a route whose RTT
    # dwarfs the configured interval (e.g. after a schedule-driven latency
    # spike) the ~1-RTT drain stays a bounded ~10% overhead instead of
    # becoming a permanent drain cycle.
    probe_rtt_interval: float = 5.0
    # Regime-shift detection (time-varying routes): when ``regime_buckets``
    # consecutive *completed* min-RTT buckets each sit above
    # ``regime_factor x`` the filter minimum, the route itself has moved (a
    # sustained latency shift, not a transient queue).  The pre-shift
    # buckets are dropped so the min re-anchors to the new regime — instead
    # of a stale pre-degradation minimum pinning the budget (and firing the
    # rtt_inflation backoff on every completion) until the whole
    # ``rtt_window`` expires — and the controller re-enters slow start to
    # re-probe the new BDP quickly.
    regime_factor: float = 3.0
    regime_buckets: int = 2
    # Adaptive hedging: ``FlowController.hedge_after()`` returns
    # ``hedge_rtt_multiple x min_rtt`` — a straggler is a fetch taking
    # several drained-route RTTs, whatever the route's scale.
    hedge_rtt_multiple: float = 4.0

    def __post_init__(self) -> None:
        if self.floor_batches < 1:
            raise ValueError(f"floor_batches must be >= 1, "
                             f"got {self.floor_batches}")
        if self.ceiling_batches < self.floor_batches:
            raise ValueError(f"ceiling_batches ({self.ceiling_batches}) must "
                             f"be >= floor_batches ({self.floor_batches})")
        if self.gain <= 0.0:
            raise ValueError(f"gain must be positive, got {self.gain}")
        if not 0.0 < self.beta < 1.0:
            raise ValueError(f"beta must be in (0, 1), got {self.beta}")
        if self.rtt_inflation <= 1.0:
            raise ValueError(f"rtt_inflation must be > 1, "
                             f"got {self.rtt_inflation}")
        if self.rate_window <= 0.0 or self.rtt_window <= 0.0:
            raise ValueError("rate_window and rtt_window must be positive")
        if self.rate_buckets < 2:
            raise ValueError(f"rate_buckets must be >= 2, "
                             f"got {self.rate_buckets}")
        if self.probe_rtt_interval <= 0.0:
            raise ValueError(f"probe_rtt_interval must be positive, "
                             f"got {self.probe_rtt_interval}")
        if self.regime_factor <= 1.0:
            raise ValueError(f"regime_factor must be > 1, "
                             f"got {self.regime_factor}")
        if self.regime_buckets < 1:
            raise ValueError(f"regime_buckets must be >= 1, "
                             f"got {self.regime_buckets}")
        if self.hedge_rtt_multiple <= 1.0:
            raise ValueError(f"hedge_rtt_multiple must be > 1, "
                             f"got {self.hedge_rtt_multiple}")


class SharedIngressLimiter:
    """Fair-share cap for controllers whose consumers share one client NIC.

    Each registered controller's budget is additionally capped at
    ``gain x (bandwidth / n_active) x min_rtt`` worth of samples — its
    fair-share bandwidth-delay product — so N hosts on one ingress converge
    to ~1/N shares instead of the deepest-buffered host starving the rest.

    The divisor counts *active* members only: a member with no completion
    inside ``activity_window`` (a drained host, a consumer blocked on
    compute) has no demand right now, so its slice is redistributed to the
    members still loading instead of stranded.  The asking controller always
    counts itself — a drained host coming back asks for budget before it has
    fresh completions — and members that have never completed anything count
    as active too (they are about to ramp).

    Every completion is also recorded per member (a bounded latency ring
    plus byte/count totals): the raw material for per-host and per-tenant
    request-latency reporting.  :class:`repro.core.tenancy.TenantScheduler`
    subclasses this into weighted-fair per-tenant QoS shares with admission
    control; the ``admit`` hook here is its seam (the base limiter admits
    everything — the per-route budget is the only brake).
    """

    _LATENCY_RING = 8192        # recent completions kept per member

    def __init__(self, bandwidth: float, clock=None,
                 activity_window: float = 1.0) -> None:
        if bandwidth <= 0.0:
            raise ValueError(f"bandwidth must be positive, got {bandwidth}")
        if activity_window <= 0.0:
            raise ValueError(f"activity_window must be positive, "
                             f"got {activity_window}")
        self.bandwidth = bandwidth
        self.activity_window = activity_window
        self._clock = clock
        self._members: List["FlowController"] = []
        self._last_seen: Dict["FlowController", float] = {}
        self._latency: Dict["FlowController", Deque[float]] = {}
        self._member_bytes: Dict["FlowController", int] = {}
        self._member_completions: Dict["FlowController", int] = {}

    def register(self, ctl: "FlowController") -> None:
        if ctl not in self._members:
            self._members.append(ctl)
            self._latency[ctl] = deque(maxlen=self._LATENCY_RING)
            self._member_bytes[ctl] = 0
            self._member_completions[ctl] = 0

    def on_complete(self, ctl: "FlowController", rtt: float, now: float,
                    nbytes: int) -> None:
        """Per-completion bookkeeping (fed by ``FlowController.on_complete``):
        the activity timestamp that drives the work-conserving split, plus
        the latency ring and byte totals behind the reports.  Pure
        accounting — budgets only move through ``fair_cap_samples``."""
        if ctl not in self._latency:
            self.register(ctl)
        self._last_seen[ctl] = now
        self._latency[ctl].append(rtt)
        self._member_bytes[ctl] += nbytes
        self._member_completions[ctl] += 1

    def _now(self) -> float:
        if self._clock is not None:
            return self._clock.now()
        return max(self._last_seen.values(), default=0.0)

    def active_members(self, now: Optional[float] = None,
                       include: Optional["FlowController"] = None,
                       ) -> List["FlowController"]:
        """Members with demand: a completion inside ``activity_window`` ago,
        or no samples yet (still ramping).  ``include`` forces the asking
        controller in — a drained member asking for budget is waking up."""
        if now is None:
            now = self._now()
        out = [c for c in self._members
               if c not in self._last_seen
               or now - self._last_seen[c] <= self.activity_window]
        if include is not None and include not in out:
            out.append(include)
        return out

    def latencies(self, ctl: "FlowController") -> List[float]:
        """Recent per-fetch RTTs of one member (bounded ring, oldest first)."""
        return list(self._latency.get(ctl, ()))

    def member_bytes(self, ctl: "FlowController") -> int:
        return self._member_bytes.get(ctl, 0)

    def admit(self, ctl: "FlowController") -> bool:
        """Tenant-level admission seam (consulted by ``ConnectionPool.admit``
        on the route-admission path).  No tenants here, so always yes."""
        return True

    def note_issue(self) -> None:
        """A member pool issued a fetch, so in-flight load moved.  The base
        limiter's split never reads in-flight state; the tenant scheduler
        invalidates its admission memo here."""

    def fair_cap_samples(self, ctl: "FlowController") -> float:
        min_rtt = ctl.min_rtt()
        avg = ctl.avg_sample_bytes()
        if min_rtt is None or avg is None:
            return math.inf
        active = self.active_members(include=ctl)
        share = self.bandwidth / max(len(active), 1)
        return ctl.cfg.gain * (share / avg) * min_rtt


class FlowController:
    """Per-route in-flight sample budget driven by measured RTT and rate."""

    def __init__(self, cfg: FlowControlConfig, batch_size: int, clock,
                 name: str = "route",
                 limiter: Optional[SharedIngressLimiter] = None) -> None:
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.cfg = cfg
        self.batch_size = batch_size
        self.name = name
        self._clock = clock
        self._floor = float(cfg.floor_batches * batch_size)
        self._ceiling = float(cfg.ceiling_batches * batch_size)
        self._limiter = limiter
        if limiter is not None:
            limiter.register(self)
        # probe window: slow start from the floor (TCP-style)
        self._probe_cap = self._floor
        self._slow_start = True
        # delivery-rate filter: [bucket_start, completions] aggregates, newest
        # last; the estimate is the max over *complete* buckets (rate is
        # budget-limited while ramping, so the max is the best recent proof
        # of what the route can deliver).
        self._rate_events: Deque[List[float]] = deque()
        self._rate_hint: Optional[float] = None     # checkpoint re-seed
        # min-RTT filter: [bucket_start, min_rtt] aggregates over rtt_window
        self._rtt_mins: Deque[List[float]] = deque()
        self._rtt_ema: Optional[float] = None
        self._min_rtt_hint: Optional[float] = None  # checkpoint re-seed
        # min-RTT *anchor*: the lowest RTT seen since the last regime
        # shift.  The windowed filter alone is unstable under a standing
        # queue (gain > 1): once every sample in the window is queue-
        # inflated, the windowed min drifts up, which raises the BDP cap,
        # which deepens the queue — positive feedback that runs the budget
        # away between PROBE_RTT drains.  The anchor pins the BDP term and
        # the backoff threshold to propagation delay; only a confirmed
        # regime shift (or a lower sample) may move it.
        self._rtt_anchor: Optional[float] = None
        self._avg_bytes: Optional[float] = None
        # in-flight load EMA (fed by the pool at issue time): the gap
        # between the budget and this is the route's *spare* BDP — the
        # signal ownership rebalancing shifts keyspace weight toward
        # (see FederatedRing.rebalance in core/federation.py).
        self._inflight_ema: Optional[float] = None
        # delivery-rate memo: the estimate is a pure function of the rate
        # buckets and the clock, but the admission path queries it once per
        # would-be fetch — thousands of times per event under a deferral
        # storm — so recomputing the windowed series each call dominates
        # whole-run wall time without this
        self._rate_cache: Optional[tuple] = None
        self._cooldown_until = -math.inf
        self._next_probe_rtt = cfg.probe_rtt_interval
        self._drain_until = -math.inf
        self._regime_streak = 0
        # counters / traces
        self.completions = 0
        self.backoffs = 0                 # RTT-inflation backoffs
        self.loss_signals = 0             # failover/hedge backoffs
        self.rtt_probes = 0               # PROBE_RTT drains
        self.regime_shifts = 0            # confirmed route regime shifts
        self.budget_trace: List[tuple] = []   # (t, budget_samples) on change

    # -- signal intake ------------------------------------------------------
    def on_complete(self, t_issued: float, t_done: float,
                    nbytes: int) -> None:
        """One fetch finished: an RTT sample plus a delivery event."""
        rtt = max(t_done - t_issued, 1e-9)
        self.completions += 1
        if self._limiter is not None:
            self._limiter.on_complete(self, rtt, t_done, nbytes)
        if self._rtt_anchor is None or rtt < self._rtt_anchor:
            self._rtt_anchor = rtt
        # min-RTT filter (bucketed so the deque stays bounded on fast routes)
        width = self.cfg.rtt_window / 4.0
        b = math.floor(t_done / width) * width
        if self._rtt_mins and self._rtt_mins[-1][0] == b:
            self._rtt_mins[-1][1] = min(self._rtt_mins[-1][1], rtt)
        else:
            if self._rtt_mins:
                self._regime_check()    # the previous bucket just completed
            self._rtt_mins.append([b, rtt])
        while self._rtt_mins[0][0] < t_done - self.cfg.rtt_window:
            self._rtt_mins.popleft()
        # smoothed RTT: time constant ~ half an in-flight window, so one
        # straggling connection's samples can't trigger a backoff alone
        alpha = min(1.0, 2.0 * self.batch_size
                    / max(self._budget_raw(ignore_drain=True), 1.0))
        self._rtt_ema = (rtt if self._rtt_ema is None
                         else self._rtt_ema + alpha * (rtt - self._rtt_ema))
        # delivery-rate buckets (t_done is monotone on one clock)
        w = self.cfg.rate_window
        rb = math.floor(t_done / w) * w
        if self._rate_events and self._rate_events[-1][0] == rb:
            self._rate_events[-1][1] += 1.0
        else:
            self._rate_events.append([rb, 1.0])
            # trim by count AND by age: after a completion gap (outage,
            # PROBE_RTT drain) stale buckets would otherwise stretch the
            # rate series across the whole gap until count-eviction catches
            # up
            horizon = rb - w * (self.cfg.rate_buckets + 1)
            while (len(self._rate_events) > self.cfg.rate_buckets + 1
                   or self._rate_events[0][0] < horizon):
                self._rate_events.popleft()
        # average sample size (EMA) for byte<->sample conversions
        self._avg_bytes = (float(nbytes) if self._avg_bytes is None
                           else 0.99 * self._avg_bytes + 0.01 * nbytes)
        # grow the probe window: +1 sample per completion in slow start
        # (doubles per RTT); +1 batch per RTT afterwards (additive increase)
        if self._slow_start:
            self._probe_cap += 1.0
        else:
            # ~probe_cap completions arrive per RTT, so +B/probe_cap per
            # completion compounds to +1 batch per RTT (TCP's MSS/cwnd)
            self._probe_cap += self.batch_size / max(self._probe_cap, 1.0)
        self._probe_cap = min(self._probe_cap, self._ceiling)
        # queueing-delay congestion signal.  The expected RTT under our own
        # standing load is propagation plus the time the budget takes to
        # serialize at the measured delivery rate — on transfer-dominated
        # routes that serialization term dwarfs the propagation min, so
        # comparing the smoothed RTT against ``inflation x min_rtt`` alone
        # would read normal batch-burst service as congestion and pin the
        # budget at the floor.
        min_rtt = self.min_rtt()
        rate = self.delivery_rate()
        if min_rtt is not None and self._rtt_ema is not None:
            expected = min_rtt + (
                self._budget_raw(ignore_drain=True) / rate
                if rate else 0.0)
            if (self._rtt_ema > self.cfg.rtt_inflation * expected
                    and t_done >= self._cooldown_until):
                self.backoffs += 1
                self._back_off(t_done, min_rtt)
        # PROBE_RTT: periodically drain the self-inflicted queue so a
        # *lower* propagation delay can show itself (the anchor only moves
        # down on a queue-free sample; upward moves go through regime
        # detection).  Skipped when already at the floor — nothing to drain.
        if t_done >= self._next_probe_rtt and t_done >= self._drain_until:
            # RTT-aware cadence (see FlowControlConfig.probe_rtt_interval):
            # a ~1-RTT drain (the standing queue at the cap is at most
            # (gain - 1) x BDP) every >= 10 RTTs caps drain overhead at
            # ~10% no matter how far a schedule has pushed the route's RTT
            self._next_probe_rtt = t_done + max(
                self.cfg.probe_rtt_interval, 10.0 * (min_rtt or 0.0))
            if self._budget_raw(ignore_drain=True) > 1.25 * self._floor:
                self.rtt_probes += 1
                self._drain_until = t_done + max(min_rtt or 0.0, 1e-3)
        self._record()

    def _regime_check(self) -> None:
        """Called when a min-RTT bucket completes: has the route shifted?

        A *completed* bucket whose minimum still sits far above the filter
        minimum means not one sample in a whole bucket width touched the old
        floor — a sustained move, not queueing noise (PROBE_RTT drains keep
        standing queues out of the picture).  After ``regime_buckets``
        such buckets in a row, drop the stale pre-shift evidence and
        re-slow-start toward the new BDP."""
        done_min = self._rtt_mins[-1][1]
        overall = self.min_rtt()    # the anchor: propagation-delay floor
        if not done_min > self.cfg.regime_factor * overall:
            # Dead-band ratchet: a standing queue inflates samples by at
            # most the budget gain, so a completed bucket whose *minimum*
            # sits above ``gain x anchor`` proves the propagation delay
            # itself moved — just not (yet) far enough for a full regime
            # shift.  Raise the anchor to the safe under-estimate
            # ``done_min / gain`` (true min >= that), letting the budget
            # track slow ramps without a re-slow-start; without this the
            # anchor pins the BDP term below a creeping route's real BDP
            # and the budget spirals toward the floor.
            if done_min > self.cfg.gain * overall:
                self._rtt_anchor = done_min / self.cfg.gain
                self._record()
            self._regime_streak = 0
            return
        self._regime_streak += 1
        if self._regime_streak < self.cfg.regime_buckets:
            return
        # Confirmed upward shift: keep only the new-regime buckets so the
        # min filter re-anchors *now* instead of when rtt_window expires,
        # drop any checkpoint hints (evidence from the old regime), and
        # re-probe — the BDP under the new regime is unknown, so slow-start
        # growth (+1 sample per completion) from the current cap finds it
        # in O(log) RTTs instead of one additive batch per RTT.
        self.regime_shifts += 1
        self._regime_streak = 0
        while len(self._rtt_mins) > self.cfg.regime_buckets:
            self._rtt_mins.popleft()
        self._rtt_anchor = min(m for _, m in self._rtt_mins)
        self._min_rtt_hint = None
        self._rate_hint = None
        self._rate_cache = None
        self._slow_start = True
        # The filter just re-anchored to the new regime (and the budget sat
        # near the floor through the detection window, so the surviving
        # samples are queue-free) — a PROBE_RTT drain now would only stall
        # the re-slow-start.  Defer it a full RTT-aware interval.
        self._next_probe_rtt = self._clock.now() + max(
            self.cfg.probe_rtt_interval, 10.0 * (self.min_rtt() or 0.0))
        self._record()

    def note_inflight(self, inflight: int) -> None:
        """Sample the pool's in-flight count (called per issued fetch)."""
        self._inflight_ema = (float(inflight) if self._inflight_ema is None
                              else 0.95 * self._inflight_ema
                              + 0.05 * inflight)
        if self._limiter is not None:
            self._limiter.note_issue()

    @property
    def limiter(self) -> Optional[SharedIngressLimiter]:
        """The shared-ingress limiter / tenant scheduler this controller is
        registered with (``None`` when the consumer owns its NIC)."""
        return self._limiter

    def inflight_samples(self) -> float:
        """Measured in-flight load (EMA of the pool's at-issue samples) —
        what tenant-level admission compares against the share's BDP."""
        return self._inflight_ema or 0.0

    def on_failure(self) -> None:
        """A connection failed over — treat like a loss event."""
        self._loss_signal()

    def on_hedge(self) -> None:
        """A hedge fired (straggler past ``hedge_after``) — mild congestion."""
        self._loss_signal()

    def _loss_signal(self) -> None:
        now = self._clock.now()
        if now < self._cooldown_until:
            return
        self.loss_signals += 1
        self._back_off(now, self.min_rtt())
        self._record()

    def _back_off(self, now: float, min_rtt: Optional[float]) -> None:
        self._slow_start = False
        self._probe_cap = max(self.cfg.beta
                              * self._budget_raw(ignore_drain=True),
                              self._floor)
        self._cooldown_until = now + max(min_rtt or 0.0, 1e-3)

    # -- estimates ----------------------------------------------------------
    def min_rtt(self) -> Optional[float]:
        if self._rtt_anchor is not None:
            return self._rtt_anchor
        if self._rtt_mins:
            return min(m for _, m in self._rtt_mins)
        return self._min_rtt_hint

    def delivery_rate(self) -> Optional[float]:
        """Max windowed delivery rate (samples/s) over complete buckets."""
        last = self._rate_events[-1] if self._rate_events else None
        key = (self._clock.now(), len(self._rate_events),
               last[0] if last else None, last[1] if last else None)
        if self._rate_cache is not None and self._rate_cache[0] == key:
            return self._rate_cache[1]
        done = [(t, n) for t, n in self._rate_events
                if t + self.cfg.rate_window <= self._clock.now()]
        if not done:
            rate = self._rate_hint
        else:
            series = windowed_series(done, self.cfg.rate_window,
                                     start=done[0][0])
            rate = max(r for _, r in series)
        self._rate_cache = (key, rate)
        return rate

    def bdp_samples(self) -> Optional[float]:
        rate, min_rtt = self.delivery_rate(), self.min_rtt()
        if rate is None or min_rtt is None:
            return None
        return rate * min_rtt

    def avg_sample_bytes(self) -> Optional[float]:
        return self._avg_bytes

    def hedge_after(self) -> Optional[float]:
        """Adaptive hedge delay: ``hedge_rtt_multiple x min_rtt``.

        A straggler is a fetch taking several drained-route RTTs —
        whatever the route's scale — so the hedge trigger tracks the
        measured RTT instead of a hand-tuned constant (and tracks regime
        shifts along with the min filter).  ``None`` until a first RTT
        sample exists: hedging against an unmeasured route is a guess."""
        min_rtt = self.min_rtt()
        if min_rtt is None:
            return None
        return self.cfg.hedge_rtt_multiple * min_rtt

    def in_drain(self) -> bool:
        """True inside a PROBE_RTT drain window.  Hedging is suppressed
        there: the standing queue is being drained on purpose, so slow
        completions are expected, and a duplicate fetch would both refill
        the queue and feed the controller a bogus loss signal."""
        return self._clock.now() < self._drain_until

    def io_parallelism(self, n_conns: int,
                       per_conn: int = 32) -> int:
        """Connections worth keeping active for the current budget
        (carried-over ROADMAP item: the controller drives issue
        *parallelism*, not just depth).  Sized so each active connection
        holds ~``per_conn`` in-flight samples — enough to keep its AIMD
        process probing — so a shallow local budget runs a few warm
        streams while a WAN budget fans out to all of them.  Consumed by
        ``ConnectionPool`` routing when ``io_scaling`` is on."""
        budget = self._budget_raw(ignore_drain=True)
        return max(1, min(n_conns, int(math.ceil(budget / max(per_conn, 1)))))

    def spare_bdp_samples(self) -> float:
        """Unused in-flight headroom: operating budget minus the measured
        in-flight load.  A member pinned at its budget has ~0 spare; an
        underused (or entirely idle) member exposes its full headroom —
        what bandwidth-aware ownership rebalancing shifts keys toward."""
        budget = self._budget_raw(ignore_drain=True)
        if self._inflight_ema is None:
            return budget               # never asked to carry anything
        return max(0.0, budget - self._inflight_ema)

    # -- budget -------------------------------------------------------------
    def _budget_raw(self, ignore_drain: bool = False) -> float:
        # min(probe, gain x BDP): the probe window rules out an unbounded
        # burst while the rate filter is still warming up, and the BDP
        # target rules out over-buffering once it is — the rate estimate
        # saturates at the true bottleneck, so gain x BDP is self-limiting
        # even while the probe keeps slow-starting.
        if not ignore_drain and self._clock.now() < self._drain_until:
            return self._floor          # PROBE_RTT: drain to re-measure
        cap = self._probe_cap
        bdp = self.bdp_samples()
        if bdp is not None:
            # + one batch: issue is batch-quantized, so the pipe needs the
            # next batch already in flight while a completed one hands over
            # (TCP's cwnd = BDP + MSS).  Without it, a route whose BDP
            # falls just under one batch pins at depth 1, where handover
            # gaps idle the pipe — and the delivery-rate filter, measuring
            # only what the throttled pipe delivers, can never prove the
            # capacity needed to lift the cap back out.
            cap = min(cap, self.cfg.gain * bdp + self.batch_size)
        if self._limiter is not None:
            cap = min(cap, self._limiter.fair_cap_samples(self))
        return min(max(cap, self._floor), self._ceiling)

    def budget(self) -> int:
        """Allowed in-flight samples right now."""
        return int(self._budget_raw())

    def operating_budget(self) -> int:
        """The steady operating point — what the budget returns to after a
        transient PROBE_RTT drain (what reports and snapshots record)."""
        return int(self._budget_raw(ignore_drain=True))

    def depth(self, batch_size: Optional[int] = None) -> int:
        """Budget expressed in batches (what ``_target_depth`` consumes)."""
        b = batch_size or self.batch_size
        return max(self.cfg.floor_batches,
                   min(self.cfg.ceiling_batches,
                       int(math.ceil(self._budget_raw() / b))))

    def _record(self) -> None:
        b = self.budget()
        if not self.budget_trace or self.budget_trace[-1][1] != b:
            self.budget_trace.append((self._clock.now(), b))

    # -- checkpoint ---------------------------------------------------------
    def snapshot(self) -> Dict:
        """Epoch-boundary state: everything a restore needs to resume at the
        measured operating point instead of re-slow-starting."""
        return {
            "budget": float(self._budget_raw(ignore_drain=True)),
            "probe_cap": float(self._probe_cap),
            "min_rtt": self.min_rtt(),
            "rate": self.delivery_rate(),
            "avg_bytes": self._avg_bytes,
            "backoffs": self.backoffs,
            "loss_signals": self.loss_signals,
        }

    def restore(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if "members" in state:
            # federation-shaped snapshot restored onto a single-route
            # controller (e.g. a federated checkpoint onto a plain run):
            # collapse the members — budgets/rates sum, min-RTT is the min
            state = _collapse_members(state)
            if not state:
                return
        self._probe_cap = min(max(float(state.get("probe_cap")
                                        or state.get("budget")
                                        or self._floor),
                                  self._floor), self._ceiling)
        self._min_rtt_hint = state.get("min_rtt")
        self._rate_hint = state.get("rate")
        self._rate_cache = None
        if state.get("avg_bytes"):
            self._avg_bytes = float(state["avg_bytes"])
        # re-seeded, not fresh: the hints govern until real samples land, and
        # regrowth is additive (no second slow-start burst on a warm cluster)
        self._slow_start = False
        self._record()

    # -- reporting ----------------------------------------------------------
    def report(self) -> Dict:
        operating = self.operating_budget()
        return {
            "name": self.name,
            "budget_samples": operating,
            "depth_batches": max(self.cfg.floor_batches,
                                 min(self.cfg.ceiling_batches,
                                     int(math.ceil(operating
                                                   / self.batch_size)))),
            "bdp_samples": self.bdp_samples(),
            "min_rtt_s": self.min_rtt(),
            "rate_samples_per_s": self.delivery_rate(),
            "spare_bdp_samples": self.spare_bdp_samples(),
            "slow_start": self._slow_start,
            "backoffs": self.backoffs,
            "loss_signals": self.loss_signals,
            "rtt_probes": self.rtt_probes,
            "regime_shifts": self.regime_shifts,
            "completions": self.completions,
        }


class FlowControllerGroup:
    """One controller per member cluster of a federation; the host's budget
    is their sum, so each route ramps to its own BDP independently."""

    def __init__(self, controllers: Dict[str, FlowController],
                 batch_size: int) -> None:
        if not controllers:
            raise ValueError("a controller group needs at least one member")
        self.members = dict(controllers)
        self.batch_size = batch_size
        first = next(iter(self.members.values()))
        self.cfg = first.cfg

    def budget(self) -> int:
        return sum(c.budget() for c in self.members.values())

    def depth(self, batch_size: Optional[int] = None) -> int:
        b = batch_size or self.batch_size
        total = sum(c._budget_raw() for c in self.members.values())
        return max(1, int(math.ceil(total / b)))

    def spare_by_member(self) -> Dict[str, float]:
        """Per-member spare BDP (samples) — the rebalance input signal."""
        return {name: c.spare_bdp_samples()
                for name, c in self.members.items()}

    def snapshot(self) -> Dict:
        return {"members": {name: c.snapshot()
                            for name, c in self.members.items()}}

    def restore(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if "members" not in state:
            # plain snapshot restored onto a federation group (e.g. a
            # single-cluster checkpoint onto a federated run): split the
            # budget evenly; each member's own samples re-shape it quickly
            share = _scale_snapshot(state, 1.0 / len(self.members))
            for ctl in self.members.values():
                ctl.restore(share)
            return
        for name, member_state in (state.get("members") or {}).items():
            if name in self.members:
                self.members[name].restore(member_state)

    def report(self) -> Dict:
        members = {name: c.report() for name, c in self.members.items()}
        total = sum(m["budget_samples"] for m in members.values())
        return {
            "budget_samples": total,
            "depth_batches": max(1, int(math.ceil(total / self.batch_size))),
            "members": members,
        }


def _mean(values: List[float]) -> Optional[float]:
    vals = [v for v in values if v is not None]
    return sum(vals) / len(vals) if vals else None


def _collapse_members(state: Dict) -> Optional[Dict]:
    """Flatten a federation-shaped snapshot into a single-route one: the
    summed member budgets seed the probe cap.  No rate hint — a summed rate
    times the *minimum* member RTT would be a meaningless BDP for
    heterogeneous routes (WAN rate x local RTT), so the first real rate
    buckets re-shape the budget instead."""
    members = [m for m in (state.get("members") or {}).values() if m]
    if not members:
        return None
    total_budget = sum(m.get("budget") or 0.0 for m in members)
    return {
        "budget": total_budget,
        "probe_cap": total_budget,
        "min_rtt": min((m["min_rtt"] for m in members
                        if m.get("min_rtt") is not None), default=None),
        "rate": None,
        "avg_bytes": _mean([m.get("avg_bytes") for m in members]),
        "backoffs": 0,
        "loss_signals": 0,
    }


def _scale_snapshot(state: Dict, factor: float) -> Dict:
    """Scale the extensive quantities (budget, probe cap, rate) of a plain
    snapshot; intensive ones (min-RTT, sample size) pass through."""
    out = dict(state)
    for key in ("budget", "probe_cap", "rate"):
        if out.get(key) is not None:
            out[key] = float(out[key]) * factor
    return out


def merge_snapshots(snapshots: List[Dict], new_count: int) -> Optional[Dict]:
    """Combine N shards' controller snapshots into the seed for one of M new
    shards (elastic N->M restore): the cluster-wide in-flight total is
    conserved (budgets sum, then split M ways), the min-RTT floor is the min
    over shards, and per-member federation snapshots merge by cluster name.
    """
    snapshots = [s for s in snapshots if s]
    if not snapshots or new_count < 1:
        return None
    if "members" in snapshots[0]:
        names = {n for s in snapshots for n in (s.get("members") or {})}
        return {"members": {
            n: merge_snapshots([(s.get("members") or {}).get(n)
                                for s in snapshots], new_count)
            for n in names}}
    scale = len(snapshots) / float(new_count)
    rates = _mean([s.get("rate") for s in snapshots])
    return {
        "budget": _mean([s.get("budget") for s in snapshots]) * scale,
        "probe_cap": _mean([s.get("probe_cap") or s.get("budget")
                            for s in snapshots]) * scale,
        "min_rtt": min((s["min_rtt"] for s in snapshots
                        if s.get("min_rtt") is not None), default=None),
        "rate": rates * scale if rates is not None else None,
        "avg_bytes": _mean([s.get("avg_bytes") for s in snapshots]),
        "backoffs": 0,
        "loss_signals": 0,
    }


FLOW_CONTROL_MODES = ("static", "adaptive")

__all__ = ["FlowControlConfig", "FlowController", "FlowControllerGroup",
           "SharedIngressLimiter", "merge_snapshots", "FLOW_CONTROL_MODES"]
