"""Baseline loader models the paper compares against (Table 2).

Both run against the *same* network/storage simulator as our loader, so the
comparison isolates loader strategy from environment:

``RecordShardLoader`` — MosaicML StreamingDataset model: the dataset is
pre-packed into record-file shards; the client keeps ``predownload`` shard
downloads in flight, each over a *fresh* connection (S3-style GET: 2-RTT
setup + AIMD ramp from half rate — short-lived connections never reach
capacity at high RTT, which is exactly why SD degrades intercontinentally).
Samples are then served from completed shards with a window shuffle (the
non-uniform shuffle the paper criticizes).

``SyncWindowLoader`` — tf.data service model: a synchronous request/response
stream with a bounded in-flight window; throughput ~ window/(RTT + overhead),
collapsing with distance as in Table 3.

Both baselines are deliberately **codec-free**: neither system ships a wire
codec in the configuration the paper measures, so their requests take the
node ``serve()`` / ``SimConnection.request`` default path (``wire_bytes =
payload bytes``, ``encode_seconds = 0``).  Our stack is allowed to enable
codecs in the comparison — that asymmetry is part of the result, not a bug.
``benchmarks/bench_competitors.py`` runs both against the adaptive stack on
the same scenario cells.
"""

from __future__ import annotations

import dataclasses
import uuid as _uuid
from dataclasses import dataclass
from typing import Callable, List, Optional

import numpy as np

from .cluster import Cluster
from .kvstore import KVStore
from .netsim import (Clock, RateResource, RouteProfile, SimConnection, TIERS,
                     NIC_BANDWIDTH)


@dataclass
class ShardSpec:
    uuids: List[_uuid.UUID]
    nbytes: int


def build_shards(store: KVStore, uuids: List[_uuid.UUID],
                 shard_bytes: int = 64 * 2 ** 20) -> List[ShardSpec]:
    """Pack samples into record-file shards in *storage* order (rigid)."""
    shards: List[ShardSpec] = []
    cur: List[_uuid.UUID] = []
    acc = 0
    for u in uuids:
        row = store.get_data(u)
        cur.append(u)
        acc += row.size
        if acc >= shard_bytes:
            shards.append(ShardSpec(cur, acc))
            cur, acc = [], 0
    if cur:
        shards.append(ShardSpec(cur, acc))
    return shards


class RecordShardLoader:
    """MosaicML-SD-style shard streaming over the simulated network.

    Codec-free by design: StreamingDataset's shard GETs carry the packed
    record bytes as-is, so every ``SimConnection.request`` here uses the
    default ``wire_bytes``/``encode_seconds`` path (wire == payload, no
    node-side encode CPU).  Time-varying routes are honoured: the capped
    route is derived with ``dataclasses.replace``, keeping burst/schedule/
    outage fields, and the AIMD model samples them at event time.
    """

    S3_SETUP_RTTS = 2.0             # TCP+TLS handshake per GET
    S3_STREAM_CAP = 45.0e6          # per-object GET throughput ceiling, B/s
    S3_FIRST_BYTE = 0.030           # request processing at the gateway
    S3_PIECE = 4 * 2 ** 20          # stream shards in pieces so TCP ramps

    def __init__(self, clock: Clock, cluster: Cluster, route: str | RouteProfile,
                 shards: List[ShardSpec], batch_size: int = 512,
                 predownload: int = 8, seed: int = 0) -> None:
        self.clock = clock
        self.cluster = cluster
        self.route = TIERS[route] if isinstance(route, str) else route
        self.batch_size = batch_size
        self.predownload = predownload
        self._rng = np.random.default_rng(seed)
        order = self._rng.permutation(len(shards))  # shard-level shuffle only
        self._shards = [shards[i] for i in order]
        self._next_shard = 0
        self._ready_samples: List[tuple] = []   # (uuid, size)
        self._downloading = 0
        self._consumed_batches = 0
        self.bytes_received = 0
        self.batch_consume_t: List[float] = []
        self._ingress = RateResource("sd/ingress", NIC_BANDWIDTH)
        self._conn_seq = 0
        self._node = list(cluster.nodes.values())[0]

    # -- shard downloads -----------------------------------------------------
    def _start_downloads(self) -> None:
        while (self._downloading < self.predownload
               and self._next_shard < len(self._shards)):
            shard = self._shards[self._next_shard]
            self._next_shard += 1
            self._downloading += 1
            # fresh connection per GET: setup + AIMD ramp from half rate.
            # replace() keeps every other RouteProfile field (burst model,
            # schedules, outages) — a positional rebuild here once silently
            # dropped them, pinning competitor runs to a static network.
            cap_route = dataclasses.replace(
                self.route,
                conn_capacity=min(self.route.conn_capacity,
                                  self.S3_STREAM_CAP))
            conn = SimConnection(self._conn_seq, self.clock, self._node, cap_route,
                                 np.random.default_rng(1000 + self._conn_seq),
                                 self._ingress)
            self._conn_seq += 1
            setup = self.S3_SETUP_RTTS * self.route.rtt + self.S3_FIRST_BYTE

            def fire(sh=shard, cn=conn):
                # stream the shard in pieces so the fresh connection's AIMD
                # rate actually ramps during the transfer
                n_pieces = max(sh.nbytes // self.S3_PIECE, 1)
                state = {"left": n_pieces}

                def piece_done(t, sh=sh):
                    state["left"] -= 1
                    if state["left"] == 0:
                        self._shard_done(sh)

                per = sh.nbytes // n_pieces
                for _ in range(n_pieces):
                    cn.request(per, piece_done)

            self.clock.schedule(setup, fire)

    def _shard_done(self, shard: ShardSpec) -> None:
        self._downloading -= 1
        self.bytes_received += shard.nbytes
        sizes = [self.cluster.store.get_data(u).size for u in shard.uuids]
        samples = list(zip(shard.uuids, sizes))
        self._ready_samples.extend(samples)
        # window shuffle inside the download buffer (non-uniform by design)
        self._rng.shuffle(self._ready_samples)
        self._start_downloads()

    # -- consumption ---------------------------------------------------------
    def start(self) -> "RecordShardLoader":
        self._start_downloads()
        return self

    def next_batch(self, timeout: float = 600.0) -> List[tuple]:
        ok = self.clock.run_until(
            lambda: len(self._ready_samples) >= self.batch_size, timeout=timeout)
        if not ok:
            raise TimeoutError("RecordShardLoader starved")
        batch = self._ready_samples[:self.batch_size]
        del self._ready_samples[:self.batch_size]
        self._consumed_batches += 1
        self.batch_consume_t.append(self.clock.now())
        self._start_downloads()
        return batch

    def throughput(self, skip: int = 2) -> float:
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0, t1 = self.batch_consume_t[skip], self.batch_consume_t[-1]
        n = len(self.batch_consume_t) - skip - 1
        avg_b = self.bytes_received / max(self._consumed_batches, 1)
        return n * avg_b / max(t1 - t0, 1e-9)


class SyncWindowLoader:
    """tf.data-service-style synchronous streaming: bounded window per RTT.

    Codec-free by design: the tf.data service protocol streams serialized
    elements uncompressed, so the modelled round-trip carries raw payload
    bytes — no wire codec, no node-side encode CPU.  The analytic window
    model only samples route RTT/capacity, so it is insensitive to the
    schedule-aware route extensions by construction.
    """

    WINDOW_BYTES = 1.3e6            # in-flight element window
    OVERHEAD = 0.0012               # serialization + dispatcher overhead, s
    STREAM_BW = 1.3e9               # worker->client stream rate, B/s

    def __init__(self, clock: Clock, cluster: Cluster, route: str | RouteProfile,
                 avg_sample_bytes: int, batch_size: int = 512, seed: int = 0) -> None:
        self.clock = clock
        self.route = TIERS[route] if isinstance(route, str) else route
        self.batch_size = batch_size
        self.avg_sample_bytes = avg_sample_bytes
        self._rng = np.random.default_rng(seed)
        self.bytes_received = 0
        self.batch_consume_t: List[float] = []
        self._buffered = 0.0        # samples available client-side
        self._round_pending = False

    def _round_trip(self) -> None:
        if self._round_pending:
            return
        self._round_pending = True
        transfer = self.WINDOW_BYTES / min(self.route.conn_capacity * 2,
                                           self.STREAM_BW)
        dt = self.route.rtt + self.OVERHEAD + transfer
        jitter = 1.0 + 0.05 * float(self._rng.uniform(-1, 1))

        def done() -> None:
            self._round_pending = False
            self.bytes_received += self.WINDOW_BYTES
            self._buffered += self.WINDOW_BYTES / self.avg_sample_bytes
            if self._buffered < 4 * self.batch_size:
                self._round_trip()

        self.clock.schedule(dt * jitter, done)

    def start(self) -> "SyncWindowLoader":
        self._round_trip()
        return self

    def next_batch(self, timeout: float = 3000.0) -> int:
        def ready() -> bool:
            if self._buffered < self.batch_size and not self._round_pending:
                self._round_trip()
            return self._buffered >= self.batch_size

        ok = self.clock.run_until(ready, timeout=timeout)
        if not ok:
            raise TimeoutError("SyncWindowLoader starved")
        self._buffered -= self.batch_size
        self.batch_consume_t.append(self.clock.now())
        self._round_trip()
        return self.batch_size

    def throughput(self, skip: int = 2) -> float:
        if len(self.batch_consume_t) <= skip + 1:
            return 0.0
        t0, t1 = self.batch_consume_t[skip], self.batch_consume_t[-1]
        n = len(self.batch_consume_t) - skip - 1
        return n * self.batch_size * self.avg_sample_bytes / max(t1 - t0, 1e-9)


__all__ = ["ShardSpec", "build_shards", "RecordShardLoader", "SyncWindowLoader"]
