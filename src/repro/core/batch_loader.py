"""Batch assembly (paper Fig. 2): requests fan out, callbacks fan in.

After the last sample of a batch arrives, the output tensor is allocated
contiguously in one shot and samples are copied in by a thread pool; the
batch becomes available when the copy completes.  In virtual-clock mode the
copy is *modelled* (bytes / host-copy bandwidth); in real-clock mode the copy
actually happens into a preallocated numpy arena (shared-memory analogue).
"""

from __future__ import annotations

import functools
import uuid as _uuid
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from .arena import ArenaSlab, PinnedArena
from .connection import ConnectionPool, FetchResult
from .netsim import Clock

HOST_COPY_BANDWIDTH = 20.0e9  # bytes/s, multi-threaded memcpy into the arena


@dataclass
class AssembledBatch:
    """One output batch: features+labels, ready for the device feed.

    With an arena-backed assembler the payload bytes live in ``slab`` (one
    reused contiguous buffer; the per-sample ``FetchResult.payload`` refs
    are dropped at assembly) and ``payloads()`` serves zero-copy views.
    ``nbytes`` is *decoded* (host/consumer) bytes; ``wire_nbytes`` is what
    actually crossed the network — they differ under a wire codec, and
    egress/tenant accounting must use the wire figure.
    """

    seq: int
    samples: List[FetchResult]
    t_first_issue: float
    t_last_arrival: float
    t_ready: float
    epoch: int = 0
    slab: Optional[ArenaSlab] = None

    @property
    def nbytes(self) -> int:
        """Decoded payload bytes (what the host/device consume)."""
        return sum(s.size for s in self.samples)

    @property
    def wire_nbytes(self) -> int:
        """Encoded bytes billed on the wire (== nbytes without a codec)."""
        return sum(s.wire_size for s in self.samples)

    @property
    def labels(self) -> np.ndarray:
        return np.asarray([s.label for s in self.samples], dtype=np.int32)

    def payloads(self) -> "List[Optional[bytes] | memoryview]":
        if self.slab is not None:
            return [self.slab.view(i, s.size)
                    for i, s in enumerate(self.samples)]
        return [s.payload for s in self.samples]

    def pixels(self, h: int, w: int, c: int) -> np.ndarray:
        """Zero-copy ``(B, h, w, c)`` uint8 view (arena batches only)."""
        if self.slab is None:
            raise ValueError("pixels() needs an arena-backed batch "
                             "(LoaderConfig.use_arena=True)")
        return self.slab.pixels(h, w, c)

    def release(self) -> None:
        """Recycle the arena slab (no-op otherwise).  Call after the batch
        content has been uploaded/consumed; views from ``payloads()`` /
        ``pixels()`` must not be read afterwards."""
        if self.slab is not None:
            self.slab.release()

    @property
    def uuids(self) -> List[_uuid.UUID]:
        return [s.uuid for s in self.samples]


class BatchAssembler:
    """Models (or performs) the contiguous-allocation + parallel-copy stage."""

    def __init__(self, clock: Clock, copy_bandwidth: float = HOST_COPY_BANDWIDTH,
                 real_copy: bool = False,
                 arena: Optional[PinnedArena] = None) -> None:
        self._clock = clock
        self._copy_bw = copy_bandwidth
        self._real_copy = real_copy
        # Pinned arena (core/arena.py): real copies land in a reused
        # contiguous slab instead of a fresh bytearray per batch, and the
        # per-sample payload refs are dropped — the slab is the only copy.
        self._arena = arena
        self.bytes_assembled = 0

    def assemble(self, seq: int, epoch: int, samples: List[FetchResult],
                 on_ready: Callable[[AssembledBatch], None]) -> None:
        t_arr = max(s.t_done for s in samples)
        nbytes = sum(s.size for s in samples)
        self.bytes_assembled += nbytes
        slab = None
        if self._real_copy and self._arena is not None:
            slab = self._arena.acquire()
            for i, s in enumerate(samples):
                slab.write(i, s.payload, s.size)
                s.payload = None       # the slab owns the bytes now
        elif self._real_copy:
            # Legacy one-shot bytearray; copies are cheap at test scale.
            # Each sample owns exactly ``size`` bytes (payloads are
            # full-size since DataRow.materialize stopped truncating — clip
            # defensively so a short payload can never smear into its
            # neighbour's slot).
            arena = bytearray(nbytes)
            off = 0
            for s in samples:
                if s.payload is not None:
                    n = min(len(s.payload), s.size)
                    arena[off:off + n] = s.payload[:n]
                off += s.size
        delay = nbytes / self._copy_bw
        batch = AssembledBatch(seq=seq, samples=list(samples),
                               t_first_issue=min(s.t_issued for s in samples),
                               t_last_arrival=t_arr,
                               t_ready=self._clock.now() + delay,
                               epoch=epoch, slab=slab)
        self._clock.schedule(delay, on_ready, batch)


class BatchRequest:
    """In-order unit of work: all UUIDs of one batch requested at once.

    Results are tracked per *slot*, not per uuid: a batch that spans an epoch
    boundary can legitimately contain the same uuid twice (tail of one
    permutation + head of the next), and keying a dict by uuid would then
    wait forever on a count that can never be reached.
    """

    def __init__(self, seq: int, epoch: int, uuids: List[_uuid.UUID],
                 pool: ConnectionPool, assembler: BatchAssembler,
                 on_ready: Callable[[AssembledBatch], None]) -> None:
        self.seq = seq
        self.epoch = epoch
        self._results: List[Optional[FetchResult]] = [None] * len(uuids)
        self._got = 0
        self._want = len(uuids)
        self._assembler = assembler
        self._on_ready = on_ready
        for i, key in enumerate(uuids):  # all requests posted to the driver at once
            pool.fetch(key, functools.partial(self._one_done, i))

    def _one_done(self, slot: int, res: FetchResult) -> None:
        if self._results[slot] is not None:
            return
        self._results[slot] = res
        self._got += 1
        if self._got == self._want:
            self._assembler.assemble(self.seq, self.epoch,
                                     list(self._results), self._on_ready)


__all__ = ["AssembledBatch", "BatchAssembler", "BatchRequest",
           "HOST_COPY_BANDWIDTH"]
