"""Hymba-style hybrid blocks: parallel attention + Mamba heads.

Each block runs a sliding-window GQA attention path and a Mamba (selective
SSM) path over the same normalized input and averages the two (the paper's
learnable per-head fusion is simplified to a learned scalar mix; meta-tokens
are elided — noted in DESIGN.md).  SWA + SSM keeps the block sub-quadratic,
which is why this architecture runs the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

from . import attention as attn
from . import ssm as ssm_mod
from .layers import (embed, embed_spec, rmsnorm, rmsnorm_spec, softmax_xent,
                     swiglu, swiglu_spec, unembed)
from .params import P, abstract_params, init_params, logical_axes, stack_layer_specs
from .transformer import DENSE_ATTN_MAX_SEQ


class HymbaModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.d_inner = cfg.d_model          # mamba inner width
        self.constrain_act = None
        self.constrain_q = None
        self.constrain_kv = None

    def block_spec(self) -> Dict:
        c = self.cfg
        return {
            "ln1": rmsnorm_spec(c.d_model),
            "attn": attn.gqa_spec(c.d_model, c.n_heads, c.n_kv_heads,
                                  c.resolved_head_dim),
            "mamba": ssm_mod.mamba_spec(c.d_model, self.d_inner, c.ssm_state),
            "mix": P((1,), (None,), init="zeros"),     # sigmoid(mix) blend
            "ln2": rmsnorm_spec(c.d_model),
            "mlp": swiglu_spec(c.d_model, c.d_ff),
        }

    def param_specs(self) -> Dict:
        c = self.cfg
        return {"embed": embed_spec(c.vocab, c.d_model),
                "blocks": stack_layer_specs(self.block_spec(), c.n_layers),
                "ln_f": rmsnorm_spec(c.d_model)}

    def init(self, key, dtype=None) -> Dict:
        return init_params(self.param_specs(), key, dtype or self.dtype)

    def abstract_params(self) -> Dict:
        return abstract_params(self.param_specs(), self.dtype)

    def param_logical_axes(self) -> Dict:
        return logical_axes(self.param_specs())

    # -- full-sequence forward -------------------------------------------------
    def forward(self, params: Dict, tokens: jax.Array,
                extras: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens, self.dtype)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(h, layer):
            y = rmsnorm(layer["ln1"], h, c.norm_eps)
            q, k, v = attn.project_qkv(layer["attn"], y)
            q = attn.apply_rope(q, positions, c.rope_theta)
            k = attn.apply_rope(k, positions, c.rope_theta)
            k = attn.expand_kv(k, c.n_heads)
            v = attn.expand_kv(v, c.n_heads)
            if self.constrain_q is not None:
                q = self.constrain_q(q)
                k = self.constrain_kv(k)
                v = self.constrain_kv(v)
            if S <= DENSE_ATTN_MAX_SEQ:
                ao = attn.dense_attention(q, k, v, positions[0], positions[0],
                                          causal=True, window=c.window)
            else:
                ao = attn.chunked_attention(q, k, v, positions[0], positions[0],
                                            causal=True, window=c.window)
            ao = attn.project_out(layer["attn"], ao)
            mo, _ = ssm_mod.mamba_apply(layer["mamba"], y)
            mix = jax.nn.sigmoid(layer["mix"].astype(jnp.float32))[0]
            fused = (mix * ao.astype(jnp.float32)
                     + (1.0 - mix) * mo.astype(jnp.float32)).astype(h.dtype)
            h = h + fused
            y = rmsnorm(layer["ln2"], h, c.norm_eps)
            return cst(h + swiglu(layer["mlp"], y)), None

        cst = self.constrain_act or (lambda t: t)
        x = cst(x)
        fn = jax.checkpoint(body) if c.remat else body
        x, _ = jax.lax.scan(fn, x, params["blocks"])
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        return unembed(params["embed"], x), {}

    def train_loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        logits, _ = self.forward(params, tokens, batch)
        mask = batch.get("loss_mask")
        loss = softmax_xent(logits[:, :-1], tokens[:, 1:],
                            mask[:, 1:] if mask is not None else None)
        return loss, {"loss": loss, "xent": loss}

    # -- decode ------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        W = min(c.window or seq_len, seq_len)
        kv = attn.init_kv_cache(batch, W, c.n_kv_heads, c.resolved_head_dim,
                                self.dtype)
        kv_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[kv for _ in range(c.n_layers)])
        ms = ssm_mod.mamba_init_state(batch, self.d_inner, c.ssm_state,
                                      dtype=self.dtype)
        ms_stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (c.n_layers,) + x.shape), ms)
        return {"kv": kv_stack, "mamba": ms_stack}

    def cache_specs(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        W = min(c.window or seq_len, seq_len)
        kv = attn.cache_specs(batch, W, c.n_kv_heads, c.resolved_head_dim,
                              self.dtype)
        kv_stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((c.n_layers,) + s.shape, s.dtype), kv)
        ms = ssm_mod.mamba_state_specs(batch, self.d_inner, c.ssm_state,
                                       dtype=self.dtype)
        ms_stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((c.n_layers,) + s.shape, s.dtype), ms)
        return {"kv": kv_stack, "mamba": ms_stack}

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        x = embed(params["embed"], tokens, self.dtype)

        def body(x, scanned):
            layer, kv_cache, m_state = scanned
            y = rmsnorm(layer["ln1"], x, c.norm_eps)
            ao, new_kv = attn.decode_attention(layer["attn"], kv_cache, y,
                                               window=c.window,
                                               rope_theta=c.rope_theta)
            mo, new_ms = ssm_mod.mamba_apply(layer["mamba"], y, m_state)
            mix = jax.nn.sigmoid(layer["mix"].astype(jnp.float32))[0]
            fused = (mix * ao.astype(jnp.float32)
                     + (1.0 - mix) * mo.astype(jnp.float32)).astype(x.dtype)
            x = x + fused
            y = rmsnorm(layer["ln2"], x, c.norm_eps)
            return x + swiglu(layer["mlp"], y), (new_kv, new_ms)

        x, (new_kv, new_ms) = jax.lax.scan(
            body, x, (params["blocks"], cache["kv"], cache["mamba"]))
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, {"kv": new_kv, "mamba": new_ms}

    # -- shapes --------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "cache": self.cache_specs(B, S)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def make_batch(self, key: jax.Array, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.random.randint(key, (B, 1), 0, c.vocab),
                    "cache": self.init_cache(B, S)}
        return {"tokens": jax.random.randint(key, (B, S), 0, c.vocab)}

    def input_logical_axes(self, shape: ShapeConfig) -> Dict:
        if shape.kind == "decode":
            kv = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                  "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                  "pos": ("layers",)}
            ms = {"h": ("layers", "batch", "d_inner", "state"),
                  "conv": ("layers", "batch", "conv_k", "d_inner")}
            return {"tokens": ("batch", None), "cache": {"kv": kv, "mamba": ms}}
        return {"tokens": ("batch", "seq")}


__all__ = ["HymbaModel"]
