"""State-space / recurrent sequence mixers: Mamba-style selective SSM and
xLSTM's mLSTM / sLSTM cells.

Training path uses *chunked* parallel forms (associative scan within a chunk,
sequential carry across chunks) so activation memory is O(B * chunk * d *
state) instead of O(B * S * d * state); decode is an O(1)-state update —
which is exactly why the ssm/hybrid architectures run the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .params import P

CHUNK = 256


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (used by hymba's mamba heads)
# ---------------------------------------------------------------------------

def mamba_spec(d: int, d_inner: int, state: int, conv_k: int = 4) -> Dict:
    return {
        "w_in": P((d, 2 * d_inner), ("d_model", "d_inner2")),
        "conv_w": P((conv_k, d_inner), ("conv_k", "d_inner")),
        "w_dt": P((d_inner, d_inner), ("d_inner", "d_inner"), scale=0.1),
        "dt_bias": P((d_inner,), ("d_inner",), init="zeros"),
        "w_bc": P((d_inner, 2 * state), ("d_inner", "state2")),
        "a_log": P((d_inner, state), ("d_inner", "state"), init="zeros"),
        "d_skip": P((d_inner,), ("d_inner",), init="ones"),
        "w_out": P((d_inner, d), ("d_inner", "d_model")),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over seq. x (B,S,C), w (K,C)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else state
    return out, new_state


def _ssm_scan_chunked(da: jax.Array, dbx: jax.Array, h0: jax.Array,
                      chunk: int = CHUNK) -> Tuple[jax.Array, jax.Array]:
    """h_t = da_t * h_{t-1} + dbx_t (elementwise over (B,S,D,N) inputs).

    Associative scan inside chunks, lax.scan carry across chunks.
    Returns (h for every t, final h)."""
    B, S, D, N = da.shape
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    da_c = da.reshape(B, n_chunks, chunk, D, N).transpose(1, 0, 2, 3, 4)
    dbx_c = dbx.reshape(B, n_chunks, chunk, D, N).transpose(1, 0, 2, 3, 4)

    def chunk_step(h, inp):
        a, b = inp                                    # (B, chunk, D, N)
        # prefix within chunk via associative scan — in f32: the stored scan
        # elements stay bf16 (that is what dominates HBM), but accumulating
        # the prefix products in bf16 drifts away from the sequential decode
        # recurrence, which carries f32 state.
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(
            combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
        h_all = a_cum * h[:, None] + b_cum            # (B, chunk, D, N)
        # emit per-step states in the input dtype (bf16 on the train path)
        return h_all[:, -1], h_all.astype(a.dtype)

    h_final, h_chunks = jax.lax.scan(chunk_step, h0, (da_c, dbx_c))
    h_seq = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, D, N)
    return h_seq[:, :S], h_final


def mamba_apply(params: Dict, x: jax.Array,
                state: Optional[Dict] = None,
                ) -> Tuple[jax.Array, Dict]:
    """x (B,S,d). state (decode): {'h': (B,D,N), 'conv': (B,K-1,D)}."""
    B, S, d = x.shape
    D = params["w_in"].shape[1] // 2
    N = params["a_log"].shape[1]
    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _causal_conv(xs, params["conv_w"].astype(xs.dtype), conv_state)
    xs = jax.nn.silu(xs)

    dt = jax.nn.softplus(jnp.einsum("bsD,DE->bsE", xs, params["w_dt"])
                         + params["dt_bias"]).astype(jnp.float32)   # (B,S,D)
    bc = jnp.einsum("bsD,Dn->bsn", xs, params["w_bc"])
    b_in, c_out = jnp.split(bc.astype(jnp.float32), 2, axis=-1)     # (B,S,N)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))               # (D,N) < 0
    # (B,S,D,N) scan elements in bf16 (the state carry stays f32): these are
    # the largest SSM activations and dominate train-time HBM otherwise
    da = jnp.exp(dt[..., None] * a[None, None]).astype(jnp.bfloat16)
    dbx = ((dt * xs.astype(jnp.float32))[..., None]
           * b_in[:, :, None, :]).astype(jnp.bfloat16)

    h0 = (jnp.zeros((B, D, N), jnp.float32) if state is None
          else state["h"].astype(jnp.float32))
    h_seq, h_last = _ssm_scan_chunked(da, dbx, h0)
    y = jnp.einsum("bsDn,bsn->bsD", h_seq.astype(jnp.float32),
                   c_out).astype(x.dtype)
    y = y + xs * params["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsD,Dd->bsd", y, params["w_out"])
    new_state = {"h": h_last.astype(jnp.float32), "conv": new_conv}
    return out, new_state


def mamba_state_specs(batch: int, d_inner: int, state: int, conv_k: int = 4,
                      dtype=jnp.bfloat16) -> Dict:
    return {"h": jax.ShapeDtypeStruct((batch, d_inner, state), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, conv_k - 1, d_inner), dtype)}


def mamba_init_state(batch: int, d_inner: int, state: int, conv_k: int = 4,
                     dtype=jnp.bfloat16) -> Dict:
    return {"h": jnp.zeros((batch, d_inner, state), jnp.float32),
            "conv": jnp.zeros((batch, conv_k - 1, d_inner), dtype)}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory)
# ---------------------------------------------------------------------------

def mlstm_spec(d: int, n_heads: int, head_dim: int) -> Dict:
    return {
        "wq": P((d, n_heads, head_dim), ("d_model", "heads", "head_dim")),
        "wk": P((d, n_heads, head_dim), ("d_model", "heads", "head_dim")),
        "wv": P((d, n_heads, head_dim), ("d_model", "heads", "head_dim")),
        "w_if": P((d, 2 * n_heads), ("d_model", "heads2"), scale=0.1),
        "if_bias": P((2 * n_heads,), ("heads2",), init="zeros"),
        "wo": P((n_heads, head_dim, d), ("heads", "head_dim", "d_model")),
        "ogate": P((d, n_heads, head_dim), ("d_model", "heads", "head_dim"),
                   scale=0.1),
    }


def mlstm_apply(params: Dict, x: jax.Array, state: Optional[Dict] = None,
                chunk: int = CHUNK) -> Tuple[jax.Array, Dict]:
    """Chunkwise-parallel mLSTM. x (B,S,d).

    C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = (q_t C_t) / max(|q_t n_t|, 1)
    Gates are stabilized per chunk (log-space cumulative decays).
    """
    B, S, d = x.shape
    H, Dh = params["wq"].shape[1], params["wq"].shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]) * (Dh ** -0.5)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"]) * (Dh ** -0.5)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    gates = jnp.einsum("bsd,dg->bsg", x, params["w_if"]) + params["if_bias"]
    i_pre, f_pre = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,S,H)
    log_f = -jax.nn.softplus(-f_pre)          # log sigmoid — forget in (0,1)
    log_i = -jax.nn.softplus(-i_pre)          # stabilized input gate

    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-30.0)
    Sp = n_chunks * chunk

    def resh(t):
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = resh(q), resh(k), resh(v)
    lfc, lic = resh(log_f), resh(log_i)

    def chunk_step(carry, inp):
        C, n = carry                       # (B,H,Dh,Dh), (B,H,Dh)
        qb, kb, vb, lf, li = inp           # (B,chunk,H,*)
        lf_cum = jnp.cumsum(lf, axis=1)    # (B,chunk,H) log prod f_1..t
        # decay applied to the incoming state for each position t
        dec_in = jnp.exp(lf_cum)           # (B,chunk,H)
        # intra-chunk weights: a_{t,s} = exp(lf_cum_t - lf_cum_s + li_s), s<=t
        w_log = (lf_cum[:, :, None, :] - lf_cum[:, None, :, :]
                 + li[:, None, :, :])      # (B,t,s,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        w = jnp.where(mask, jnp.exp(w_log), 0.0)
        scores = jnp.einsum("bthk,bshk->btsh", qb, kb).astype(jnp.float32)
        intra_num = jnp.einsum("btsh,bshv->bthv", scores * w,
                               vb.astype(jnp.float32))
        # q_t . n_t  (normalizer): intra part is sum_s w_ts (q_t . k_s)
        intra_den = jnp.sum(scores * w, axis=2)                   # (B,t,H)
        inter_num = jnp.einsum("bthk,bhkv->bthv", qb.astype(jnp.float32),
                               C) * dec_in[..., None]
        inter_den = jnp.einsum("bthk,bhk->bth", qb.astype(jnp.float32),
                               n) * dec_in
        num = intra_num + inter_num
        den = jnp.abs(intra_den + inter_den)[..., None]
        h = num / jnp.maximum(den, 1.0)
        # state update to end of chunk
        dec_k = jnp.exp(lf_cum[:, -1:, :] - lf_cum + li)       # (B,chunk,H)
        C_new = C * jnp.exp(lf_cum[:, -1])[..., None, None] + jnp.einsum(
            "bshk,bshv->bhkv", (kb.astype(jnp.float32)
                                * dec_k[..., None]), vb.astype(jnp.float32))
        n_new = n * jnp.exp(lf_cum[:, -1])[..., None] + jnp.einsum(
            "bshk->bhk", kb.astype(jnp.float32) * dec_k[..., None])
        return (C_new, n_new), h

    C0 = (jnp.zeros((B, H, Dh, Dh), jnp.float32) if state is None
          else state["C"])
    n0 = (jnp.zeros((B, H, Dh), jnp.float32) if state is None
          else state["n"])
    (C_f, n_f), h_chunks = jax.lax.scan(chunk_step, (C0, n0),
                                        (qc, kc, vc, lfc, lic))
    h = h_chunks.swapaxes(0, 1).reshape(B, Sp, H, Dh)[:, :S]
    o_gate = jax.nn.sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, params["ogate"]).astype(jnp.float32))
    h = (h * o_gate).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", h, params["wo"])
    return out, {"C": C_f, "n": n_f}


def mlstm_state_specs(batch: int, n_heads: int, head_dim: int) -> Dict:
    return {"C": jax.ShapeDtypeStruct((batch, n_heads, head_dim, head_dim),
                                      jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, n_heads, head_dim), jnp.float32)}


def mlstm_init_state(batch: int, n_heads: int, head_dim: int) -> Dict:
    return {"C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
            "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32)}


def slstm_spec(d: int, n_heads: int) -> Dict:
    dh = d // n_heads
    return {
        "w_gates": P((d, 4 * d), ("d_model", "gates")),
        "r_gates": P((n_heads, dh, 4 * dh), ("heads", "head_dim", "gates_h"),
                     scale=0.5),
        "b_gates": P((4 * d,), ("gates",), init="zeros"),
        "w_out": P((d, d), ("d_model", "d_model_out")),
    }


def slstm_apply(params: Dict, x: jax.Array, state: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
    """Sequential sLSTM with exponential gating + per-head recurrence.

    x (B,S,d).  State: c,n,m,h each (B,d) (m is the log-stabilizer).
    """
    B, S, d = x.shape
    H = params["r_gates"].shape[0]
    dh = d // H
    zx = jnp.einsum("bsd,dg->bsg", x, params["w_gates"]) + params["b_gates"]
    zx = zx.astype(jnp.float32)

    def step(carry, z_t):
        c, n, m, h = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhk,hkg->bhg", hh,
                         params["r_gates"].astype(jnp.float32))
        z = z_t + rec.reshape(B, 4 * d)
        zi, zf, zz, zo = jnp.split(z, 4, axis=-1)
        log_f = -jax.nn.softplus(-zf)          # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, zi)     # stabilizer
        i = jnp.exp(zi - m_new)
        f = jnp.exp(log_f + m - m_new)
        c_new = f * c + i * jnp.tanh(zz)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        carry0 = (zeros, zeros, zeros - 10.0, zeros)
    else:
        carry0 = (state["c"], state["n"], state["m"], state["h"])
    carry, hs = jax.lax.scan(step, carry0, zx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)                   # (B,S,d)
    out = jnp.einsum("bsd,de->bse", hs, params["w_out"])
    c, n, m, h = carry
    return out, {"c": c, "n": n, "m": m, "h": h}


def slstm_state_specs(batch: int, d: int) -> Dict:
    z = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def slstm_init_state(batch: int, d: int) -> Dict:
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z - 10.0, "h": z}


__all__ = ["mamba_spec", "mamba_apply", "mamba_state_specs", "mamba_init_state",
           "mlstm_spec", "mlstm_apply", "mlstm_state_specs", "mlstm_init_state",
           "slstm_spec", "slstm_apply", "slstm_state_specs", "slstm_init_state",
           "CHUNK"]
