"""xLSTM stack: alternating mLSTM (matrix-memory) and sLSTM (scalar-memory)
blocks, per arXiv:2405.04517.  The 24-layer config is scanned as 12
(mLSTM, sLSTM) pairs; d_ff=0 — the cells carry their own projections.
O(1) decode state => runs the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

from . import ssm as ssm_mod
from .layers import embed, embed_spec, rmsnorm, rmsnorm_spec, softmax_xent, unembed
from .params import abstract_params, init_params, logical_axes, stack_layer_specs


class XLSTMModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_pairs = cfg.n_layers // 2
        self.head_dim = cfg.resolved_head_dim
        self.constrain_act = None
        self.constrain_q = None
        self.constrain_kv = None

    def pair_spec(self) -> Dict:
        c = self.cfg
        return {
            "ln_m": rmsnorm_spec(c.d_model),
            "mlstm": ssm_mod.mlstm_spec(c.d_model, c.n_heads, self.head_dim),
            "ln_s": rmsnorm_spec(c.d_model),
            "slstm": ssm_mod.slstm_spec(c.d_model, c.n_heads),
        }

    def param_specs(self) -> Dict:
        c = self.cfg
        return {"embed": embed_spec(c.vocab, c.d_model),
                "pairs": stack_layer_specs(self.pair_spec(), self.n_pairs),
                "ln_f": rmsnorm_spec(c.d_model)}

    def init(self, key, dtype=None) -> Dict:
        return init_params(self.param_specs(), key, dtype or self.dtype)

    def abstract_params(self) -> Dict:
        return abstract_params(self.param_specs(), self.dtype)

    def param_logical_axes(self) -> Dict:
        return logical_axes(self.param_specs())

    # -- forward -----------------------------------------------------------
    def forward(self, params: Dict, tokens: jax.Array,
                extras: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        x = embed(params["embed"], tokens, self.dtype)

        def body(h, pair):
            y = rmsnorm(pair["ln_m"], h, c.norm_eps)
            mo, _ = ssm_mod.mlstm_apply(pair["mlstm"], y)
            h = h + mo
            y = rmsnorm(pair["ln_s"], h, c.norm_eps)
            so, _ = ssm_mod.slstm_apply(pair["slstm"], y)
            return cst(h + so), None

        cst = self.constrain_act or (lambda t: t)
        x = cst(x)
        fn = jax.checkpoint(body) if c.remat else body
        x, _ = jax.lax.scan(fn, x, params["pairs"])
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        return unembed(params["embed"], x), {}

    def train_loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        logits, _ = self.forward(params, tokens, batch)
        mask = batch.get("loss_mask")
        loss = softmax_xent(logits[:, :-1], tokens[:, 1:],
                            mask[:, 1:] if mask is not None else None)
        return loss, {"loss": loss, "xent": loss}

    # -- decode ------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        m = ssm_mod.mlstm_init_state(batch, c.n_heads, self.head_dim)
        s = ssm_mod.slstm_init_state(batch, c.d_model)
        stack = lambda t: jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_pairs,) + x.shape), t)
        return {"mlstm": stack(m), "slstm": stack(s)}

    def cache_specs(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        m = ssm_mod.mlstm_state_specs(batch, c.n_heads, self.head_dim)
        s = ssm_mod.slstm_state_specs(batch, c.d_model)
        stack = lambda t: jax.tree.map(
            lambda sp: jax.ShapeDtypeStruct((self.n_pairs,) + sp.shape,
                                            sp.dtype), t)
        return {"mlstm": stack(m), "slstm": stack(s)}

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        x = embed(params["embed"], tokens, self.dtype)

        def body(x, scanned):
            pair, m_state, s_state = scanned
            y = rmsnorm(pair["ln_m"], x, c.norm_eps)
            mo, new_m = ssm_mod.mlstm_apply(pair["mlstm"], y, m_state)
            x = x + mo
            y = rmsnorm(pair["ln_s"], x, c.norm_eps)
            so, new_s = ssm_mod.slstm_apply(pair["slstm"], y, s_state)
            return x + so, (new_m, new_s)

        x, (new_m, new_s) = jax.lax.scan(
            body, x, (params["pairs"], cache["mlstm"], cache["slstm"]))
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, {"mlstm": new_m, "slstm": new_s}

    # -- shapes --------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "cache": self.cache_specs(B, S)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    def make_batch(self, key: jax.Array, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.random.randint(key, (B, 1), 0, c.vocab),
                    "cache": self.init_cache(B, S)}
        return {"tokens": jax.random.randint(key, (B, S), 0, c.vocab)}

    def input_logical_axes(self, shape: ShapeConfig) -> Dict:
        if shape.kind == "decode":
            m = {"C": ("layers", "batch", "heads", "head_dim", "head_dim_out"),
                 "n": ("layers", "batch", "heads", "head_dim")}
            s = {k: ("layers", "batch", "d_model")
                 for k in ("c", "n", "m", "h")}
            return {"tokens": ("batch", None),
                    "cache": {"mlstm": m, "slstm": s}}
        return {"tokens": ("batch", "seq")}


__all__ = ["XLSTMModel"]
