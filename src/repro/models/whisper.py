"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the brief, ``input_specs()`` supplies precomputed frame embeddings
(B, frames, d_model) — the conv frontend's output — so the model here is the
transformer backbone: sinusoidal-position encoder, causal decoder with
cross-attention, LayerNorm + GELU MLPs, learned decoder positions sized by
the requested shape (real Whisper caps at 448; the 32k decode shapes are a
config exercise, noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

from . import attention as attn
from .layers import (embed, embed_spec, gelu_mlp, gelu_mlp_spec, layernorm,
                     layernorm_spec, sinusoidal_positions, softmax_xent,
                     unembed)
from .params import P, abstract_params, init_params, logical_axes, stack_layer_specs
from .transformer import DENSE_ATTN_MAX_SEQ


class WhisperModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_enc = cfg.enc_layers or cfg.n_layers
        self.n_dec = cfg.n_layers
        self.constrain_act = None
        self.constrain_q = None
        self.constrain_kv = None

    # -- specs -----------------------------------------------------------
    def _enc_block_spec(self) -> Dict:
        c = self.cfg
        return {"ln1": layernorm_spec(c.d_model),
                "attn": attn.gqa_spec(c.d_model, c.n_heads, c.n_kv_heads,
                                      c.resolved_head_dim, bias=True),
                "ln2": layernorm_spec(c.d_model),
                "mlp": gelu_mlp_spec(c.d_model, c.d_ff)}

    def _dec_block_spec(self) -> Dict:
        c = self.cfg
        return {"ln1": layernorm_spec(c.d_model),
                "self_attn": attn.gqa_spec(c.d_model, c.n_heads, c.n_kv_heads,
                                           c.resolved_head_dim, bias=True),
                "ln_x": layernorm_spec(c.d_model),
                "cross_attn": attn.gqa_spec(c.d_model, c.n_heads, c.n_kv_heads,
                                            c.resolved_head_dim, bias=True),
                "ln2": layernorm_spec(c.d_model),
                "mlp": gelu_mlp_spec(c.d_model, c.d_ff)}

    def param_specs(self) -> Dict:
        c = self.cfg
        return {
            "embed": embed_spec(c.vocab, c.d_model),
            "enc_blocks": stack_layer_specs(self._enc_block_spec(), self.n_enc),
            "enc_ln": layernorm_spec(c.d_model),
            "dec_blocks": stack_layer_specs(self._dec_block_spec(), self.n_dec),
            "dec_ln": layernorm_spec(c.d_model),
        }

    def init(self, key, dtype=None) -> Dict:
        return init_params(self.param_specs(), key, dtype or self.dtype)

    def abstract_params(self) -> Dict:
        return abstract_params(self.param_specs(), self.dtype)

    def param_logical_axes(self) -> Dict:
        return logical_axes(self.param_specs())

    # -- encoder -----------------------------------------------------------
    def encode(self, params: Dict, frames: jax.Array) -> jax.Array:
        c = self.cfg
        B, F, _ = frames.shape
        x = frames.astype(self.dtype)
        x = x + sinusoidal_positions(F, c.d_model).astype(self.dtype)[None]
        pos = jnp.arange(F, dtype=jnp.int32)

        def body(h, layer):
            y = layernorm(layer["ln1"], h, c.norm_eps)
            q, k, v = attn.project_qkv(layer["attn"], y)
            o = attn.dense_attention(q, k, v, pos, pos, causal=False)
            h = h + attn.project_out(layer["attn"], o)
            y = layernorm(layer["ln2"], h, c.norm_eps)
            return h + gelu_mlp(layer["mlp"], y), None

        fn = jax.checkpoint(body) if c.remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
        return layernorm(params["enc_ln"], x, c.norm_eps)

    # -- decoder (full sequence: train / prefill) ---------------------------
    def forward(self, params: Dict, tokens: jax.Array, extras: Dict
                ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        B, S = tokens.shape
        enc_out = self.encode(params, extras["frames"])
        x = embed(params["embed"], tokens, self.dtype)
        x = x + sinusoidal_positions(S, c.d_model).astype(self.dtype)[None]
        pos = jnp.arange(S, dtype=jnp.int32)
        enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

        cst = self.constrain_act or (lambda t: t)
        x = cst(x)

        def body(h, layer):
            y = layernorm(layer["ln1"], h, c.norm_eps)
            q, k, v = attn.project_qkv(layer["self_attn"], y)
            if S <= DENSE_ATTN_MAX_SEQ:
                o = attn.dense_attention(q, k, v, pos, pos, causal=True)
            else:
                o = attn.chunked_attention(q, k, v, pos, pos, causal=True)
            h = h + attn.project_out(layer["self_attn"], o)
            y = layernorm(layer["ln_x"], h, c.norm_eps)
            q, k, v = attn.project_qkv(layer["cross_attn"], y, enc_out)
            o = attn.dense_attention(q, k, v, pos, enc_pos, causal=False)
            h = h + attn.project_out(layer["cross_attn"], o)
            y = layernorm(layer["ln2"], h, c.norm_eps)
            return cst(h + gelu_mlp(layer["mlp"], y)), None

        fn = jax.checkpoint(body) if c.remat else body
        x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
        x = layernorm(params["dec_ln"], x, c.norm_eps)
        return unembed(params["embed"], x), {}

    def train_loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        logits, _ = self.forward(params, tokens, batch)
        loss = softmax_xent(logits[:, :-1], tokens[:, 1:])
        return loss, {"loss": loss, "xent": loss}

    # -- decode --------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        self_c = attn.init_kv_cache(batch, seq_len, c.n_kv_heads,
                                    c.resolved_head_dim, self.dtype)
        self_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[self_c for _ in range(self.n_dec)])
        F = c.enc_frames
        cross = {"k": jnp.zeros((self.n_dec, batch, F, c.n_kv_heads,
                                 c.resolved_head_dim), self.dtype),
                 "v": jnp.zeros((self.n_dec, batch, F, c.n_kv_heads,
                                 c.resolved_head_dim), self.dtype)}
        return {"self": self_stack, "cross": cross}

    def cache_specs(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        spec = attn.cache_specs(batch, seq_len, c.n_kv_heads,
                                c.resolved_head_dim, self.dtype)
        self_stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n_dec,) + s.shape, s.dtype),
            spec)
        F = c.enc_frames
        cross = {"k": jax.ShapeDtypeStruct(
                     (self.n_dec, batch, F, c.n_kv_heads,
                      c.resolved_head_dim), self.dtype),
                 "v": jax.ShapeDtypeStruct(
                     (self.n_dec, batch, F, c.n_kv_heads,
                      c.resolved_head_dim), self.dtype)}
        return {"self": self_stack, "cross": cross}

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        B = tokens.shape[0]
        pos = cache["self"]["pos"][0]
        x = embed(params["embed"], tokens, self.dtype)
        # sinusoidal position of the current step
        d = c.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)
        angle = pos.astype(jnp.float32) / jnp.power(10_000.0, dim / d)
        pe = jnp.zeros((d,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(angle))
        pe = pe.at[1::2].set(jnp.cos(angle[: (d + 1) // 2]))
        x = x + pe.astype(self.dtype)[None, None, :]
        F = cache["cross"]["k"].shape[2]
        enc_pos = jnp.arange(F, dtype=jnp.int32)

        def body(x, scanned):
            layer, self_cache, cross_k, cross_v = scanned
            y = layernorm(layer["ln1"], x, c.norm_eps)
            o, new_self = attn.decode_attention(layer["self_attn"], self_cache,
                                                y, use_rope=False)
            x = x + o
            y = layernorm(layer["ln_x"], x, c.norm_eps)
            q, _, _ = attn.project_qkv(layer["cross_attn"], y)
            qpos = jnp.zeros((1,), jnp.int32)
            o = attn.dense_attention(q, cross_k, cross_v, qpos, enc_pos,
                                     causal=False)
            x = x + attn.project_out(layer["cross_attn"], o)
            y = layernorm(layer["ln2"], x, c.norm_eps)
            return x + gelu_mlp(layer["mlp"], y), new_self

        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["self"],
                      cache["cross"]["k"], cache["cross"]["v"]))
        x = layernorm(params["dec_ln"], x, c.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, {"self": new_self, "cross": cache["cross"]}

    # -- shapes ----------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "cache": self.cache_specs(B, S)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "frames": jax.ShapeDtypeStruct((B, c.enc_frames, c.d_model),
                                               self.dtype)}

    def make_batch(self, key: jax.Array, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.random.randint(key, (B, 1), 0, c.vocab),
                    "cache": self.init_cache(B, S)}
        return {"tokens": jax.random.randint(key, (B, S), 0, c.vocab),
                "frames": 0.02 * jax.random.normal(
                    key, (B, c.enc_frames, c.d_model), self.dtype)}

    def input_logical_axes(self, shape: ShapeConfig) -> Dict:
        if shape.kind == "decode":
            kv = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                  "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                  "pos": ("layers",)}
            cross = {"k": ("layers", "batch", "frames", "kv_heads", "head_dim"),
                     "v": ("layers", "batch", "frames", "kv_heads", "head_dim")}
            return {"tokens": ("batch", None),
                    "cache": {"self": kv, "cross": cross}}
        return {"tokens": ("batch", "seq"),
                "frames": ("batch", "frames", "d_model")}


__all__ = ["WhisperModel"]
