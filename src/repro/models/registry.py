"""Model registry: ArchConfig -> model instance."""

from __future__ import annotations

from repro.configs.base import ArchConfig

from .hybrid import HymbaModel
from .transformer import DecoderLM
from .whisper import WhisperModel
from .xlstm import XLSTMModel

_FAMILY_TO_MODEL = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "audio": WhisperModel,
    "hybrid": HymbaModel,
    "ssm": XLSTMModel,
}


def build_model(cfg: ArchConfig):
    try:
        cls = _FAMILY_TO_MODEL[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for {cfg.name}") from None
    return cls(cfg)


__all__ = ["build_model"]
