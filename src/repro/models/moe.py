"""Mixture-of-Experts with sort-based, capacity-bounded dispatch.

GShard-style one-hot dispatch einsums cost O(T^2 k cf d) — quadratic in
tokens — so we use the sort/scatter formulation (as MaxText's dropping MoE
does): flatten (token, slot) pairs, stable-sort by expert, rank within the
expert group via segment starts, scatter into an (E, C, d) buffer, run the
expert FFNs as one batched einsum, and gather back.  Linear dispatch cost;
expert compute is E*C*d*f*3 matmuls with E*C = k*cf*T.

Sharding: tokens are batch-sharded ("data"), experts are sharded over
"model" when divisible (else the FFN dim is); XLA inserts the all-to-alls at
the scatter/gather boundaries.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .params import P


def moe_spec(d: int, f: int, n_experts: int) -> Dict:
    return {
        "router": P((d, n_experts), ("d_model", "experts"), scale=0.1),
        "w_gate": P((n_experts, d, f), ("experts", "d_model", "d_ff")),
        "w_up": P((n_experts, d, f), ("experts", "d_model", "d_ff")),
        "w_down": P((n_experts, f, d), ("experts", "d_ff", "d_model")),
    }


def moe_apply(params: Dict, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, constrain=None,
              seq_chunk: int = 512,
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (B, S, d), aux metrics (load-balance & z losses).

    Dispatch is *grouped per batch row* (per sequence): every sort/scatter/
    gather batches over B, so all dispatch buffers shard over the data axis
    — a single global token sort would force multi-hundred-GB replicated
    (B*S*k, d) tensors under SPMD (measured; see EXPERIMENTS.md §Perf).
    Capacity is per-group, C = ceil(Sc*k*cf/E), the standard per-device
    capacity of GShard-family implementations.

    The sequence is additionally processed in chunks (lax.scan, rematted):
    router logits (B,S,E) and the (B,E,C,d) buffers would otherwise reach
    tens of GB per device for E=384, k=8 at 4k-32k sequence lengths.

    ``constrain`` (optional): sharding constrainer applied to the
    (B, E, C, *) dispatch/expert buffers.
    """
    B, S, d = x.shape
    if S % seq_chunk or S <= seq_chunk:
        return _moe_chunk(params, x, top_k=top_k,
                          capacity_factor=capacity_factor,
                          constrain=constrain)
    n = S // seq_chunk
    xc = x.reshape(B, n, seq_chunk, d).swapaxes(0, 1)

    def body(_, x_chunk):
        out_c, aux_c = _moe_chunk(params, x_chunk, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  constrain=constrain)
        return 0, (out_c, aux_c)

    _, (out, auxs) = jax.lax.scan(jax.checkpoint(body), 0, xc)
    out = out.swapaxes(0, 1).reshape(B, S, d)
    metrics = jax.tree.map(lambda a: a.mean(), auxs)
    return out, metrics


def _moe_chunk(params: Dict, x: jax.Array, *, top_k: int,
               capacity_factor: float, constrain=None,
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = x.shape
    E = params["router"].shape[-1]
    k = top_k
    Sk = S * k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch/GShard) ---------------------------------------
    me = probs.mean(axis=(0, 1))                               # (E,)
    rows = jnp.arange(B)[:, None]
    counts = jnp.zeros((B, E), jnp.float32).at[
        rows, expert_idx.reshape(B, Sk)].add(1.0)
    ce = counts.sum(axis=0) / (B * Sk)
    aux_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- per-row sort-based dispatch, inverse-mapping form ------------------
    C = max(int(-(-Sk * capacity_factor // E)), 1)
    flat_e = expert_idx.reshape(B, Sk)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)        # (B, Sk)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    tok = (sort_idx // k).astype(jnp.int32)                    # source token
    starts = jnp.cumsum(counts, axis=1) - counts               # (B, E)
    rank = (jnp.arange(Sk)[None, :]
            - jnp.take_along_axis(starts, sorted_e, axis=1)).astype(jnp.int32)
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)         # E*C = dropped

    # slot -> source token (inverse mapping): dispatch is ONE gather from x,
    # never materializing the k-times-larger (B, Sk, d) sorted-token tensor.
    src = jnp.zeros((B, E * C), jnp.int32).at[rows, dest].set(tok,
                                                              mode="drop")
    filled = jnp.zeros((B, E * C), bool).at[rows, dest].set(True, mode="drop")
    gate_slot = jnp.zeros((B, E * C), jnp.float32).at[rows, dest].set(
        jnp.take_along_axis(gate_vals.reshape(B, Sk), sort_idx, axis=1),
        mode="drop")

    cst = constrain or (lambda t: t)
    xin = jnp.take_along_axis(x, src[..., None], axis=1)       # (B, EC, d)
    xin = xin * filled[..., None].astype(x.dtype)
    h = cst(xin.reshape(B, E, C, d))

    g = cst(jnp.einsum("becd,edf->becf", h, params["w_gate"]))
    u = cst(jnp.einsum("becd,edf->becf", h, params["w_up"]))
    y = cst(jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                       params["w_down"]))
    yf = y.reshape(B, E * C, d)

    # combine: scatter-add slots back to their source tokens
    updates = yf * (gate_slot[..., None] * filled[..., None]).astype(x.dtype)
    out = jnp.zeros((B, S, d), x.dtype).at[rows, src].add(updates)

    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": 1.0 - keep.mean(),
    }
    return out, metrics


__all__ = ["moe_spec", "moe_apply"]
