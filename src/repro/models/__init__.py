from .registry import build_model

__all__ = ["build_model"]
