"""GQA attention: full / causal / sliding-window / cross, with KV caches.

Two XLA implementations:
  * ``dense``   — classic einsum softmax (smoke tests, short seqs, decode);
  * ``chunked`` — memory-efficient online-softmax attention (lax.map over
    query chunks, lax.scan over KV chunks).  This is the lowering/dry-run
    path for long sequences; the TPU-native equivalent is the Pallas flash
    kernel in ``repro.kernels.flash_attention`` (same math, VMEM tiling).

Layout: q (B, S, K, G, Dh) where H = K*G; k, v (B, T, K, Dh).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope
from .params import P

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def gqa_spec(d: int, n_heads: int, n_kv: int, head_dim: int,
             qk_norm: bool = False, bias: bool = False) -> Dict:
    spec = {
        "wq": P((d, n_heads, head_dim), ("d_model", "heads", "head_dim")),
        "wk": P((d, n_kv, head_dim), ("d_model", "kv_heads", "head_dim")),
        "wv": P((d, n_kv, head_dim), ("d_model", "kv_heads", "head_dim")),
        "wo": P((n_heads, head_dim, d), ("heads", "head_dim", "d_model")),
    }
    if qk_norm:  # Qwen3-style per-head RMSNorm on q and k
        spec["q_norm"] = P((head_dim,), ("head_dim",), init="ones")
        spec["k_norm"] = P((head_dim,), ("head_dim",), init="ones")
    if bias:     # whisper-style projection biases
        spec["bq"] = P((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        spec["bv"] = P((n_kv, head_dim), ("kv_heads", "head_dim"), init="zeros")
        spec["bo"] = P((d,), ("d_model",), init="zeros")
    return spec


def _head_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def project_qkv(params: Dict, x: jax.Array, x_kv: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q (B,S,H,Dh), k (B,T,K,Dh), v (B,T,K,Dh)."""
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x_kv, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x_kv, params["wv"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        v = v + params["bv"].astype(v.dtype)
    if "q_norm" in params:
        q = _head_rmsnorm(q, params["q_norm"])
        k = _head_rmsnorm(k, params["k_norm"])
    return q, k, v


def project_out(params: Dict, o: jax.Array) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    if "bo" in params:
        out = out + params["bo"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
               window: int, kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """(…, Sq, Tk) additive bias from causality / sliding window / validity."""
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window > 0:
        ok &= d < window
    if kv_valid is not None:
        ok &= kv_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,T,K,D) -> (B,T,H,D) by repeating each kv head G=H/K times.

    The grouped (B,K,G,S,D) layout cannot shard K=8 kv heads over a 16-way
    model axis — XLA then *replicates* the whole attention computation.
    Expanding to H query heads restores head sharding for train/prefill;
    decode keeps the grouped path (expansion would multiply KV-cache reads
    by G in a memory-bound kernel)."""
    K = k.shape[2]
    G = n_heads // K
    if G == 1:
        return k
    return jnp.repeat(k, G, axis=2)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    kv_valid: Optional[jax.Array] = None,
                    expand_heads: bool = True) -> jax.Array:
    """q (B,S,H,Dh), k/v (B,T,K,Dh) -> (B,S,H,Dh)."""
    B, S, H, Dh = q.shape
    scale = Dh ** -0.5
    if expand_heads:
        k = expand_kv(k, H)
        v = expand_kv(v, H)
        scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) \
            * scale
        bias = _mask_bias(q_pos, kv_pos, causal, window, kv_valid)
        scores = scores + (bias[..., None, :, :] if bias.ndim == 3 else bias)
        w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, v)
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    bias = _mask_bias(q_pos, kv_pos, causal, window, kv_valid)  # (B?,S,T)
    scores = scores + bias[..., None, None, :, :] if bias.ndim == 3 \
        else scores + bias
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return o.reshape(B, S, H, Dh)


def _mea_forward(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
                 window: int, q_chunk: int, kv_chunk: int):
    """Online-softmax forward. q (B,S,H,Dh); k,v (B,T,K,Dh).

    Returns (out (B,S,H,Dh), lse (B,K,G,S) f32). Positions are arange.
    """
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    nq, nk = -(-S // q_chunk), -(-T // kv_chunk)
    scale = Dh ** -0.5

    qg = (q.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4)
          .reshape(B, K, G, nq, q_chunk, Dh))
    kc = k.transpose(0, 2, 1, 3).reshape(B, K, nk, kv_chunk, Dh)
    vc = v.transpose(0, 2, 1, 3).reshape(B, K, nk, kv_chunk, Dh)

    def per_q_chunk(inputs):
        qc, iq = inputs                    # (B,K,G,qc,Dh), ()
        qpos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs2):
            m, l, acc = carry
            kb, vb, j = inputs2            # (B,K,kvc,Dh), (B,K,kvc,Dh), ()
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qc, kb).astype(jnp.float32)
            s = s * scale + _mask_bias(qpos, kpos, causal, window)
            m_new = jnp.maximum(m, s.max(axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,bktd->bkgqd", p.astype(vb.dtype), vb)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
             jnp.arange(nk)))
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(qc.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return o, lse

    o, lse = jax.lax.map(per_q_chunk,
                         (qg.transpose(3, 0, 1, 2, 4, 5), jnp.arange(nq)))
    # o: (nq,B,K,G,qc,Dh) -> (B,S,H,Dh);  lse: (nq,B,K,G,qc) -> (B,K,G,S)
    o = o.transpose(1, 2, 3, 0, 4, 5).reshape(B, K, G, S, Dh)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(B, K, G, S)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _mea_attention(q, k, v, causal, window, q_chunk, kv_chunk):
    out, _ = _mea_forward(q, k, v, causal, window, q_chunk, kv_chunk)
    return out


def _mea_fwd(q, k, v, causal, window, q_chunk, kv_chunk):
    out, lse = _mea_forward(q, k, v, causal, window, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _mea_bwd(causal, window, q_chunk, kv_chunk, res, dout):
    """Flash-style backward: scores recomputed blockwise, never saved.

    Live memory is O(block) + the dq accumulator — this is what keeps the
    train_4k/prefill_32k cells inside 16 GB/chip (the naive scan VJP would
    save the full f32 score matrix: B*H*S*T*4 bytes, tens of GB/device).
    """
    q, k, v, out, lse = res
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    nq, nk = -(-S // q_chunk), -(-T // kv_chunk)
    scale = Dh ** -0.5

    qg = (q.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4)
          .reshape(B, K, G, nq, q_chunk, Dh))
    do_g = (dout.reshape(B, S, K, G, Dh).transpose(0, 2, 3, 1, 4)
            .reshape(B, K, G, nq, q_chunk, Dh))
    lse_c = lse.reshape(B, K, G, nq, q_chunk)
    # delta_i = sum_d dO_i * O_i
    delta = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    delta_c = (delta.reshape(B, S, K, G).transpose(0, 2, 3, 1)
               .reshape(B, K, G, nq, q_chunk))
    kc = k.transpose(0, 2, 1, 3).reshape(B, K, nk, kv_chunk, Dh)
    vc = v.transpose(0, 2, 1, 3).reshape(B, K, nk, kv_chunk, Dh)

    def kv_step(dq_acc, inputs):
        kb, vb, j = inputs                 # (B,K,kvc,Dh) x2, ()
        kpos = j * kv_chunk + jnp.arange(kv_chunk)

        def per_q(inputs2):
            qc, doc, lsec, dlc, iq = inputs2
            qpos = iq * q_chunk + jnp.arange(q_chunk)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qc, kb).astype(jnp.float32)
            s = s * scale + _mask_bias(qpos, kpos, causal, window)
            p = jnp.exp(s - lsec[..., None])
            dv_p = jnp.einsum("bkgqt,bkgqd->bktd", p,
                              doc.astype(jnp.float32))
            dp = jnp.einsum("bkgqd,bktd->bkgqt", doc.astype(jnp.float32),
                            vb.astype(jnp.float32))
            ds = p * (dp - dlc[..., None]) * scale
            dq_c = jnp.einsum("bkgqt,bktd->bkgqd", ds,
                              kb.astype(jnp.float32))
            dk_p = jnp.einsum("bkgqt,bkgqd->bktd", ds,
                              qc.astype(jnp.float32))
            return dq_c, dk_p, dv_p

        dq_cs, dk_ps, dv_ps = jax.lax.map(
            per_q, (qg.transpose(3, 0, 1, 2, 4, 5),
                    do_g.transpose(3, 0, 1, 2, 4, 5),
                    lse_c.transpose(3, 0, 1, 2, 4),
                    delta_c.transpose(3, 0, 1, 2, 4),
                    jnp.arange(nq)))
        # dq contribution of this kv chunk, for all q
        dq_j = (dq_cs.transpose(1, 2, 3, 0, 4, 5)
                .reshape(B, K, G, S, Dh))
        return dq_acc + dq_j, (dk_ps.sum(axis=0), dv_ps.sum(axis=0))

    dq0 = jnp.zeros((B, K, G, S, Dh), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        kv_step, dq0,
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(nk)))
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)
    # dk_c/dv_c: (nk, B, K, kvc, Dh) -> (B, K, T, Dh) -> (B, T, K, Dh)
    dk = (dk_c.transpose(1, 2, 0, 3, 4).reshape(B, K, T, Dh)
          .transpose(0, 2, 1, 3).astype(k.dtype))
    dv = (dv_c.transpose(1, 2, 0, 3, 4).reshape(B, K, T, Dh)
          .transpose(0, 2, 1, 3).astype(v.dtype))
    return dq, dk, dv


_mea_attention.defvjp(_mea_fwd, _mea_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array = None, kv_pos: jax.Array = None, *,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Memory-efficient attention with a flash-style custom VJP.

    Positions are implicit arange (the q_pos/kv_pos arguments are accepted
    for API parity with dense_attention but must be arange if given).
    Equivalent to dense_attention — validated in tests, fwd and grad.
    """
    B, S, H, Dh = q.shape
    k = expand_kv(k, H)          # TP-friendly GQA (see expand_kv docstring)
    v = expand_kv(v, H)
    T = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    nq, nk = -(-S // q_chunk), -(-T // kv_chunk)
    S_p, T_p = nq * q_chunk, nk * kv_chunk
    if S_p != S:
        q = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    if T_p != T:
        k = jnp.pad(k, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
        # padded keys are masked by causality only if S_p >= T_p; enforce
        # explicitly via the window/causal mask positions (padded kpos > any
        # valid qpos when causal). For non-causal use, pad must be handled by
        # the caller; all in-repo callers are causal or exact-multiple.
    out = _mea_attention(q, k, v, causal, window, q_chunk, kv_chunk)
    return out[:, :S]


# ---------------------------------------------------------------------------
# KV caches (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Dict:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),     # tokens filled so far
    }


def cache_specs(batch: int, max_len: int, n_kv: int, head_dim: int,
                dtype=jnp.bfloat16) -> Dict:
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, n_kv, head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def decode_attention(params: Dict, cache: Dict, x: jax.Array, *,
                     window: int = 0, rope_theta: float = 10_000.0,
                     use_rope: bool = True) -> Tuple[jax.Array, Dict]:
    """One-token step: x (B,1,d). Updates cache in place (donated buffer)."""
    B = x.shape[0]
    q, k_new, v_new = project_qkv(params, x)
    pos = cache["pos"]
    if use_rope:
        posv = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, posv[None, :], rope_theta)
        k_new = apply_rope(k_new, posv[None, :], rope_theta)
    T = cache["k"].shape[1]
    if window > 0:
        slot = jnp.mod(pos, T)        # ring buffer for sliding-window caches
    else:
        slot = pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kv_idx = jnp.arange(T)
    if window > 0:
        # valid = written and within window; positions in ring order
        age = jnp.mod(slot - kv_idx, T)
        valid = (age < jnp.minimum(pos + 1, T))
        kv_pos = pos - age
    else:
        valid = kv_idx <= pos
        kv_pos = kv_idx
    q_pos = jnp.full((1,), pos, jnp.int32)
    o = dense_attention(q, k, v, q_pos[None, :], kv_pos[None, :],
                        causal=False, window=0,
                        kv_valid=jnp.broadcast_to(valid, (B, T)),
                        expand_heads=False)
    out = project_out(params, o)
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    return out, new_cache


__all__ = ["gqa_spec", "project_qkv", "project_out", "dense_attention",
           "chunked_attention", "init_kv_cache", "cache_specs",
           "decode_attention", "NEG_INF"]
