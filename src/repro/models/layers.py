"""Shared layers: norms, RoPE, MLPs, embeddings — pure-JAX, spec-based."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .params import P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> Dict:
    return {"scale": P((d,), ("d_model",), init="ones")}


def rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int) -> Dict:
    return {"scale": P((d,), ("d_model",), init="ones"),
            "bias": P((d,), ("d_model",), init="zeros")}


def layernorm(params: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0
               ) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : (d + 1) // 2]))
    return pe


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_spec(d: int, f: int) -> Dict:
    return {"w_gate": P((d, f), ("d_model", "d_ff")),
            "w_up": P((d, f), ("d_model", "d_ff")),
            "w_down": P((f, d), ("d_ff", "d_model"))}


def swiglu(params: Dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, params["w_down"])


def gelu_mlp_spec(d: int, f: int) -> Dict:
    return {"w_in": P((d, f), ("d_model", "d_ff")),
            "b_in": P((f,), ("d_ff",), init="zeros"),
            "w_out": P((f, d), ("d_ff", "d_model")),
            "b_out": P((d,), ("d_model",), init="zeros")}


def gelu_mlp(params: Dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int) -> Dict:
    return {"embedding": P((vocab, d), ("vocab", "d_model"), init="embed")}


def embed(params: Dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["embedding"].astype(dtype)[tokens]


def unembed(params: Dict, x: jax.Array) -> jax.Array:
    # logits in f32 for a stable softmax/xent
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["embedding"].astype(jnp.float32))


def output_head_spec(d: int, vocab: int) -> Dict:
    return {"w_out": P((d, vocab), ("d_model", "vocab"))}


def output_head(params: Dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      params["w_out"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy over valid positions. logits: (..., V)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


__all__ = [
    "rmsnorm_spec", "rmsnorm", "layernorm_spec", "layernorm", "apply_rope",
    "rope_freqs", "sinusoidal_positions", "swiglu_spec", "swiglu",
    "gelu_mlp_spec", "gelu_mlp", "embed_spec", "embed", "unembed",
    "output_head_spec", "output_head", "softmax_xent",
]
