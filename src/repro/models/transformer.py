"""Decoder-only LM covering the dense, MoE and VLM architecture families.

One scanned pre-norm block: x += attn(norm(x)); x += ffn|moe(norm(x)).
Layers are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` (+ optional remat) so the HLO stays depth-independent —
required to compile the 61-layer/1T-param configs in the dry-run.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

from . import attention as attn
from . import moe as moe_mod
from .layers import (embed, embed_spec, rmsnorm, rmsnorm_spec, softmax_xent,
                     swiglu, swiglu_spec, unembed)
from .params import (P, abstract_params, init_params, logical_axes,
                     stack_layer_specs)

DENSE_ATTN_MAX_SEQ = 2048   # above this, use chunked (memory-efficient) attn


class DecoderLM:
    """dense / moe / vlm decoder LM built from an ArchConfig."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_moe = cfg.n_experts > 0
        self.is_vlm = cfg.n_patches > 0
        self.dtype = jnp.dtype(cfg.dtype)
        # optional sharding constrainers (set by launchers)
        self.constrain_act = None
        self.constrain_q = None
        self.constrain_kv = None
        self.constrain_moe = None

    # -- specs ---------------------------------------------------------------
    def block_spec(self) -> Dict:
        c = self.cfg
        spec = {
            "ln1": rmsnorm_spec(c.d_model),
            "attn": attn.gqa_spec(c.d_model, c.n_heads, c.n_kv_heads,
                                  c.resolved_head_dim, qk_norm=c.qk_norm),
            "ln2": rmsnorm_spec(c.d_model),
        }
        if self.is_moe:
            spec["moe"] = moe_mod.moe_spec(c.d_model, c.d_ff, c.n_experts)
        else:
            spec["mlp"] = swiglu_spec(c.d_model, c.d_ff)
        return spec

    def param_specs(self) -> Dict:
        c = self.cfg
        spec = {
            "embed": embed_spec(c.vocab, c.d_model),
            "blocks": stack_layer_specs(self.block_spec(), c.n_layers),
            "ln_f": rmsnorm_spec(c.d_model),
        }
        if self.is_vlm:
            spec["mm_proj"] = {"w": P((c.d_model, c.d_model),
                                      ("d_model", "d_model_out"))}
        return spec

    def init(self, key: jax.Array, dtype=None) -> Dict:
        return init_params(self.param_specs(), key, dtype or self.dtype)

    def abstract_params(self) -> Dict:
        return abstract_params(self.param_specs(), self.dtype)

    def param_logical_axes(self) -> Dict:
        return logical_axes(self.param_specs())

    # -- forward ---------------------------------------------------------------
    def _block(self, layer_params: Dict, x: jax.Array, positions: jax.Array
               ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        h = rmsnorm(layer_params["ln1"], x, c.norm_eps)
        q, k, v = attn.project_qkv(layer_params["attn"], h)
        q = attn.apply_rope(q, positions, c.rope_theta)
        k = attn.apply_rope(k, positions, c.rope_theta)
        k = attn.expand_kv(k, c.n_heads)     # TP-friendly GQA
        v = attn.expand_kv(v, c.n_heads)
        if self.constrain_q is not None:
            q = self.constrain_q(q)
            k = self.constrain_kv(k)
            v = self.constrain_kv(v)
        S = x.shape[1]
        if S <= DENSE_ATTN_MAX_SEQ:
            o = attn.dense_attention(q, k, v, positions[0], positions[0],
                                     causal=True, window=c.window)
        else:
            o = attn.chunked_attention(q, k, v, positions[0], positions[0],
                                       causal=True, window=c.window)
        x = x + attn.project_out(layer_params["attn"], o)
        h = rmsnorm(layer_params["ln2"], x, c.norm_eps)
        aux = {}
        if self.is_moe:
            y, aux = moe_mod.moe_apply(layer_params["moe"], h,
                                       top_k=c.top_k,
                                       capacity_factor=c.capacity_factor,
                                       constrain=self.constrain_moe)
        else:
            y = swiglu(layer_params["mlp"], h)
        return x + y, aux

    def _run_blocks(self, params: Dict, x: jax.Array, positions: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        cst = self.constrain_act or (lambda t: t)
        x = cst(x)

        def body(carry, layer_params):
            h, aux_acc = carry
            h, aux = self._block(layer_params, h, positions)
            h = cst(h)
            if aux:
                aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc,
                                       {k: jnp.asarray(v, jnp.float32)
                                        for k, v in aux.items()})
            return (h, aux_acc), None

        aux0 = ({"moe_aux_loss": jnp.zeros((), jnp.float32),
                 "moe_z_loss": jnp.zeros((), jnp.float32),
                 "moe_dropped_frac": jnp.zeros((), jnp.float32)}
                if self.is_moe else {})
        fn = body
        if c.remat:
            fn = jax.checkpoint(body,
                                policy=jax.checkpoint_policies.nothing_saveable)
        if c.scan_layers:
            (x, aux), _ = jax.lax.scan(fn, (x, aux0), params["blocks"])
        else:
            for i in range(c.n_layers):
                layer = jax.tree.map(lambda p: p[i], params["blocks"])
                (x, aux), _ = fn((x, aux0), layer)
        if aux:
            aux = {k: v / c.n_layers for k, v in aux.items()}
        return x, aux

    def forward(self, params: Dict, tokens: jax.Array,
                extras: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
        """Full-sequence logits (training / prefill)."""
        c = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens, self.dtype)
        if self.is_vlm:
            patches = extras["patch_embeds"].astype(self.dtype)
            patches = jnp.einsum("bpd,de->bpe", patches,
                                 params["mm_proj"]["w"])
            x = jax.lax.dynamic_update_slice(x, patches, (0, 0, 0))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux = self._run_blocks(params, x, positions)
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, aux

    # -- losses ---------------------------------------------------------------
    def train_loss(self, params: Dict, batch: Dict
                   ) -> Tuple[jax.Array, Dict]:
        tokens = batch["tokens"]
        logits, aux = self.forward(params, tokens, batch)
        targets = tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = mask[:, 1:] if mask is not None else None
        if self.is_vlm and mask is None:
            # text-only loss: skip the patch positions
            pos = jnp.arange(targets.shape[1])[None, :]
            mask = (pos >= self.cfg.n_patches).astype(jnp.float32)
        loss = softmax_xent(logits[:, :-1], targets, mask)
        metrics = {"xent": loss}
        if self.is_moe:
            loss = loss + 0.01 * aux["moe_aux_loss"] + 1e-3 * aux["moe_z_loss"]
            metrics.update(aux)
        metrics["loss"] = loss
        return loss, metrics

    # -- decode ----------------------------------------------------------------
    def _cache_len(self, seq_len: int) -> int:
        c = self.cfg
        return min(c.window, seq_len) if c.window else seq_len

    def init_cache(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        T = self._cache_len(seq_len)
        one = lambda: attn.init_kv_cache(batch, T, c.n_kv_heads,
                                         c.resolved_head_dim, self.dtype)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one() for _ in range(c.n_layers)])
        return stacked

    def cache_specs(self, batch: int, seq_len: int) -> Dict:
        c = self.cfg
        T = self._cache_len(seq_len)
        spec = attn.cache_specs(batch, T, c.n_kv_heads, c.resolved_head_dim,
                                self.dtype)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((c.n_layers,) + s.shape, s.dtype),
            spec)

    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """tokens (B,1) -> logits (B,1,V), updated stacked cache."""
        c = self.cfg
        x = embed(params["embed"], tokens, self.dtype)

        def body(x, scanned):
            layer_params, layer_cache = scanned
            h = rmsnorm(layer_params["ln1"], x, c.norm_eps)
            o, new_cache = attn.decode_attention(
                layer_params["attn"], layer_cache, h, window=c.window,
                rope_theta=c.rope_theta)
            x = x + o
            h = rmsnorm(layer_params["ln2"], x, c.norm_eps)
            if self.is_moe:
                y, _ = moe_mod.moe_apply(layer_params["moe"], h,
                                         top_k=c.top_k,
                                         capacity_factor=c.capacity_factor)
            else:
                y = swiglu(layer_params["mlp"], h)
            return x + y, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        x = rmsnorm(params["ln_f"], x, c.norm_eps)
        logits = unembed(params["embed"], x)
        return logits, new_cache

    # -- shape plumbing ----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "cache": self.cache_specs(B, S)}
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if self.is_vlm:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, c.n_patches, c.d_model), self.dtype)
        return specs

    def make_batch(self, key: jax.Array, shape: ShapeConfig) -> Dict:
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"tokens": jax.random.randint(key, (B, 1), 0, c.vocab),
                    "cache": self.init_cache(B, S)}
        batch = {"tokens": jax.random.randint(key, (B, S), 0, c.vocab)}
        if self.is_vlm:
            # frontend-stub embeddings at token-embedding scale
            batch["patch_embeds"] = 0.02 * jax.random.normal(
                key, (B, c.n_patches, c.d_model), self.dtype)
        return batch

    def input_logical_axes(self, shape: ShapeConfig) -> Dict:
        kv_cache_axes = {"k": ("layers", "batch", "kv_seq", "kv_heads",
                               "head_dim"),
                         "v": ("layers", "batch", "kv_seq", "kv_heads",
                               "head_dim"),
                         "pos": ("layers",)}
        if shape.kind == "decode":
            return {"tokens": ("batch", None), "cache": kv_cache_axes}
        axes = {"tokens": ("batch", "seq")}
        if self.is_vlm:
            axes["patch_embeds"] = ("batch", "patches", "d_model")
        return axes


__all__ = ["DecoderLM", "DENSE_ATTN_MAX_SEQ"]
