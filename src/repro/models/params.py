"""Parameter specs: shapes + logical sharding axes + initializers.

Models declare parameters as ``P(shape, axes)`` trees; ``init_params``
materializes them and ``logical_axes`` yields a matching tree of logical-axis
tuples that ``repro.sharding.rules`` maps onto the device mesh.  Scanned layer
stacks simply prepend a ``"layers"`` axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Spec of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float = 1.0                    # fan-in override multiplier

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def is_spec(x: Any) -> bool:
    return isinstance(x, P)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # convention: last axis is the output axis for weight matrices
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def init_params(spec_tree: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialize a spec tree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def make(spec: P, k: jax.Array) -> jax.Array:
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "embed":
            return (jax.random.normal(k, spec.shape, dtype)
                    * jnp.asarray(0.02 * spec.scale, dtype))
        std = spec.scale / math.sqrt(max(_fan_in(spec.shape), 1))
        return jax.random.normal(k, spec.shape, dtype) * jnp.asarray(std, dtype)

    return treedef.unflatten([make(s, k) for s, k in zip(leaves, keys)])


def abstract_params(spec_tree: Any, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStructs for dry-run lowering — no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree,
        is_leaf=is_spec)


def logical_axes(spec_tree: Any) -> Any:
    """Tree of logical-axis tuples matching the param tree."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def stack_layer_specs(spec_tree: Any, n_layers: int) -> Any:
    """Prepend a scanned 'layers' axis to every spec in the tree."""
    return jax.tree.map(
        lambda s: P((n_layers,) + s.shape, ("layers",) + s.axes,
                    init=s.init, scale=s.scale),
        spec_tree, is_leaf=is_spec)


def count_params(tree: Any) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    if leaves and isinstance(leaves[0], P):
        return sum(int(np.prod(l.shape)) for l in leaves)
    return sum(int(np.prod(l.shape)) for l in leaves)


__all__ = ["P", "is_spec", "init_params", "abstract_params", "logical_axes",
           "stack_layer_specs", "count_params"]
