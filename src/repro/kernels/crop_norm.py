"""Pallas TPU fused crop + mirror + normalize (+HWC->CHW) — the on-device
half of DALI's ``crop_mirror_normalize`` stage (paper Listings 2/3).

One grid step processes one image: the (H, W, C) uint8 source tile lives in
VMEM (a 256x256x3 image is ~192 KiB), the kernel dynamic-slices the crop
window (offsets arrive via scalar prefetch, so the slice indices are known
to the DMA engine), optionally mirrors, converts uint8->f32, applies
per-channel mean/std, and writes the CHW output — one HBM round trip for
what a CPU pipeline does in four passes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _crop_kernel(scalars_ref, img_ref, mean_ref, std_ref, o_ref, *,
                 out_h: int, out_w: int):
    b = pl.program_id(0)
    oy = scalars_ref[b, 0]
    ox = scalars_ref[b, 1]
    mirror = scalars_ref[b, 2]

    img = img_ref[0]                                  # (H, W, C) uint8
    crop = jax.lax.dynamic_slice(
        img, (oy, ox, 0), (out_h, out_w, img.shape[2]))
    crop = jnp.where(mirror > 0, crop[:, ::-1, :], crop)
    x = crop.astype(jnp.float32)
    x = (x - mean_ref[...]) / std_ref[...]
    o_ref[0] = x.transpose(2, 0, 1).astype(o_ref.dtype)


def crop_mirror_normalize(img: jax.Array, oy: jax.Array, ox: jax.Array,
                          mirror: jax.Array, mean: jax.Array, std: jax.Array,
                          out_h: int, out_w: int, dtype=jnp.float32, *,
                          interpret: bool = True) -> jax.Array:
    """img (B,H,W,C) uint8 -> (B,C,out_h,out_w) normalized.

    Crop offsets are clamped to the valid window so an out-of-range offset
    degrades to an edge crop instead of relying on dynamic-slice's silent
    index adjustment (keeps kernel and NumPy reference bit-aligned).
    """
    B, H, W, C = img.shape
    oy = jnp.clip(oy.astype(jnp.int32), 0, H - out_h)
    ox = jnp.clip(ox.astype(jnp.int32), 0, W - out_w)
    scalars = jnp.stack([oy, ox, mirror.astype(jnp.int32)], axis=1)  # (B, 3)
    kernel = functools.partial(_crop_kernel, out_h=out_h, out_w=out_w)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda b, s_ref: (b, 0, 0, 0)),
            pl.BlockSpec((C,), lambda b, s_ref: (0,)),
            pl.BlockSpec((C,), lambda b, s_ref: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C, out_h, out_w),
                               lambda b, s_ref: (b, 0, 0, 0)),
        scratch_shapes=[],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, out_h, out_w), dtype),
        interpret=interpret,
    )(scalars, img, mean.astype(jnp.float32), std.astype(jnp.float32))


__all__ = ["crop_mirror_normalize"]
