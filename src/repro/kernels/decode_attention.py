"""Pallas TPU flash-decode: one query token against a long KV cache.

Memory-bound kernel (arithmetic intensity ~2 FLOP/byte): the point on TPU is
streaming the KV cache HBM->VMEM exactly once at full bandwidth while the
G grouped q-heads of each kv head ride along in registers.  Grid is
(batch, kv_head, kv_blocks); m/l/acc scratch carries across kv_blocks.

Layouts: q (B, K, G, D); k,v (B, K, T, D); lengths (B,) valid prefix.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, block_k: int, scale: float):
    b = pl.program_id(0)
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    t_pos = it * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t_pos < len_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(it == nt - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, *, block_k: int = 512,
                 interpret: bool = True) -> jax.Array:
    """q (B,K,G,D); k,v (B,K,T,D); lengths (B,) -> (B,K,G,D)."""
    B, K, G, D = q.shape
    T = k.shape[2]
    block_k = min(block_k, T)
    nt = -(-T // block_k)
    T_p = nt * block_k
    if T_p != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))

    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               scale=D ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, nt),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, it, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, it, len_ref: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, it, len_ref: (b, h, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, it, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
    return out


__all__ = ["flash_decode"]
