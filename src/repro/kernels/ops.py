"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only — the
kernels execute through the Pallas interpreter for correctness validation)
and to False on a real TPU backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .crop_norm import crop_mirror_normalize as _cmn
from .decode_attention import flash_decode as _flash_decode
from .flash_attention import flash_attention as _flash_attention
from .moe_gmm import grouped_matmul as _gmm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode(q, k, v, lengths, *, block_k=512, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _flash_decode(q, k, v, lengths, block_k=block_k,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("out_h", "out_w", "dtype",
                                             "interpret"))
def crop_mirror_normalize(img, oy, ox, mirror, mean, std, *, out_h, out_w,
                          dtype=jnp.float32, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _cmn(img, oy, ox, mirror, mean, std, out_h, out_w, dtype,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def grouped_matmul(x, w, *, block_c=128, block_f=128, block_d=512,
                   interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d,
                interpret=interpret)


__all__ = ["flash_attention", "flash_decode", "crop_mirror_normalize",
           "grouped_matmul"]
