"""Pallas TPU flash attention (training / prefill), GQA + causal + SWA.

TPU adaptation of the FlashAttention idea: online-softmax accumulation over
KV blocks held in VMEM, with the MXU doing the (bq x D) @ (D x bk) and
(bq x bk) @ (bk x D) matmuls.  The grid is (batch, q_head, q_blocks,
kv_blocks); TPU executes the minor-most grid dimension sequentially per core,
so the m/l/acc scratch accumulators persist across the kv_block axis.

Layouts: q (B, H, S, D), k/v (B, K, T, D) with H = K * G (GQA: the k/v
index_map folds the q head onto its kv head).  Block sizes default to
128 (MXU-aligned); D is kept whole in the lane dimension.

Validated against ref.mha_reference in interpret mode (tests sweep shapes,
dtypes, causal/window flags).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = k_pos < kv_len
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B,H,S,D); k,v (B,K,T,D); H % K == 0. Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    if H % K:
        raise ValueError(f"H={H} not a multiple of K={K}")
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = -(-S // block_q)
    nk = -(-T // block_k)
    S_p, T_p = nq * block_q, nk * block_k
    if S_p != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, S_p - S), (0, 0)))
    if T_p != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=T)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]


__all__ = ["flash_attention"]
