"""Pallas TPU grouped matmul (per-expert GEMM) for the MoE dispatch path.

x (E, C, d) @ w (E, d, f) -> (E, C, f): grid (E, C/bc, f/bf, d/bd) with an
f32 VMEM accumulator carried across the (sequential, minor-most) d axis.
Block sizes are MXU-aligned (128); this is the megablox-style building block
the sort-based MoE dispatch feeds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _done():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 512,
                   interpret: bool = True) -> jax.Array:
    """x (E,C,d) @ w (E,d,f) -> (E,C,f)."""
    E, C, d = x.shape
    f = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, f)
    block_d = min(block_d, d)
    nc, nf, nd = -(-C // block_c), -(-f // block_f), -(-d // block_d)
    Cp, fp, dp = nc * block_c, nf * block_f, nd * block_d
    if (Cp, dp) != (C, d):
        x = jnp.pad(x, ((0, 0), (0, Cp - C), (0, dp - d)))
    if (dp, fp) != (d, f):
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, fp - f)))

    out = pl.pallas_call(
        _gmm_kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :f]


__all__ = ["grouped_matmul"]
