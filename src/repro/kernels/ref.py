"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests) —
plus a pure-NumPy ``crop_mirror_normalize_np`` that doubles as the host-side
baseline transform in ``data.pipeline.ImageFeed``."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,H,S,D); k,v (B,K,T,D) -> (B,H,S,D). GQA by head folding."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, S, D)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return o.reshape(B, H, S, D).astype(q.dtype)


def decode_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """Single-token decode. q (B,H,D); k,v (B,K,T,D); lengths (B,) valid
    prefix lengths. -> (B,H,D)."""
    B, H, D = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    valid = jnp.arange(T)[None, :] < lengths[:, None]          # (B,T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def crop_mirror_normalize_reference(img: jax.Array, oy: jax.Array,
                                    ox: jax.Array, mirror: jax.Array,
                                    mean: jax.Array, std: jax.Array,
                                    out_h: int, out_w: int,
                                    dtype=jnp.float32) -> jax.Array:
    """img (B,H,W,C) uint8 -> (B,C,out_h,out_w), DALI crop_mirror_normalize.

    oy/ox (B,) crop offsets, mirror (B,) bool, mean/std (C,) in 0..255 scale.
    """
    def one(im, y, x, m):
        crop = jax.lax.dynamic_slice(im, (y, x, 0),
                                     (out_h, out_w, im.shape[2]))
        crop = jnp.where(m, crop[:, ::-1, :], crop)
        out = (crop.astype(jnp.float32) - mean) / std
        return out.transpose(2, 0, 1).astype(dtype)

    return jax.vmap(one)(img, oy, ox, mirror)


def crop_mirror_normalize_np(img: np.ndarray, oy, ox, mirror,
                             mean: np.ndarray, std: np.ndarray,
                             out_h: int, out_w: int,
                             dtype=np.float32) -> np.ndarray:
    """NumPy twin of the Pallas kernel: (B,H,W,C) uint8 -> (B,C,oh,ow).

    Same clamping semantics as the kernel entry point (offsets clip to the
    valid window).  Also serves as ``ImageFeed``'s materialize-path host
    transform — the four-pass CPU pipeline the fused kernel replaces.
    """
    B, H, W, C = img.shape
    oy = np.clip(np.asarray(oy, dtype=np.int64), 0, H - out_h)
    ox = np.clip(np.asarray(ox, dtype=np.int64), 0, W - out_w)
    mean = np.asarray(mean, dtype=np.float32)
    std = np.asarray(std, dtype=np.float32)
    out = np.empty((B, C, out_h, out_w), dtype=dtype)
    for i in range(B):
        crop = img[i, oy[i]:oy[i] + out_h, ox[i]:ox[i] + out_w, :]
        if mirror[i]:
            crop = crop[:, ::-1, :]
        x = (crop.astype(np.float32) - mean) / std
        out[i] = x.transpose(2, 0, 1).astype(dtype)
    return out


def gmm_reference(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped (per-expert) matmul: x (E,C,d) @ w (E,d,f) -> (E,C,f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


__all__ = ["mha_reference", "decode_reference",
           "crop_mirror_normalize_reference", "crop_mirror_normalize_np",
           "gmm_reference"]
