"""Fault-tolerant checkpointing: atomic, versioned, async, reshardable.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir and
atomically renamed — a crash mid-save can never corrupt the latest
checkpoint.  Restore takes a *target sharding tree* so a checkpoint saved on
one mesh can be loaded onto a different mesh/host-count (elastic rescale):
arrays are device_put against the new shardings.

The loader position (epoch, cursor) is stored in the manifest, making
mid-epoch restart exact at batch granularity (see core/prefetcher.state).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> str:
        # Snapshot to host memory synchronously (cheap), write async if asked.
        flat = _flatten_with_paths(state)
        manifest = {"step": int(step), "time": time.time(),
                    "keys": sorted(flat.keys()), "extra": extra or {}}

        def write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        self.wait()                        # one save in flight at most
        if blocking:
            write()
        else:
            self._async_thread = threading.Thread(target=write, daemon=True)
            self._async_thread.start()
        return os.path.join(self.directory, f"step_{step:08d}")

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Load into the structure of ``template``; reshard if asked.

        ``shardings``: optional matching tree of NamedSharding for the target
        mesh (elastic restore onto a different topology).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))

        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        paths = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path_)
            for path_, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves_t))
        out = []
        for key, tmpl, sh in zip(paths, leaves_t, shard_leaves):
            if key not in data:
                raise KeyError(f"checkpoint missing key {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {tmpl.shape}")
            arr = arr.astype(tmpl.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return treedef.unflatten(out), manifest


__all__ = ["CheckpointManager"]
