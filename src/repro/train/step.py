"""Train / serve step builders — the functions the launcher jits and the
dry-run lowers.  State is a plain dict pytree: {"params", "opt"}.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import (OptimizerConfig, abstract_opt_state, adamw_init,
                        adamw_update, opt_state_logical_axes)


def make_train_step(model, opt_cfg: OptimizerConfig, microbatches: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    """(state, batch) -> (state, metrics). Donate `state` when jitting.

    microbatches > 1 enables gradient accumulation: the global batch is
    split along dim 0 and scanned, dividing activation memory by the
    microbatch count (required to fit the big train_4k cells in 16 GB/chip).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.train_loss(p, batch), has_aux=True)(params)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def resh(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def body(gacc, mbatch):
                (loss, metrics), g = grads_of(params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype) / microbatches,
                    gacc, g)
                return gacc, metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            grads, metrics_all = jax.lax.scan(body, g0, mb)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_all)
        new_params, new_opt, stats = adamw_update(grads, state["opt"],
                                                  params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(stats)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_serve_step(model) -> Callable:
    """(params, cache, tokens) -> (logits, cache). Donate `cache`."""

    def serve_step(params: Dict, cache: Any, tokens: jax.Array):
        return model.decode_step(params, cache, tokens)

    return serve_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params: Dict, batch: Dict):
        logits, _ = model.forward(params, batch["tokens"], batch)
        return logits

    return prefill_step


def init_state(model, opt_cfg: OptimizerConfig, key: jax.Array,
               dtype=None) -> Dict:
    params = model.init(key, dtype)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def abstract_state(model, opt_cfg: OptimizerConfig) -> Dict:
    ap = model.abstract_params()
    return {"params": ap, "opt": abstract_opt_state(ap, opt_cfg)}


def state_logical_axes(model, opt_cfg: OptimizerConfig) -> Dict:
    pa = model.param_logical_axes()
    return {"params": pa, "opt": opt_state_logical_axes(pa, opt_cfg)}


__all__ = ["make_train_step", "make_serve_step", "make_prefill_step",
           "init_state", "abstract_state", "state_logical_axes"]
