"""GPipe-style pipeline parallelism over a "stage" mesh axis via shard_map.

The layer stack is split into S contiguous stages; microbatches stream
through stages with ``jax.lax.ppermute`` moving activations to the next
stage.  Schedule: plain GPipe (fill S-1 bubbles, then steady state) —
bubble fraction (S-1)/(M+S-1) with M microbatches.

This is an optional parallelism mode (the production mesh in this repo uses
DPxTP(+SP); PP composes on top when depth x width exceeds a pod), exercised
by tests/test_pipeline_parallel.py on an 8-device host mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(fn, mesh, in_specs, out_specs):
    """jax.shard_map (>=0.5) or the experimental spelling (0.4.x)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as esm
    # the scan carry is device-varying after ppermute; the 0.4.x replication
    # checker cannot see that, so it must be disabled rather than pcast-ed.
    return esm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _mark_varying(x, axis):
    """Mark a scan carry device-varying: jax.lax.pcast (some versions) or
    jax.lax.pvary (newer); 0.4.x has no such notion (check_rep=False above
    covers it)."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, (axis,), to="varying")
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        return pvary(x, (axis,))
    return x


def pipeline_forward(stage_fn: Callable, n_stages: int, n_microbatches: int,
                     mesh: Mesh, axis: str = "stage"):
    """Build a pipelined forward: x (M, mb, ...) -> y (M, mb, ...).

    ``stage_fn(stage_params, x)`` applies one stage's layers.
    ``stage_params`` leaves carry a leading stage axis (sharded over
    ``axis``); x microbatches are processed GPipe-style.
    """

    def pipelined(stage_params, x_mb):
        M = n_microbatches
        S = n_stages

        def per_stage(params_local, x_local):
            # params_local: this stage's params (leading axis 1); x_local:
            # full microbatch stream (replicated batch entry point).
            params_local = jax.tree.map(lambda p: p[0], params_local)
            stage_id = jax.lax.axis_index(axis)
            T = M + S - 1               # total schedule ticks

            def tick(carry, t):
                buf, outputs = carry    # buf: activation entering this stage
                # stage s works on microbatch (t - s) when 0 <= t-s < M
                mb_idx = t - stage_id
                active = (mb_idx >= 0) & (mb_idx < M)
                x_in = jnp.where(
                    stage_id == 0,
                    x_local[jnp.clip(mb_idx, 0, M - 1)],
                    buf)
                y = stage_fn(params_local, x_in)
                y = jnp.where(active, y, buf)
                # pass activation to the next stage
                nxt = jax.lax.ppermute(
                    y, axis, [(i, i + 1) for i in range(S - 1)])
                # last stage writes its finished microbatch
                out_idx = jnp.clip(mb_idx, 0, M - 1)
                write = active & (stage_id == S - 1)
                outputs = jnp.where(
                    write,
                    outputs.at[out_idx].set(y),
                    outputs)
                return (nxt, outputs), None

            buf0 = jnp.zeros_like(x_local[0])
            out0 = jnp.zeros_like(x_local)
            # the carry becomes device-varying after ppermute: mark it so
            buf0 = _mark_varying(buf0, axis)
            out0 = _mark_varying(out0, axis)
            (_, outputs), _ = jax.lax.scan(tick, (buf0, out0),
                                           jnp.arange(T))
            # only stage S-1 holds real outputs; broadcast via psum of masked
            outputs = jax.lax.psum(
                jnp.where(stage_id == S - 1, outputs, 0.0), axis)
            return outputs

        return _shard_map(
            per_stage, mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
        )(stage_params, x_mb)

    return pipelined


def stack_stage_params(layer_params: Any, n_stages: int) -> Any:
    """(L, ...) layer-stacked params -> (S, L/S, ...) stage-stacked."""
    def resh(p):
        L = p.shape[0]
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])

    return jax.tree.map(resh, layer_params)


__all__ = ["pipeline_forward", "stack_stage_params"]
