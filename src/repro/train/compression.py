"""Gradient compression for data-parallel all-reduce (int8 + error feedback).

In the pjit path XLA owns the gradient all-reduce, so compression is exposed
as an explicit shard_map collective: each DP rank quantizes its local
gradient shard to int8 (per-row scale), all-reduces the int8 payload (4x
fewer bytes on the wire), dequantizes, and keeps the quantization residual
locally as *error feedback* added to the next step's gradient — the standard
EF-SGD recipe that keeps convergence unbiased in expectation.

Used by the optional ``compressed_dp_grads`` wrapper and unit-tested for the
contraction property (error norm bounded, mean preserved).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if x.ndim == 0:
        x = x[None]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One EF round on a leaf (local shard): returns (g_compressed, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    deq = dequantize_int8(q, scale).reshape(g32.shape)
    return deq.astype(g.dtype), (g32 - deq)


def compressed_psum_grads(grads: Any, errors: Any, axis_name: str
                          ) -> Tuple[Any, Any]:
    """Inside shard_map: int8-compress local grads (+EF), then psum.

    Wire bytes per leaf: 1 byte/elem + scales, vs 4 (f32) / 2 (bf16).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # shared-scale quantization: pmax the per-row amax first (tiny wire
        # cost) so psum(q) is EXACT in the quantized domain — per-shard
        # scales would bias the sum in a way error feedback cannot absorb.
        amax = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
        amax = jax.lax.pmax(amax, axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        deq = summed.astype(jnp.float32) * scale / n    # mean gradient
        new_e = g32 - q.astype(jnp.float32) * scale      # local EF residual
        return deq.astype(g.dtype), new_e

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = tree.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tree.unflatten([o[0] for o in out]),
            tree.unflatten([o[1] for o in out]))


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


__all__ = ["quantize_int8", "dequantize_int8", "compress_leaf",
           "compressed_psum_grads", "init_error_feedback"]
