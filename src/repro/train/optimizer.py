"""AdamW with fp32 or blockwise-int8 optimizer state, global-norm clipping,
and a warmup+cosine schedule.  Pure-JAX (no optax dependency).

int8 state is a distributed-memory trick (8-bit Adam): m and v are stored as
int8 with a per-row fp32 scale, dequantized on use, requantized after the
update.  For kimi-k2 (1.03T params) this is the difference between fitting
512 x 16 GB chips and not: bf16 params (2.06 TB) + int8 m+v (2.06 TB)
~= 8 GB/chip, vs ~24 GB/chip with fp32 m/v + master weights.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # float32       : fp32 m and v (classic AdamW)
    # int8          : blockwise-int8 m and v (8-bit Adam)
    # int8_factored : int8 m + Adafactor-style factored v (row/col moments)
    #                 — the only variant that fits the 1T config on ONE pod
    state_dtype: str = "float32"


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ---------------------------------------------------------------------------
# int8 blockwise quantization (per-row scale over the last axis)
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array) -> Dict[str, jax.Array]:
    if x.ndim == 0:
        x = x[None]
        scale = jnp.maximum(jnp.abs(x), 1e-12) / 127.0
        return {"q": jnp.round(x / scale).astype(jnp.int8)[0],
                "scale": scale[0]}
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(qs: Dict[str, jax.Array]) -> jax.Array:
    return qs["q"].astype(jnp.float32) * qs["scale"]


def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}


# ---------------------------------------------------------------------------
# Init / update
# ---------------------------------------------------------------------------

def _factorable(shape) -> bool:
    return len(shape) >= 2


def _vfactor_init(shape) -> Dict[str, jax.Array]:
    return {"vr": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "vc": jnp.zeros(shape[:-2] + (1, shape[-1]), jnp.float32)}


def _is_vfactor(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"vr", "vc"}


def adamw_init(params: Any, cfg: OptimizerConfig) -> Dict:
    quant_m = cfg.state_dtype in ("int8", "int8_factored")

    def make_m(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if quant_m else z

    def make_v(p):
        if cfg.state_dtype == "int8":
            return _quantize(jnp.zeros(p.shape, jnp.float32))
        if cfg.state_dtype == "int8_factored" and _factorable(p.shape):
            return _vfactor_init(p.shape)
        return jnp.zeros(p.shape, jnp.float32)

    return {"m": jax.tree.map(make_m, params),
            "v": jax.tree.map(make_v, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(abstract_params: Any, cfg: OptimizerConfig) -> Dict:
    def q_spec(p):
        scale_shape = p.shape[:-1] + (1,) if p.shape else ()
        return {"q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "scale": jax.ShapeDtypeStruct(scale_shape, jnp.float32)}

    def one_m(p):
        if cfg.state_dtype in ("int8", "int8_factored"):
            return q_spec(p)
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    def one_v(p):
        if cfg.state_dtype == "int8":
            return q_spec(p)
        if cfg.state_dtype == "int8_factored" and _factorable(p.shape):
            return {"vr": jax.ShapeDtypeStruct(p.shape[:-1] + (1,), jnp.float32),
                    "vc": jax.ShapeDtypeStruct(p.shape[:-2] + (1, p.shape[-1]),
                                               jnp.float32)}
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return {"m": jax.tree.map(one_m, abstract_params),
            "v": jax.tree.map(one_v, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_state_logical_axes(param_axes: Any, cfg: OptimizerConfig) -> Dict:
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)

    def one_m(axes):
        if cfg.state_dtype in ("int8", "int8_factored"):
            scale_axes = tuple(axes[:-1]) + (None,) if axes else ()
            return {"q": tuple(axes), "scale": scale_axes}
        return tuple(axes)

    def one_v(axes):
        if cfg.state_dtype == "int8":
            return one_m(axes)
        if cfg.state_dtype == "int8_factored" and len(axes) >= 2:
            return {"vr": tuple(axes[:-1]) + (None,),
                    "vc": tuple(axes[:-2]) + (None, axes[-1])}
        return tuple(axes)

    return {"m": jax.tree.map(one_m, param_axes, is_leaf=is_axes),
            "v": jax.tree.map(one_v, param_axes, is_leaf=is_axes),
            "step": ()}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: Any, opt_state: Dict, params: Any,
                 cfg: OptimizerConfig) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    quant_m = cfg.state_dtype in ("int8", "int8_factored")

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _dequantize(m) if quant_m else m
        m_new = b1 * m_f + (1 - b1) * g
        m_hat = m_new / bc1
        if _is_vfactor(v):
            g2 = g * g + 1e-30
            vr = b2 * v["vr"] + (1 - b2) * g2.mean(axis=-1, keepdims=True)
            vc = b2 * v["vc"] + (1 - b2) * g2.mean(axis=-2, keepdims=True)
            v_hat = (vr * vc / jnp.maximum(
                vr.mean(axis=-2, keepdims=True), 1e-30)) / bc2
            v_new = {"vr": vr, "vc": vc}
        else:
            v_f = _dequantize(v) if cfg.state_dtype == "int8" else v
            v_full = b2 * v_f + (1 - b2) * g * g
            v_hat = v_full / bc2
            v_new = _quantize(v_full) if cfg.state_dtype == "int8" else v_full
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, (_quantize(m_new) if quant_m else m_new), v_new

    def upd_leaf(p, g, m, v):
        # Chunk giant (layer-stacked) leaves over their leading axis so the
        # f32 dequant/update temporaries are per-layer, not per-stack — for
        # kimi's (61,384,7168,2048) expert weights that is the difference
        # between ~5 GB and ~0.1 GB of optimizer temp per buffer.
        if p.ndim >= 3 and p.size > (1 << 27):
            return jax.lax.map(lambda t: upd(*t), (p, g, m, v))
        return upd(p, g, m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd_leaf(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats


__all__ = ["OptimizerConfig", "lr_at", "adamw_init", "adamw_update",
           "abstract_opt_state", "opt_state_logical_axes", "global_norm"]
