"""End-to-end training loop: loader -> device feed -> jitted step ->
checkpoint, with mid-epoch fault-tolerant restart.

This is the driver the examples use (single host, real payloads).  On a
cluster the same loop runs per host with ``LoaderConfig.shard_id`` /
``num_shards`` set from the process index (each host fetches exactly its
shard of the global batch, as the paper partitions per GPU).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.core import CassandraLoader, KVStore, LoaderConfig
from repro.data.pipeline import DeviceFeed
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_state, make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    seq_len: int = 128
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0


def run_training(model, store: KVStore, uuids, loader_cfg: LoaderConfig,
                 loop_cfg: TrainLoopConfig,
                 opt_cfg: Optional[OptimizerConfig] = None,
                 state: Optional[Dict] = None,
                 on_metrics: Optional[Callable] = None) -> Dict:
    """Train `model` from the network loader. Returns final state + history."""
    opt_cfg = opt_cfg or OptimizerConfig(total_steps=loop_cfg.total_steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))

    ckpt = (CheckpointManager(loop_cfg.checkpoint_dir)
            if loop_cfg.checkpoint_dir else None)
    start_step = 0
    loader_pos = {"epoch": 0, "cursor": 0}
    if state is None:
        if ckpt and ckpt.latest_step() is not None:
            template = init_state(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed))
            state, manifest = ckpt.restore(template)
            start_step = manifest["step"]
            loader_pos = manifest["extra"].get("loader", loader_pos)
        else:
            state = init_state(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed))

    loader = CassandraLoader(store, uuids, loader_cfg)
    loader.start(epoch=loader_pos["epoch"], cursor=loader_pos["cursor"])
    feed = DeviceFeed(loader, loop_cfg.seq_len)

    history = []
    t0 = time.time()
    for step in range(start_step, loop_cfg.total_steps):
        dev_batch, _meta = next(feed)
        batch = {"tokens": dev_batch["tokens"],
                 "loss_mask": dev_batch["loss_mask"]}
        state, metrics = step_fn(state, batch)
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            rec = {"step": step + 1, "loss": loss,
                   "sps": (step + 1 - start_step) * loader_cfg.batch_size
                   / max(time.time() - t0, 1e-9)}
            history.append(rec)
            if on_metrics:
                on_metrics(rec)
        if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
            ckpt.save(step + 1, state,
                      extra={"loader": loader.state()}, blocking=False)
    if ckpt:
        ckpt.save(loop_cfg.total_steps, state,
                  extra={"loader": loader.state()}, blocking=True)
    loader.close()
    return {"state": state, "history": history}


__all__ = ["TrainLoopConfig", "run_training"]
