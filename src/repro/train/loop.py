"""End-to-end training loop: loader -> device feed -> jitted step ->
checkpoint, with mid-epoch fault-tolerant restart.

This is the driver the examples use (single host, real payloads).  On a
cluster the same loop runs per host with ``LoaderConfig.shard_id`` /
``num_shards`` set from the process index (each host fetches exactly its
shard of the global batch, as the paper partitions per GPU).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.core import CassandraLoader, KVStore, LoaderConfig, VirtualClock
from repro.data.pipeline import DeviceFeed
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_state, make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    seq_len: int = 128
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0
    # Compute seconds charged to the timeline per step instead of the
    # measured wall time of the jitted step.  With a virtual-clock loader
    # this pins the consumer side of the simulation (deterministic stall /
    # goodput numbers — what bench_training's goodput sweep gates on);
    # None (default) charges the measured step time.
    charge_step_time: Optional[float] = None


def run_training(model, store: KVStore, uuids, loader_cfg: LoaderConfig,
                 loop_cfg: TrainLoopConfig,
                 opt_cfg: Optional[OptimizerConfig] = None,
                 state: Optional[Dict] = None,
                 on_metrics: Optional[Callable] = None) -> Dict:
    """Train `model` from the network loader.

    Returns ``{"state", "history", "stats", "step_stats"}`` — history
    records carry ``loss``/``sps`` plus per-step data-stall accounting
    (``stall_frac``, ``goodput_sps``), ``stats`` is the
    ``StepStats.summary`` at skip=1 (the jit-compile step excluded) and
    ``step_stats`` the raw ``core.stats.StepStats`` for custom skips.
    """
    opt_cfg = opt_cfg or OptimizerConfig(total_steps=loop_cfg.total_steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))

    ckpt = (CheckpointManager(loop_cfg.checkpoint_dir)
            if loop_cfg.checkpoint_dir else None)
    start_step = 0
    loader_pos = {"epoch": 0, "cursor": 0}
    if state is None:
        if ckpt and ckpt.latest_step() is not None:
            template = init_state(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed))
            state, manifest = ckpt.restore(template)
            start_step = manifest["step"]
            loader_pos = manifest["extra"].get("loader", loader_pos)
        else:
            state = init_state(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed))

    loader = CassandraLoader(store, uuids, loader_cfg)
    loader.start(epoch=loader_pos["epoch"], cursor=loader_pos["cursor"])
    # adaptive runs resume at the checkpointed operating point instead of
    # re-slow-starting from scratch (no-op in static mode / old checkpoints)
    loader.restore_flow(loader_pos.get("flow"))
    feed = DeviceFeed(loader, loop_cfg.seq_len)
    ss = feed.step_stats
    clk = loader.clock
    virtual = isinstance(clk, VirtualClock)
    B = loader_cfg.batch_size

    def ckpt_extra() -> Dict:
        # the *feed's* position (loader cursor rewound by device-queued
        # batches) — checkpointing loader.state() directly would skip the
        # in-flight batches on restore
        pos = feed.state()
        flow = loader.flow_snapshot()
        if flow is not None:
            pos["flow"] = flow
        return {"loader": pos}

    history = []
    t0 = None                 # set after the first step: sps excludes the
    #                           jit compile baked into step one
    for step in range(start_step, loop_cfg.total_steps):
        dev_batch, _meta = next(feed)
        batch = {"tokens": dev_batch["tokens"],
                 "loss_mask": dev_batch["loss_mask"]}
        c0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        compute = time.perf_counter() - c0
        if loop_cfg.charge_step_time is not None:
            compute = loop_cfg.charge_step_time
        if virtual:
            # charge compute to the sim timeline: in-flight transfers
            # progress during the step, and wait/compute share one clock
            clk.sleep(compute)
        ss.on_compute(compute, t_end=clk.now())
        if t0 is None:
            t0 = time.time()
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            rec = {"step": step + 1, "loss": loss,
                   "sps": (step - start_step) * B
                   / max(time.time() - t0, 1e-9),
                   "stall_frac": ss.stall_frac(skip=1),
                   "goodput_sps": ss.goodput_sps(B, skip=1)}
            history.append(rec)
            if on_metrics:
                on_metrics(rec)
        if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
            ckpt.save(step + 1, state, extra=ckpt_extra(), blocking=False)
    if ckpt:
        ckpt.save(loop_cfg.total_steps, state, extra=ckpt_extra(),
                  blocking=True)
    loader.close()
    return {"state": state, "history": history,
            "stats": ss.summary(B, skip=1), "step_stats": ss}


__all__ = ["TrainLoopConfig", "run_training"]
