from .rules import (rules_for_profile, shard_batch_spec, spec_for,
                    tree_shardings)

__all__ = ["rules_for_profile", "shard_batch_spec", "spec_for",
           "tree_shardings"]
