"""Logical-axis sharding rules -> NamedSharding, divisibility-aware.

Every parameter/input tensor carries logical axis names (see models/params.P
and the models' ``input_logical_axes``).  This engine maps logical axes to
mesh axes with:
  * a global priority order (e.g. shard kv_heads before falling back to
    sharding the KV sequence of a cache);
  * divisibility checks (25 heads on a 16-way axis -> replicate, logged);
  * profile-dependent rules: "tp" shards weights over the model axis only;
    "fsdp_tp" additionally shards the d_model dim over the data axis
    (ZeRO-3/FSDP-style) — required for the 314B/1T configs.
"""

from __future__ import annotations

import logging
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

log = logging.getLogger(__name__)

Candidate = Tuple[str, ...]

# candidates per logical axis, in preference order
BASE_RULES: Dict[str, List[Candidate]] = {
    # data-parallel axes
    "batch": [("pod", "data"), ("data",)],
    # tensor-parallel axes
    "experts": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "d_ff": [("model",)],
    "vocab": [("model",)],
    "d_inner": [("model",)],
    "d_inner2": [("model",)],
    "heads2": [("model",)],
    "gates": [("model",)],
    "gates_h": [("model",)],
    # sequence/context parallelism (activations, KV caches, long-context)
    "seq": [("data",)],
    "kv_seq": [("model",)],
    "frames": [],
    # last-resort: shard head_dim over model (e.g. KV caches whose kv_heads
    # don't divide the model axis, xlstm matrix states)
    "head_dim": [("model",)],
    # replicated by default
    "d_model": [],
    "d_model_out": [],
    "head_dim_out": [],
    "state": [],
    "state2": [],
    "conv_k": [],
    "layers": [],
    "patches": [],
}

FSDP_EXTRA: Dict[str, List[Candidate]] = {
    # prefer sharding over pod x data (multi-pod FSDP: without the pod axis
    # the parameter shards replicate per pod); single-pod meshes filter the
    # absent "pod" axis out and use data only.
    "d_model": [("pod", "data"), ("data",)],
    "d_model_out": [("pod", "data"), ("data",)],
}

# assignment priority: earlier names grab mesh axes first
PRIORITY = [
    "experts", "heads", "kv_heads", "d_ff", "vocab", "d_inner", "d_inner2",
    "heads2", "gates", "gates_h", "batch", "seq", "kv_seq", "d_model",
    "d_model_out", "head_dim", "state", "frames",
]


def rules_for_profile(profile: str) -> Dict[str, List[Candidate]]:
    rules = {k: list(v) for k, v in BASE_RULES.items()}
    if profile == "fsdp_tp":
        for k, v in FSDP_EXTRA.items():
            rules[k] = list(v) + rules.get(k, [])
    return rules


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh,
             rules: Dict[str, List[Candidate]]) -> PartitionSpec:
    """Build a PartitionSpec for one tensor."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assignment: Dict[int, Candidate] = {}
    used: set = set()

    def axis_priority(name: Optional[str]) -> int:
        if name is None or name not in PRIORITY:
            return len(PRIORITY)
        return PRIORITY.index(name)

    dims = sorted(range(len(axes)), key=lambda i: (axis_priority(axes[i]), i))
    for i in dims:
        name = axes[i]
        if name is None:
            continue
        for cand in rules.get(name, []):
            cand = tuple(a for a in cand if a in mesh_sizes)
            if not cand or any(a in used for a in cand):
                continue
            size = int(np.prod([mesh_sizes[a] for a in cand]))
            if shape[i] % size == 0 and shape[i] >= size:
                assignment[i] = cand
                used.update(cand)
                break
        else:
            if rules.get(name):
                log.debug("replicating axis %r of shape %s (no divisible rule)",
                          name, tuple(shape))
    parts = []
    for i in range(len(axes)):
        a = assignment.get(i)
        parts.append(a if a is None or len(a) > 1 else a[0])
    return PartitionSpec(*parts)


def tree_shardings(spec_tree, axes_tree, mesh: Mesh, profile: str = "tp",
                   extra_rules: Optional[Dict[str, List[Candidate]]] = None):
    """NamedSharding tree for a (ShapeDtypeStruct|array) tree + axes tree.

    Axes leaves are tuples of logical names, which jax.tree would treat as
    containers — so flatten the value tree first and match axes up to it.
    """
    rules = rules_for_profile(profile)
    if extra_rules:
        for k, v in extra_rules.items():
            rules[k] = list(v) + rules.get(k, [])
    leaves, treedef = jax.tree.flatten(spec_tree)
    axes_leaves = treedef.flatten_up_to(axes_tree)
    out = [NamedSharding(mesh, spec_for(a, x.shape, mesh, rules))
           for x, a in zip(leaves, axes_leaves)]
    return treedef.unflatten(out)


def make_act_constrainer(mesh: Mesh, batch_axes=("pod", "data"),
                         seq_axis: str = "model"):
    """Sequence-parallel residual-stream constrainer (Megatron-SP style).

    Returns f(x) that constrains a (B, S, D) activation to
    P(batch_axes, seq_axis, None) when divisible.  Applied at scan-layer
    boundaries it (a) shards the per-layer saved activations of the scan VJP
    by the model-axis size and (b) turns the attention/FFN entry/exit into
    all-gather / reduce-scatter pairs — XLA SPMD derives the standard SP
    communication pattern from the constraint.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bnames = tuple(a for a in batch_axes if a in sizes and sizes[a] > 1)
    bsize = int(np.prod([sizes[a] for a in bnames])) if bnames else 1
    ssize = sizes.get(seq_axis, 1)

    def constrain(x):
        if x.ndim != 3:
            return x
        parts = [None, None, None]
        if bsize > 1 and x.shape[0] % bsize == 0:
            parts[0] = bnames if len(bnames) > 1 else bnames[0]
        if ssize > 1 and x.shape[1] % ssize == 0:
            parts[1] = seq_axis
        if parts[0] is None and parts[1] is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts)))

    return constrain


def make_attn_constrainers(mesh: Mesh, batch_axes=("pod", "data"),
                           tp_axis: str = "model"):
    """(constrain_q, constrain_kv) for attention operand layouts.

    q (B,S,H,D): shard heads over the model axis when divisible, else fall
    back to sharding the query sequence (keeps attention FLOPs/memory sharded
    for head counts like 56 or 25 that don't divide 16 — without this XLA
    silently *replicates* the whole attention computation per device).
    k/v (B,T,H,D) (already G-expanded): heads when divisible, else
    replicated (full KV is needed by every q shard under causal masking).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bnames = tuple(a for a in batch_axes if a in sizes and sizes[a] > 1)
    bsize = int(np.prod([sizes[a] for a in bnames])) if bnames else 1
    tsize = sizes.get(tp_axis, 1)
    bpart = bnames if len(bnames) > 1 else (bnames[0] if bnames else None)

    def _shard(x, head_ok: bool, seq_ok: bool):
        if x.ndim != 4 or tsize <= 1:
            return x
        parts = [None, None, None, None]
        if bsize > 1 and x.shape[0] % bsize == 0:
            parts[0] = bpart
        if head_ok and x.shape[2] % tsize == 0:
            parts[2] = tp_axis
        elif seq_ok and x.shape[1] % tsize == 0:
            parts[1] = tp_axis
        if all(p is None for p in parts):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts)))

    def constrain_q(x):
        return _shard(x, head_ok=True, seq_ok=True)

    def constrain_kv(x):
        return _shard(x, head_ok=True, seq_ok=False)

    return constrain_q, constrain_kv


def make_moe_constrainer(mesh: Mesh, batch_axes=("pod", "data"),
                         tp_axis: str = "model"):
    """Constrainer for (E, C, X) MoE dispatch/expert buffers: experts over
    the model axis when divisible, else capacity over the data axes, else
    the feature dim over the model axis."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bnames = tuple(a for a in batch_axes if a in sizes and sizes[a] > 1)
    bsize = int(np.prod([sizes[a] for a in bnames])) if bnames else 1
    tsize = sizes.get(tp_axis, 1)
    bpart = bnames if len(bnames) > 1 else (bnames[0] if bnames else None)

    def constrain(x):
        # (B, E, C, X) grouped dispatch/expert buffers: groups over the data
        # axes, experts over the model axis when divisible (else the feature
        # dim), capacity replicated.
        if x.ndim != 4 or tsize <= 1:
            return x
        B, E, C, X = x.shape
        parts = [None, None, None, None]
        if bsize > 1 and B % bsize == 0:
            parts[0] = bpart
        if E % tsize == 0:
            parts[1] = tp_axis
        elif X % tsize == 0:
            parts[3] = tp_axis
        if all(p is None for p in parts):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*parts)))

    return constrain


def shard_batch_spec(mesh: Mesh, ndim: int) -> NamedSharding:
    """Default data-parallel sharding for a (B, ...) host batch array."""
    names = [a for a in ("pod", "data") if a in mesh.axis_names]
    parts = [tuple(names) if len(names) > 1 else names[0]] + [None] * (ndim - 1)
    return NamedSharding(mesh, PartitionSpec(*parts))


__all__ = ["BASE_RULES", "FSDP_EXTRA", "PRIORITY", "rules_for_profile",
           "spec_for", "tree_shardings", "shard_batch_spec"]
