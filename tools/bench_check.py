#!/usr/bin/env python3
"""CI perf-regression gate: benchmark results vs committed baselines.

The benchmark suites are deterministic (virtual clock + seeded RNGs), so a
``--quick`` run on any machine produces the same numbers — what moves them
is *code*.  This script turns that into a regression gate: it compares the
headline metrics of each results file (written by the quick bench runs)
against the committed baselines in ``benchmarks/baselines/`` and fails when
any metric leaves the ±``--tolerance`` band (default ±15%).

* a drop below the band is a **regression** — fix the code;
* a rise above the band is an unrecorded **improvement** — rerun with
  ``--update`` and commit the new baseline, so the gate stays tight around
  reality instead of guarding a stale floor.

A context block (bench sizing: batch size, rounds, dataset size...) is
stored with each baseline and must match exactly — full-size nightly
results are never judged against quick baselines.

Boolean ``checks`` recorded in the results files must all be true as well
(the benches assert them at run time; re-checking here keeps a hand-edited
results file from sneaking past).

Usage (CI runs exactly this, see .github/workflows/ci.yml):

    PYTHONPATH=src python -m benchmarks.bench_ramp --flowctl --quick
    PYTHONPATH=src python -m benchmarks.bench_multihost --replication --quick
    PYTHONPATH=src python -m benchmarks.bench_multihost --scale --quick
    PYTHONPATH=src python -m benchmarks.bench_scenarios --quick
    PYTHONPATH=src python -m benchmarks.bench_training --goodput --quick
    PYTHONPATH=src python -m benchmarks.bench_tenancy --quick
    PYTHONPATH=src python -m benchmarks.bench_wirefmt --quick
    PYTHONPATH=src python -m benchmarks.bench_competitors --quick
    python tools/bench_check.py

Baseline update procedure (after an intentional perf change):

    # regenerate the quick results, then
    python tools/bench_check.py --update
    git add benchmarks/baselines/ && git commit

Exit code 0 = within tolerance, 1 = regression/missing file/stale baseline.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
RESULTS_DIR = REPO_ROOT / "results"
BASELINE_DIR = REPO_ROOT / "benchmarks" / "baselines"

# Per results file: the sizing context that must match exactly, and the
# dotted paths of the guarded scalar metrics.
SPECS = {
    "flowctl_ramp.json": {
        "context": ["batch_size", "io_threads", "n_batches", "static_sweep"],
        "metrics": [
            "routes.local.adaptive.MBps",
            "routes.local.best_static.MBps",
            "routes.med.adaptive.MBps",
            "routes.med.best_static.MBps",
            "routes.high.adaptive.MBps",
            "routes.high.best_static.MBps",
            "federated.aggregate_MBps",
        ],
    },
    "multihost_replication.json": {
        "context": ["quick", "rounds", "n_samples", "zipf_s", "seed"],
        "metrics": [
            "uniform_MBps",
            "zipf_MBps",
            "zipf_replicated_MBps",
            "replica_hit_frac",
        ],
    },
    "training_goodput.json": {
        # stall-fraction bounds and the exactly-once restore property are
        # boolean `checks` asserted by the bench itself (lower stall is
        # better, so a ±band on it would flag improvements as regressions);
        # the baselines guard the goodput numbers per cell
        "context": ["quick", "n_steps", "n_samples", "batch_size",
                    "step_time_s", "skip"],
        "metrics": [
            "cells.local.static.goodput_sps",
            "cells.local.adaptive.goodput_sps",
            "cells.med.static.goodput_sps",
            "cells.med.adaptive.goodput_sps",
            "cells.high.static.goodput_sps",
            "cells.high.adaptive.goodput_sps",
        ],
    },
    "tenancy.json": {
        # the isolation bounds (serve p99 vs solo, aggregate vs untenanted)
        # are boolean `checks` asserted by the bench itself; the baselines
        # guard the scenario operating points they are computed from
        "context": ["quick", "rounds", "n_samples", "batch_size",
                    "zipf_s", "seed"],
        "metrics": [
            "solo.p99_ms",
            "untenanted.p99_ms",
            "tenanted.p99_ms",
            "untenanted.aggregate_MBps",
            "tenanted.aggregate_MBps",
            "tenanted.serve_MBps",
        ],
    },
    "wirefmt.json": {
        # the codec-gain / budget-convergence / arena-equivalence claims are
        # boolean `checks` asserted by the bench itself; the baselines guard
        # the operating points they are computed from.  Wall-clock numbers
        # (host_cpu_ratio, host_prep_s) are deliberately NOT gated here —
        # only the virtual-clock metrics are machine-independent.
        "context": ["quick", "batch_size", "n_samples", "n_batches", "seed"],
        "metrics": [
            "codec.cells.high.none.MBps",
            "codec.cells.high.byteshuffle.MBps",
            "codec.cells.high.byteshuffle.wire_MB",
            "codec.cells.high.byteshuffle.payload_MB",
            "codec.cells.local.none.MBps",
            "codec.cells.local.byteshuffle.MBps",
            "codec.gain_high",
            "codec.budget_ratio",
        ],
    },
    "multihost_scale.json": {
        # wall_s / events_per_sec / setup_s are wall-clock and machine-
        # dependent — the bench itself asserts the CI budget and the
        # events/sec floor as boolean `checks`; only the deterministic
        # virtual-clock metrics are gated here.  events_total pins the
        # event core: a scheduling rewrite that changes the simulated
        # event count (or ordering enough to alter the run) trips it.
        "context": ["quick", "n_hosts", "n_clusters", "rounds",
                    "batch_size", "n_samples", "seed"],
        "metrics": [
            "aggregate_MBps",
            "fairness",
            "wan_bytes_share",
            "replica_local_hit_frac",
            "events_total",
        ],
    },
    "competitors.json": {
        # the acceptance claim (ours >= both baselines on the high route)
        # and the baselines' distance-degradation sanity checks are boolean
        # `checks` asserted by the bench itself; the baselines here guard
        # the throughput cells the claims are computed from
        "context": ["quick", "seed", "batch_size", "n_samples",
                    "n_batches", "shard_bytes"],
        "metrics": [
            "cells.local.ours_MBps",
            "cells.local.sd_MBps",
            "cells.local.sync_MBps",
            "cells.med.ours_MBps",
            "cells.med.sd_MBps",
            "cells.med.sync_MBps",
            "cells.high.ours_MBps",
            "cells.high.sd_MBps",
            "cells.high.sync_MBps",
        ],
    },
    "scenarios.json": {
        "context": ["quick", "n_samples", "static_sweep", "oracle_slack"],
        "metrics": [
            "matrix.adaptive_floor_ratio",
            "matrix.cells.steady.oracle_MBps",
            "matrix.cells.steady.ratios.adaptive",
            "matrix.cells.bw_step.ratios.adaptive",
            "matrix.cells.lat_spike.oracle_MBps",
            "matrix.cells.lat_spike.ratios.adaptive",
            "matrix.cells.lat_ramp.ratios.adaptive",
            "matrix.cells.diurnal.ratios.adaptive",
            "matrix.cells.outage_flash.ratios.adaptive",
            "tracking.aggregate_MBps",
            "tracking.replica_hit_frac",
        ],
    },
}


def dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            raise KeyError(f"metric path {path!r} missing at {part!r}")
        obj = obj[part]
    if not isinstance(obj, (int, float)) or isinstance(obj, bool):
        raise TypeError(f"metric {path!r} is not a number: {obj!r}")
    return float(obj)


def extract(name: str, results: dict) -> dict:
    spec = SPECS[name]
    return {
        "context": {k: results.get(k) for k in spec["context"]},
        "metrics": {p: dig(results, p) for p in spec["metrics"]},
    }


def check_file(name: str, tolerance: float, update: bool) -> list:
    """Returns a list of problem strings (empty = this file is green)."""
    results_path = RESULTS_DIR / name
    baseline_path = BASELINE_DIR / name
    if not results_path.exists():
        return [f"{name}: no results at {results_path} — run the quick "
                "bench first (see module docstring)"]
    results = json.loads(results_path.read_text())

    failed_checks = [k for k, ok in results.get("checks", {}).items()
                     if not ok]
    if failed_checks:
        return [f"{name}: results file records failed checks: "
                f"{failed_checks}"]

    current = extract(name, results)
    if update:
        BASELINE_DIR.mkdir(parents=True, exist_ok=True)
        baseline_path.write_text(json.dumps(current, indent=2,
                                            sort_keys=True) + "\n")
        print(f"  {name}: baseline updated "
              f"({len(current['metrics'])} metrics)")
        return []
    if not baseline_path.exists():
        return [f"{name}: no baseline at {baseline_path} — run "
                "`python tools/bench_check.py --update` on a good build "
                "and commit it"]
    baseline = json.loads(baseline_path.read_text())

    if baseline.get("context") != current["context"]:
        return [f"{name}: bench sizing changed "
                f"(baseline {baseline.get('context')} vs current "
                f"{current['context']}) — full-size results are not "
                "comparable to quick baselines; rerun the quick bench or "
                "--update after an intentional resize"]

    problems = []
    for path, base in baseline["metrics"].items():
        if path not in current["metrics"]:
            problems.append(f"{name}: {path} missing from results")
            continue
        cur = current["metrics"][path]
        rel = (cur - base) / abs(base) if base else (0.0 if cur == 0
                                                     else float("inf"))
        mark = "ok"
        if rel < -tolerance:
            mark = "REGRESSION"
            problems.append(f"{name}: {path} regressed {rel:+.1%} "
                            f"({base:.2f} -> {cur:.2f}, tolerance "
                            f"±{tolerance:.0%})")
        elif rel > tolerance:
            mark = "IMPROVED (stale baseline)"
            problems.append(f"{name}: {path} improved {rel:+.1%} beyond the "
                            f"band ({base:.2f} -> {cur:.2f}) — rerun with "
                            "--update and commit the new baseline")
        print(f"  {name}: {path:45s} {base:12.2f} -> {cur:12.2f} "
              f"({rel:+6.1%}) {mark}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Compare bench results against committed baselines")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative tolerance band (default 0.15 = ±15%%)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from the current results")
    ap.add_argument("files", nargs="*", default=[],
                    help=f"results files to check (default: all of "
                         f"{sorted(SPECS)})")
    args = ap.parse_args(argv)
    names = args.files or sorted(SPECS)
    unknown = [n for n in names if n not in SPECS]
    if unknown:
        print(f"unknown results files {unknown} (known: {sorted(SPECS)})")
        return 1
    problems = []
    for name in names:
        problems.extend(check_file(name, args.tolerance, args.update))
    if problems:
        print("\nbench_check FAILED:")
        for p in problems:
            print(f"  - {p}")
        return 1
    verdict = ("baselines updated" if args.update
               else "all metrics within tolerance")
    print(f"\nbench_check: {verdict} ({len(names)} file(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
