#!/usr/bin/env python3
"""Docs lint: fail on broken intra-repo links in the markdown docs.

Checks every ``[text](target)`` and ``![alt](target)`` in ``README.md`` and
``docs/*.md`` (plus any extra files passed as arguments):

* external links (``http(s)://``, ``mailto:``) are skipped;
* pure in-page anchors (``#section``) are skipped;
* everything else is resolved relative to the containing file (fragments
  stripped) and must exist inside the repository.

Exit code 0 = clean, 1 = broken links (each one listed).  Run from anywhere:

    python tools/docs_lint.py [extra.md ...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

# [text](target) and ![alt](target); target ends at the first unescaped ')'
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def iter_links(path: Path):
    """Yield (line_number, raw_target) for every markdown link in ``path``,
    skipping fenced code blocks (``` ... ```)."""
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check_file(path: Path):
    """Return ``(broken_links, total_links)`` for one markdown file."""
    broken = []
    n_links = 0
    for lineno, target in iter_links(path):
        n_links += 1
        if target.startswith(_EXTERNAL) or target.startswith("#"):
            continue
        resolved = (path.parent / target.split("#", 1)[0]).resolve()
        try:
            resolved.relative_to(REPO_ROOT)
        except ValueError:
            broken.append((lineno, target, "escapes the repository"))
            continue
        if not resolved.exists():
            broken.append((lineno, target, "does not exist"))
    return broken, n_links


def main(argv: list) -> int:
    files = [REPO_ROOT / "README.md"]
    files += sorted((REPO_ROOT / "docs").glob("*.md"))
    files += [Path(a).resolve() for a in argv]
    missing_inputs = [f for f in files if not f.exists()]
    if missing_inputs:
        for f in missing_inputs:
            print(f"docs-lint: input file missing: {f}")
        return 1
    n_links = 0
    failures = 0
    for f in files:
        broken, file_links = check_file(f)
        n_links += file_links
        try:
            shown = f.relative_to(REPO_ROOT)
        except ValueError:
            shown = f
        for lineno, target, why in broken:
            print(f"{shown}:{lineno}: broken link '{target}' ({why})")
            failures += 1
    print(f"docs-lint: {len(files)} files, {n_links} links, "
          f"{failures} broken")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
