"""Multi-host loading: shard correctness, coordinated checkpoints,
contention, node failure."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CassandraLoader, EpochPlan, KVStore, LoaderConfig,
                        MultiHostConfig, MultiHostRun, tight_loop)
from repro.core.kvstore import make_uuid
from repro.data.datasets import SyntheticImageDataset, ingest


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=24_000, seed=5))
    return store, uuids


def _mh_cfg(n_hosts, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=128, prefetch_buffers=4,
                    io_threads=4, route="high", backend="scylla",
                    n_nodes=4, replication_factor=2, hedge_after=1.0,
                    seed=13, node_egress_bandwidth=1.2e8)
    defaults.update(kw)
    return MultiHostConfig(**defaults)


# ---------------------------------------------------------------------------
# EpochPlan sharding (the strided-slice bug fix)
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 400), num_shards=st.integers(1, 9),
       seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_shards_disjoint_and_cover(n, num_shards, seed):
    """Shards partition the dataset exactly, for any uneven division."""
    rng = np.random.default_rng(7)
    uuids = [make_uuid(rng) for _ in range(n)]
    shards = [EpochPlan(uuids, seed=seed, shard_id=i, num_shards=num_shards)
              for i in range(num_shards)]
    sizes = [len(s) for s in shards]
    assert sum(sizes) == n
    assert max(sizes) - min(sizes) <= 1          # balanced strips
    seen = [str(u) for s in shards for u in s._uuids]
    assert len(set(seen)) == len(seen) == n      # disjoint
    assert set(seen) == {str(u) for u in uuids}  # jointly cover


def test_shard_strip_is_shuffled_not_strided():
    """Contiguous strips of a *shuffled* list (not uuids[i::N])."""
    rng = np.random.default_rng(0)
    uuids = [make_uuid(rng) for _ in range(100)]
    shard0 = EpochPlan(uuids, seed=1, shard_id=0, num_shards=4)._uuids
    assert shard0 != uuids[0::4]                 # not the old strided slice
    assert shard0 != uuids[:25]                  # not an unshuffled strip


def test_epoch_plan_rejects_bad_shard_spec():
    uuids = [make_uuid(np.random.default_rng(0)) for _ in range(8)]
    with pytest.raises(ValueError):
        EpochPlan(uuids, shard_id=4, num_shards=4)
    with pytest.raises(ValueError):
        EpochPlan(uuids, shard_id=-1, num_shards=2)


# ---------------------------------------------------------------------------
# Checkpoint state round-trips (uneven shards, both prefetchers)
# ---------------------------------------------------------------------------

def _loader(store, uuids, **kw):
    defaults = dict(batch_size=32, prefetch_buffers=4, io_threads=4,
                    route="low", backend="scylla", seed=7)
    defaults.update(kw)
    return CassandraLoader(store, uuids, LoaderConfig(**defaults))


@pytest.mark.parametrize("num_shards", [3, 7])
def test_state_epoch_math_uneven_shards(store_uuids, num_shards):
    """consumed*B walks the (epoch, cursor) odometer of THIS shard's size."""
    store, uuids = store_uuids
    small = uuids[:1000]                        # 1000 % 3 and % 7 != 0
    ld = _loader(store, small, shard_id=1, num_shards=num_shards,
                 out_of_order=False)
    n = len(ld.plan)
    assert n == len(small) // num_shards or n == len(small) // num_shards + 1
    ld.start()
    batches = (n // 32) + 2                     # crosses the epoch boundary
    for _ in range(batches):
        ld.next_batch()
    s = ld.state()
    total = batches * 32
    assert s["epoch"] == total // n
    assert s["cursor"] == total % n


@pytest.mark.parametrize("ooo", [False, True])
def test_checkpoint_restore_roundtrip(store_uuids, ooo):
    store, uuids = store_uuids
    small = uuids[:1000]
    ld = _loader(store, small, shard_id=0, num_shards=3, out_of_order=ooo)
    ld.start()
    for _ in range(5):
        ld.next_batch()
    s = ld.state()

    res = _loader(store, small, shard_id=0, num_shards=3, out_of_order=ooo)
    res.start(s["epoch"], s["cursor"])
    assert res.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                           "consumed": 0}
    if not ooo:
        # in-order: resumed delivery equals the original stream continuation
        cont = ld.next_batch().uuids
        assert res.next_batch().uuids == cont
    else:
        # OOO reorders within the in-flight window, but must only deliver
        # samples from the plan at/after the restored cursor (same epoch)
        perm = res.plan.permutation(s["epoch"])
        allowed = {str(u) for u in perm[s["cursor"]:]}
        got = [str(u) for u in res.next_batch().uuids]
        assert set(got) <= allowed
        assert len(set(got)) == len(got)


def test_restart_cursor_past_shard_end_rolls_over(store_uuids):
    """A cursor >= shard length (uneven global batch mapping) must normalize
    instead of silently skipping an epoch's worth of data."""
    store, uuids = store_uuids
    ld = _loader(store, uuids[:1000], shard_id=2, num_shards=3)
    n = len(ld.plan)
    ld.start(epoch=0, cursor=n + 5)
    assert ld.state() == {"epoch": 1, "cursor": 5, "consumed": 0}


def test_empty_shard_raises(store_uuids):
    store, uuids = store_uuids
    # 2 samples over 3 shards: the floor-strip formula leaves shard 0 empty
    ld = _loader(store, uuids[:2], shard_id=0, num_shards=3)
    assert len(ld.plan) == 0
    with pytest.raises(ValueError):
        ld.start()


# ---------------------------------------------------------------------------
# Short-run stats (the negative-skip bug fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_batches", [1, 2])
def test_tight_loop_short_runs(store_uuids, n_batches):
    store, uuids = store_uuids
    ld = _loader(store, uuids[:4000], batch_size=64)
    res = tight_loop(ld, n_batches=n_batches)
    assert res["batches"] == n_batches
    assert res["throughput_Bps"] >= 0.0         # was a negative-index misslice
    assert res["net_bytes"] > 0


# ---------------------------------------------------------------------------
# Multi-host coordinator
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_contention_sublinear_but_fair(store_uuids):
    """Against a pinched shared cluster, aggregate throughput grows
    sub-linearly with clients while per-client rates stay within a bound."""
    store, uuids = store_uuids
    agg = {}
    for n in (1, 4):
        rep = MultiHostRun(store, uuids, _mh_cfg(n)).run(12)
        agg[n] = rep["aggregate_Bps"]
        assert rep["fairness"] > 0.6            # no client starves
    assert agg[4] > agg[1]                      # more clients -> more total
    assert agg[4] < 3.5 * agg[1]                # ...but sub-linear (shared NICs)


@pytest.mark.slow
def test_node_failure_failover_keeps_loaders_alive(store_uuids):
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids, _mh_cfg(4)).start()
    run.run(4)                                  # requests now deep in flight
    run.inject_failure("node1", after=0.0)
    served_at_failure = run.cluster.nodes["node1"].requests_served
    rep = run.run(12)                           # must not raise TimeoutError
    assert rep["cluster_load"]["node1"]["down"] == 1.0
    # the dark node served nothing after the failure fired
    assert run.cluster.nodes["node1"].requests_served == served_at_failure
    assert all(b > 0 for b in rep["per_client_Bps"])


def test_coordinated_checkpoint_consistent_and_resumable(store_uuids):
    store, uuids = store_uuids
    cfg = _mh_cfg(3, node_egress_bandwidth=6.25e9, route="low",
                  hedge_after=None)
    run = MultiHostRun(store, uuids, cfg).start()
    run.run(6)
    ck = run.checkpoint()
    assert ck["rounds"] == 6 and len(ck["shards"]) == 3
    # all shards checkpoint the same consumed count (consistent boundary)
    assert {s["consumed"] for s in ck["shards"]} == {6}

    resumed = MultiHostRun(store, uuids, cfg).start(ck)
    for ld, s in zip(resumed.loaders, ck["shards"]):
        assert ld.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                              "consumed": 0}
    rep = resumed.run(3)
    assert resumed.checkpoint()["rounds"] == 3
    assert all(b > 0 for b in rep["per_client_Bps"])


def test_checkpoint_shard_count_mismatch_rejected(store_uuids):
    store, uuids = store_uuids
    cfg = _mh_cfg(2, node_egress_bandwidth=6.25e9, route="low")
    run = MultiHostRun(store, uuids, cfg).start()
    run.run(2)
    ck = run.checkpoint()
    other = MultiHostRun(store, uuids, _mh_cfg(3, node_egress_bandwidth=6.25e9,
                                               route="low"))
    with pytest.raises(ValueError):
        other.start(ck)
