"""Multi-host loading: shard correctness, coordinated checkpoints,
contention, node failure."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CassandraLoader, EpochPlan, KVStore, LoaderConfig,
                        MultiHostConfig, MultiHostRun, tight_loop)
from repro.core.kvstore import make_uuid
from repro.data.datasets import SyntheticImageDataset, ingest


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=24_000, seed=5))
    return store, uuids


def _mh_cfg(n_hosts, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=128, prefetch_buffers=4,
                    io_threads=4, route="high", backend="scylla",
                    n_nodes=4, replication_factor=2, hedge_after=1.0,
                    seed=13, node_egress_bandwidth=1.2e8)
    defaults.update(kw)
    return MultiHostConfig(**defaults)


# ---------------------------------------------------------------------------
# EpochPlan sharding (the strided-slice bug fix)
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 400), num_shards=st.integers(1, 9),
       seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_shards_disjoint_and_cover(n, num_shards, seed):
    """Shards partition the dataset exactly, for any uneven division."""
    rng = np.random.default_rng(7)
    uuids = [make_uuid(rng) for _ in range(n)]
    shards = [EpochPlan(uuids, seed=seed, shard_id=i, num_shards=num_shards)
              for i in range(num_shards)]
    sizes = [len(s) for s in shards]
    assert sum(sizes) == n
    assert max(sizes) - min(sizes) <= 1          # balanced strips
    seen = [str(u) for s in shards for u in s._uuids]
    assert len(set(seen)) == len(seen) == n      # disjoint
    assert set(seen) == {str(u) for u in uuids}  # jointly cover


def test_shard_strip_is_shuffled_not_strided():
    """Contiguous strips of a *shuffled* list (not uuids[i::N])."""
    rng = np.random.default_rng(0)
    uuids = [make_uuid(rng) for _ in range(100)]
    shard0 = EpochPlan(uuids, seed=1, shard_id=0, num_shards=4)._uuids
    assert shard0 != uuids[0::4]                 # not the old strided slice
    assert shard0 != uuids[:25]                  # not an unshuffled strip


def test_epoch_plan_rejects_bad_shard_spec():
    uuids = [make_uuid(np.random.default_rng(0)) for _ in range(8)]
    with pytest.raises(ValueError):
        EpochPlan(uuids, shard_id=4, num_shards=4)
    with pytest.raises(ValueError):
        EpochPlan(uuids, shard_id=-1, num_shards=2)


# ---------------------------------------------------------------------------
# Checkpoint state round-trips (uneven shards, both prefetchers)
# ---------------------------------------------------------------------------

def _loader(store, uuids, **kw):
    defaults = dict(batch_size=32, prefetch_buffers=4, io_threads=4,
                    route="low", backend="scylla", seed=7)
    defaults.update(kw)
    return CassandraLoader(store, uuids, LoaderConfig(**defaults))


@pytest.mark.parametrize("num_shards", [3, 7])
def test_state_epoch_math_uneven_shards(store_uuids, num_shards):
    """consumed*B walks the (epoch, cursor) odometer of THIS shard's size."""
    store, uuids = store_uuids
    small = uuids[:1000]                        # 1000 % 3 and % 7 != 0
    ld = _loader(store, small, shard_id=1, num_shards=num_shards,
                 out_of_order=False)
    n = len(ld.plan)
    assert n == len(small) // num_shards or n == len(small) // num_shards + 1
    ld.start()
    batches = (n // 32) + 2                     # crosses the epoch boundary
    for _ in range(batches):
        ld.next_batch()
    s = ld.state()
    total = batches * 32
    assert s["epoch"] == total // n
    assert s["cursor"] == total % n


@pytest.mark.parametrize("ooo", [False, True])
def test_checkpoint_restore_roundtrip(store_uuids, ooo):
    store, uuids = store_uuids
    small = uuids[:1000]
    ld = _loader(store, small, shard_id=0, num_shards=3, out_of_order=ooo)
    ld.start()
    for _ in range(5):
        ld.next_batch()
    s = ld.state()

    res = _loader(store, small, shard_id=0, num_shards=3, out_of_order=ooo)
    res.start(s["epoch"], s["cursor"])
    assert res.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                           "consumed": 0}
    if not ooo:
        # in-order: resumed delivery equals the original stream continuation
        cont = ld.next_batch().uuids
        assert res.next_batch().uuids == cont
    else:
        # OOO reorders within the in-flight window, but must only deliver
        # samples from the plan at/after the restored cursor (same epoch)
        perm = res.plan.permutation(s["epoch"])
        allowed = {str(u) for u in perm[s["cursor"]:]}
        got = [str(u) for u in res.next_batch().uuids]
        assert set(got) <= allowed
        assert len(set(got)) == len(got)


def test_restart_cursor_past_shard_end_rolls_over(store_uuids):
    """A cursor >= shard length (uneven global batch mapping) must normalize
    instead of silently skipping an epoch's worth of data."""
    store, uuids = store_uuids
    ld = _loader(store, uuids[:1000], shard_id=2, num_shards=3)
    n = len(ld.plan)
    ld.start(epoch=0, cursor=n + 5)
    assert ld.state() == {"epoch": 1, "cursor": 5, "consumed": 0}


def test_empty_shard_raises(store_uuids):
    store, uuids = store_uuids
    # 2 samples over 3 shards: the floor-strip formula leaves shard 0 empty
    ld = _loader(store, uuids[:2], shard_id=0, num_shards=3)
    assert len(ld.plan) == 0
    with pytest.raises(ValueError):
        ld.start()


# ---------------------------------------------------------------------------
# Short-run stats (the negative-skip bug fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_batches", [1, 2])
def test_tight_loop_short_runs(store_uuids, n_batches):
    store, uuids = store_uuids
    ld = _loader(store, uuids[:4000], batch_size=64)
    res = tight_loop(ld, n_batches=n_batches)
    assert res["batches"] == n_batches
    assert res["throughput_Bps"] >= 0.0         # was a negative-index misslice
    assert res["net_bytes"] > 0


# ---------------------------------------------------------------------------
# Multi-host coordinator
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_contention_sublinear_but_fair(store_uuids):
    """Against a pinched shared cluster, aggregate throughput grows
    sub-linearly with clients while per-client rates stay within a bound."""
    store, uuids = store_uuids
    agg = {}
    for n in (1, 4):
        rep = MultiHostRun(store, uuids, _mh_cfg(n)).run(12)
        agg[n] = rep["aggregate_Bps"]
        assert rep["fairness"] > 0.6            # no client starves
    assert agg[4] > agg[1]                      # more clients -> more total
    assert agg[4] < 3.5 * agg[1]                # ...but sub-linear (shared NICs)


@pytest.mark.slow
def test_node_failure_failover_keeps_loaders_alive(store_uuids):
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids, _mh_cfg(4)).start()
    run.run(4)                                  # requests now deep in flight
    run.inject_failure("node1", after=0.0)
    served_at_failure = run.cluster.nodes["node1"].requests_served
    rep = run.run(12)                           # must not raise TimeoutError
    assert rep["cluster_load"]["node1"]["down"] == 1.0
    # the dark node served nothing after the failure fired
    assert run.cluster.nodes["node1"].requests_served == served_at_failure
    assert all(b > 0 for b in rep["per_client_Bps"])


def test_coordinated_checkpoint_consistent_and_resumable(store_uuids):
    store, uuids = store_uuids
    cfg = _mh_cfg(3, node_egress_bandwidth=6.25e9, route="low",
                  hedge_after=None)
    run = MultiHostRun(store, uuids, cfg).start()
    run.run(6)
    ck = run.checkpoint()
    assert ck["rounds"] == 6 and len(ck["shards"]) == 3
    # all shards checkpoint the same consumed count (consistent boundary)
    assert {s["consumed"] for s in ck["shards"]} == {6}

    resumed = MultiHostRun(store, uuids, cfg).start(ck)
    for ld, s in zip(resumed.loaders, ck["shards"]):
        assert ld.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                              "consumed": 0}
    rep = resumed.run(3)
    assert resumed.checkpoint()["rounds"] == 3
    assert all(b > 0 for b in rep["per_client_Bps"])


# ---------------------------------------------------------------------------
# Elastic N -> M resharding + placement policies
# ---------------------------------------------------------------------------

def _fast_cfg(n_hosts, **kw):
    """In-order, low-latency, uncontended: delivery order == plan order, so
    tests can audit exact delivery instead of re-deriving from logs."""
    fast = dict(node_egress_bandwidth=6.25e9, route="low", hedge_after=None,
                out_of_order=False, batch_size=100)
    fast.update(kw)
    return _mh_cfg(n_hosts, **fast)


def _collector(delivered):
    def on_batch(host_id, batch):
        delivered.setdefault(batch.epoch, []).extend(
            str(u) for u in batch.uuids)
    return on_batch


def test_checkpoint_roundtrip_equivalence_same_n(store_uuids):
    """K rounds + checkpoint + restore with the same N delivers exactly the
    same uuid stream (per host, in order) as an uninterrupted run, and the
    per-shard cursors match at every boundary."""
    store, uuids = store_uuids
    small = uuids[:1500]
    cfg = _fast_cfg(3)

    unbroken: dict = {}
    run = MultiHostRun(store, small, cfg).start()
    run.run(3, on_batch=_collector(unbroken))
    ck = run.checkpoint()
    continued: dict = {}
    run.run(4, on_batch=_collector(continued))
    final_states = [{k: s[k] for k in ("epoch", "cursor")}
                    for s in run.checkpoint()["shards"]]

    resumed: dict = {}
    restore = MultiHostRun(store, small, cfg).start(ck)
    restore.run(4, on_batch=_collector(resumed))
    assert resumed == continued               # same multiset AND same order
    assert [{k: s[k] for k in ("epoch", "cursor")}
            for s in restore.checkpoint()["shards"]] == final_states


@pytest.mark.parametrize("old_n,new_n", [(3, 2), (2, 4)])
def test_elastic_restore_exactly_once_per_epoch(store_uuids, old_n, new_n):
    """An N-host checkpoint restored onto M hosts still delivers the
    interrupted epoch's remaining samples exactly once, then continues with
    plain M-host epochs."""
    store, uuids = store_uuids
    small = uuids[:1200]                      # strips: 400x3 or 600x2
    delivered: dict = {}

    run = MultiHostRun(store, small, _fast_cfg(old_n)).start()
    run.run(2, on_batch=_collector(delivered))           # part of epoch 0
    ck = run.checkpoint()

    restore = MultiHostRun(store, small, _fast_cfg(new_n)).start(ck)
    remaining = 1200 - old_n * 2 * 100
    rounds = remaining // (new_n * 100)                  # finish epoch 0...
    restore.run(rounds + 1200 // (new_n * 100),          # ...plus epoch 1
                on_batch=_collector(delivered))
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe         # exactly once each


def test_elastic_restore_composes_mid_transition(store_uuids):
    """4 -> 2 -> 3 hosts, with the second checkpoint taken *inside* the
    first resize's transition epoch: the pending overrides travel in the
    checkpoint, so reshards compose without losing exactly-once."""
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run4 = MultiHostRun(store, small, _fast_cfg(4)).start()
    run4.run(1, on_batch=_collector(delivered))          # 400 of epoch 0
    run2 = MultiHostRun(store, small, _fast_cfg(2)).start(run4.checkpoint())
    run2.run(1, on_batch=_collector(delivered))          # 200 more, mid-reflow
    ck = run2.checkpoint()
    assert any("overrides" in s for s in ck["shards"])   # transition pending

    run3 = MultiHostRun(store, small, _fast_cfg(3)).start(ck)
    run3.run(2 + 4, on_batch=_collector(delivered))      # rest of e0 + all e1
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe


def test_elastic_restore_survives_node_failure_during_resize(store_uuids):
    """A node dying mid-resize must not break the reflowed shards (hedging +
    failover re-route; exactly-once is a plan property, not a routing one)."""
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run = MultiHostRun(store, small, _fast_cfg(4)).start()
    run.run(1, on_batch=_collector(delivered))
    ck = run.checkpoint()

    cfg = _fast_cfg(2, hedge_after=1.0)
    restore = MultiHostRun(store, small, cfg).start(ck)
    restore.inject_failure("node3", after=0.0)
    restore.run(4, on_batch=_collector(delivered))       # 800 more of epoch 0
    assert len(delivered[0]) == len(set(delivered[0])) == 1200
    assert restore.cluster.nodes["node3"].down


@pytest.mark.parametrize("mismatch,legacy",
                         [({"placement": "token_aware"}, False),
                          ({"placement": "token_aware"}, True),
                          ({"seed": 14}, False)])
def test_same_count_restore_with_different_strips_reshards(store_uuids,
                                                           mismatch, legacy):
    """Same host count but different strip-defining metadata (placement
    policy or seed): blindly resuming old cursors on new strips would skip
    and duplicate samples, so these restores must reflow too — including a
    legacy checkpoint with no metadata keys at all, whose missing placement
    means 'contiguous', not 'whatever the restoring run uses' (regression)."""
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run = MultiHostRun(store, small, _fast_cfg(2)).start()
    run.run(2, on_batch=_collector(delivered))           # 400 of epoch 0
    ck = run.checkpoint()
    if legacy:
        ck = {"rounds": ck["rounds"], "num_shards": ck["num_shards"],
              "shards": [{k: s[k] for k in ("epoch", "cursor", "consumed")}
                         for s in ck["shards"]]}

    other = MultiHostRun(store, small, _fast_cfg(2, **mismatch)).start(ck)
    other.run(4 + 6, on_batch=_collector(delivered))     # rest of e0 + all e1
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe


def test_pr1_style_checkpoint_still_restores(store_uuids):
    """Checkpoints predating the elastic/placement fields (no seed/placement/
    overrides keys) restore bit-identically on the same host count."""
    store, uuids = store_uuids
    cfg = _fast_cfg(3)
    run = MultiHostRun(store, uuids[:1500], cfg).start()
    run.run(3)
    ck = run.checkpoint()
    legacy = {"rounds": ck["rounds"], "num_shards": ck["num_shards"],
              "shards": [{k: s[k] for k in ("epoch", "cursor", "consumed")}
                         for s in ck["shards"]]}
    restored = MultiHostRun(store, uuids[:1500], cfg).start(legacy)
    for ld, s in zip(restored.loaders, ck["shards"]):
        assert ld.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                              "consumed": 0}


def test_token_aware_placement_beats_contiguous_locality(store_uuids):
    """On a 4-node rf=2 cluster, token-aware placement + preferred routing
    serves nearly every fetch replica-locally; contiguous sits near the
    combinatorial baseline.  The report carries the stats directly."""
    store, uuids = store_uuids
    reports = {}
    for policy in ("contiguous", "token_aware"):
        rep = MultiHostRun(store, uuids[:4000],
                           _fast_cfg(4, placement=policy)).run(4)
        assert rep["placement"] == policy
        assert sum(rep["per_node_egress_share"].values()) == pytest.approx(1.0)
        assert rep["egress_imbalance"] >= 1.0
        reports[policy] = rep
    assert reports["token_aware"]["replica_local_hit_frac"] > 0.9
    assert (reports["token_aware"]["replica_local_hit_frac"]
            > reports["contiguous"]["replica_local_hit_frac"] + 0.2)


def test_rejects_unknown_placement_policy(store_uuids):
    store, uuids = store_uuids
    with pytest.raises(ValueError):
        MultiHostRun(store, uuids[:100], _mh_cfg(2, placement="random"))


def test_restore_against_different_dataset_rejected(store_uuids):
    """Strips are deterministic functions of the uuid list, so a checkpoint
    restored over a different dataset would silently reflow wrong
    permutations — it must refuse instead (for any target host count)."""
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:1200], _fast_cfg(2)).start()
    run.run(1)
    ck = run.checkpoint()
    assert ck["dataset_size"] == 1200
    for n_hosts in (2, 3):
        with pytest.raises(ValueError):
            MultiHostRun(store, uuids[:1000], _fast_cfg(n_hosts)).start(ck)
