"""Multi-node cluster loading: token-aware routing, replication, hedging."""

import numpy as np
import pytest

from repro.core import (CassandraLoader, Cluster, KVStore, LoaderConfig,
                        VirtualClock, tight_loop)
from repro.core.connection import ConnectionPool
from repro.core.netsim import TIERS
from repro.data.datasets import SyntheticImageDataset, ingest

pytestmark = pytest.mark.slow      # full cluster sims; skip with -m "not slow"


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=20_000, seed=9))
    return store, uuids


def test_multinode_loader_delivers(store_uuids):
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=128, prefetch_buffers=4, io_threads=6,
                       route="med", n_nodes=3, replication_factor=2, seed=3)
    ld = CassandraLoader(store, uuids, cfg)
    res = tight_loop(ld, n_batches=30)
    assert res["throughput_Bps"] > 0.5e9
    # traffic actually spread across the 3 nodes
    per_node = [n.egress.bytes_total for n in ld.cluster.nodes.values()]
    assert all(b > 0 for b in per_node)
    assert max(per_node) < 0.8 * sum(per_node)


def test_token_aware_routing_hits_replicas(store_uuids):
    store, uuids = store_uuids
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=3, rf=2, seed=1)
    pool = ConnectionPool(clock, cluster, TIERS["low"], io_threads=3, seed=2)
    done = []
    for u in uuids[:300]:
        replicas = set(cluster.ring.replicas(u, 2))
        conn = pool._pick_connection(u)
        assert conn._node.name in replicas        # token-aware: replica only
        pool.fetch(u, done.append)
    clock.drain()
    assert len(done) == 300


def test_hedged_requests_first_wins(store_uuids):
    store, uuids = store_uuids
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=3, rf=2, seed=1)
    pool = ConnectionPool(clock, cluster, TIERS["high"], io_threads=3,
                          seed=2, hedge_after=0.05)
    results = []
    for u in uuids[:200]:
        pool.fetch(u, results.append)
    clock.drain()
    # every key answered exactly once despite duplicate backup requests
    assert len(results) == 200
    assert len({str(r.uuid) for r in results}) == 200
    assert pool.requests_sent > 200               # hedges actually fired
    # (whether a hedge *wins* depends on a straggling original — covered
    # statistically by test_hedging_reduces_tail_latency below)


def test_hedging_reduces_tail_latency(store_uuids):
    store, uuids = store_uuids

    def run(hedge):
        clock = VirtualClock()
        cluster = Cluster(clock, store, backend="cassandra", n_nodes=3, rf=2,
                          seed=4)
        pool = ConnectionPool(clock, cluster, TIERS["high"], io_threads=3,
                              seed=5, hedge_after=0.4 if hedge else None)
        lat = []
        for u in uuids[:400]:
            pool.fetch(u, lambda r: lat.append(r.t_done - r.t_issued))
        clock.drain()
        return np.percentile(lat, 99)

    assert run(True) <= run(False) * 1.05   # tail no worse, usually better
