"""Wire codecs: roundtrips, bounded loss, byte-accounting identities."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CassandraLoader, Cluster, ConnectionPool, KVStore,
                        LoaderConfig, VirtualClock, get_codec, tight_loop)
from repro.core.wirefmt import (BYTESHUFFLE, INT8, NONE, _rle_decode,
                                _rle_encode)
from repro.data.datasets import SyntheticImageDataset, SyntheticTokenDataset, ingest


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=6_000, seed=9))
    return store, uuids


# -- roundtrips --------------------------------------------------------------


@pytest.mark.parametrize("codec", ["none", "byteshuffle"])
@pytest.mark.parametrize("payload", [
    b"",
    b"\x00" * 10_000,                            # one giant run (RLE > 255)
    bytes(range(256)) * 40,                      # structured, low-entropy
    np.arange(3000, dtype="<f4").tobytes(),      # float ramp: shuffle shines
    b"xyz",                                      # shorter than the stride
    bytes(np.random.default_rng(3).integers(0, 256, 5001, dtype=np.uint8)),
])
def test_lossless_roundtrip(codec, payload):
    c = get_codec(codec)
    assert c.decode(c.encode(payload)) == payload


def test_byteshuffle_compresses_structured_data():
    ramp = np.arange(50_000, dtype="<u4").tobytes()   # high bytes ~constant
    blob = BYTESHUFFLE.encode(ramp)
    assert len(blob) < 0.6 * len(ramp)
    assert BYTESHUFFLE.decode(blob) == ramp


def test_byteshuffle_stride_sweep_picks_channel_period():
    """Interleaved RGB uint8 frames need stride 3, not the float-stream 4:
    the sweep must find it (a fixed stride-4 shuffle raw-escapes here)."""
    from repro.data.datasets import SyntheticPixelDataset

    ds = SyntheticPixelDataset(n_samples=4, h=64, w=64, c=3, seed=9)
    rng = np.random.default_rng(9)
    raw = ds.make_frame(rng, 1).tobytes()
    blob = BYTESHUFFLE.encode(raw)
    assert BYTESHUFFLE.decode(blob) == raw
    assert len(blob) < 0.5 * len(raw)                 # really compressed
    assert blob[3] >> 1 == 3                          # stride in the header
    ramp = np.arange(10_000, dtype="<u4").tobytes()
    assert BYTESHUFFLE.encode(ramp)[3] >> 1 == 4      # floats still pick 4


def test_byteshuffle_raw_escape_on_incompressible():
    raw = bytes(np.random.default_rng(0).integers(0, 256, 8192,
                                                  dtype=np.uint8))
    blob = BYTESHUFFLE.encode(raw)
    assert len(blob) <= len(raw) + 8              # header only, never blowup
    assert BYTESHUFFLE.decode(blob) == raw


@given(n=st.integers(0, 2000), seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_rle_roundtrip_property(n, seed):
    rng = np.random.default_rng(seed)
    # long runs mixed with noise — exercises the >255-run chunking
    x = np.repeat(rng.integers(0, 4, size=max(n // 100, 1), dtype=np.uint8),
                  rng.integers(1, 700, size=max(n // 100, 1)))[:max(n, 1)]
    out = _rle_decode(_rle_encode(x), x.size)
    np.testing.assert_array_equal(out, x)


def test_int8_bounded_error():
    rng = np.random.default_rng(5)
    x = (rng.standard_normal(10_000) * np.exp(rng.uniform(-4, 4, 10_000))
         ).astype("<f4")
    raw = x.tobytes()
    blob = INT8.encode(raw)
    assert len(blob) < 0.3 * len(raw)
    y = np.frombuffer(INT8.decode(blob), dtype="<f4")
    # per-block bound: |x - y| <= amax_block / 127
    pad = (-x.size) % INT8.BLOCK
    xp = np.pad(x, (0, pad))
    bound = np.repeat(np.abs(xp.reshape(-1, INT8.BLOCK)).max(axis=1),
                      INT8.BLOCK)[:x.size] / 127.0
    assert np.all(np.abs(x - y) <= bound + 1e-7)


def test_int8_raw_escape_paths():
    assert INT8.decode(INT8.encode(b"abc")) == b"abc"          # n % 4 != 0
    nan = np.array([1.0, np.nan], "<f4").tobytes()             # not floats
    assert INT8.decode(INT8.encode(nan)) == nan
    assert INT8.decode(INT8.encode(b"")) == b""


def test_frame_guards_and_registry():
    with pytest.raises(ValueError):
        get_codec("zstd-o-matic")
    assert get_codec(None) is NONE
    assert get_codec(BYTESHUFFLE) is BYTESHUFFLE
    with pytest.raises(ValueError):
        INT8.decode(BYTESHUFFLE.encode(b"hello world!"))       # codec mismatch


def test_encoded_size_model_deterministic():
    for c in (NONE, BYTESHUFFLE, INT8):
        assert c.encoded_size(115_000) == c.encoded_size(115_000)
        assert c.encoded_size(115_000) > 0
    assert NONE.encoded_size(115_000) == 115_000
    assert BYTESHUFFLE.encoded_size(115_000) < 115_000
    assert INT8.encoded_size(115_000) < BYTESHUFFLE.encoded_size(115_000)


# -- billing identities ------------------------------------------------------


def test_lazy_billing_matches_size_model(store_uuids):
    """Lazy rows: the pool bills exactly the codec's size model per sample —
    what SimConnection charged egress/wire/ingress with."""
    store, uuids = store_uuids
    codec = get_codec("byteshuffle")
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=4, route="low",
                       wire_codec="byteshuffle", seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    samples = []
    for _ in range(8):
        samples.extend(ld.next_batch().samples)
    assert ld.pool.bytes_received == sum(codec.encoded_size(s.size)
                                         for s in samples)
    assert ld.pool.payload_bytes_received == sum(s.size for s in samples)
    for s in samples:
        assert s.wire_size == codec.encoded_size(s.size)
        assert s.wire_size < s.size


def test_materialized_billing_matches_real_encode(store_uuids):
    """Materialized rows get *really* encoded: the wire bill is exactly
    ``len(encode(payload))`` per row."""
    store, uuids = store_uuids
    codec = get_codec("byteshuffle")
    cfg = LoaderConfig(batch_size=32, prefetch_buffers=2, route="local",
                       wire_codec="byteshuffle", materialize=True, seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    samples = []
    for _ in range(4):
        samples.extend(ld.next_batch().samples)
    expect = sum(len(codec.encode(store.get_data(s.uuid).materialize()))
                 for s in samples)
    assert ld.pool.bytes_received == expect
    assert all(s.payload == store.get_data(s.uuid).materialize()
               for s in samples)                     # decode is lossless


def test_batch_wire_vs_decoded_nbytes(store_uuids):
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=2, route="low",
                       wire_codec="byteshuffle", seed=7)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    batch = ld.next_batch()
    assert batch.wire_nbytes == sum(s.wire_size for s in batch.samples)
    assert batch.wire_nbytes < batch.nbytes          # codec active
    cfg2 = LoaderConfig(batch_size=64, prefetch_buffers=2, route="low",
                        seed=7)
    ld2 = CassandraLoader(store, uuids, cfg2)
    ld2.start()
    b2 = ld2.next_batch()
    assert b2.wire_nbytes == b2.nbytes               # none: identical


def test_codec_cpu_charged(store_uuids):
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=4, route="low",
                       wire_codec="byteshuffle", seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    tight_loop(ld, 6)
    node_cpu = sum(n.encode_cpu_seconds for n in ld.cluster.nodes.values())
    assert node_cpu > 0
    assert ld.pool.decode_cpu_seconds > 0
    # the load report surfaces the encode burn
    assert sum(r["encode_cpu_s"]
               for r in ld.cluster.load_report().values()) == node_cpu


def test_codec_none_bit_identical_to_default_pool(store_uuids):
    """wire_codec="none" == a pool built with no codec argument at all:
    same batch timeline, same bytes, zero codec CPU."""
    store, uuids = store_uuids

    def run(build_default: bool):
        cfg = LoaderConfig(batch_size=64, prefetch_buffers=4, route="med",
                           flow_control="adaptive", seed=6, n_nodes=2,
                           replication_factor=2, wire_codec="none")
        if build_default:
            clock = VirtualClock()
            cluster = Cluster(clock, store, backend=cfg.backend,
                              n_nodes=cfg.n_nodes,
                              rf=cfg.replication_factor, seed=cfg.seed + 5)
            pool = ConnectionPool(clock, cluster, cfg.route,
                                  io_threads=cfg.io_threads,
                                  conns_per_thread=cfg.conns_per_thread,
                                  seed=cfg.seed + 11)
            ld = CassandraLoader(store, uuids, cfg, clock=clock,
                                 cluster=cluster, pool=pool)
        else:
            ld = CassandraLoader(store, uuids, cfg)
        ld.start()
        for _ in range(10):
            ld.next_batch()
        return ld

    a, b = run(False), run(True)
    assert a.stats.batch_ready_t == b.stats.batch_ready_t
    assert a.pool.bytes_received == b.pool.bytes_received
    assert a.pool.bytes_received == a.pool.payload_bytes_received
    assert a.pool.decode_cpu_seconds == 0.0 == b.pool.decode_cpu_seconds
    assert sum(n.encode_cpu_seconds for n in a.cluster.nodes.values()) == 0.0


def test_flow_snapshot_roundtrips_with_codec(store_uuids):
    """An adaptive run under a codec checkpoints and restores at the same
    measured operating point (satellite: snapshot must survive the codec)."""
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=8, route="med",
                       wire_codec="byteshuffle", flow_control="adaptive",
                       seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    tight_loop(ld, 20)
    snap = ld.flow_snapshot()
    assert snap is not None and snap["budget"] > 0

    ld2 = CassandraLoader(store, uuids, cfg)
    ld2.restore_flow(snap)
    snap2 = ld2.flow_snapshot()
    for key in ("budget", "min_rtt", "rate", "avg_bytes"):
        assert snap2[key] == pytest.approx(snap[key])


def test_token_records_survive_byteshuffle(store_uuids):
    """End to end through the codec: real token payloads decode identically
    after the encode->wire->decode trip."""
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=256, seq_len=64,
                                                seed=2))
    from repro.data.datasets import decode_token_record
    cfg = LoaderConfig(batch_size=32, prefetch_buffers=2, route="low",
                       wire_codec="byteshuffle", materialize=True, seed=3)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    batch = ld.next_batch()
    for s, payload in zip(batch.samples, batch.payloads()):
        toks, label = decode_token_record(payload)
        assert label == s.label
        assert toks.size == 64


# -- controller-driven io scaling --------------------------------------------


def test_io_parallelism_tracks_budget(store_uuids):
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=8, route="local",
                       flow_control="adaptive", io_scaling=True, seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    tight_loop(ld, 15)
    n_conns = len(ld.pool.connections)
    par = ld.flow_controller.io_parallelism(n_conns)
    assert 1 <= par <= n_conns
    # shallow local budget -> far fewer active streams than the full pool
    assert par < n_conns
    assert ld.pool.active_conns_per_node() is not None
    # traffic actually concentrated: the active prefix carries ~everything
    ranks = ld.pool._conn_rank
    m = ld.pool.active_conns_per_node()
    done = [(ranks[c], c.bytes_done) for c in ld.pool.connections]
    total = sum(b for _, b in done)
    active = sum(b for r, b in done if r < max(m, 1))
    assert active > 0.5 * total


def test_io_scaling_off_keeps_full_rotation(store_uuids):
    store, uuids = store_uuids
    cfg = LoaderConfig(batch_size=64, prefetch_buffers=8, route="local",
                       flow_control="adaptive", seed=4)
    ld = CassandraLoader(store, uuids, cfg)
    tight_loop(ld, 6)
    assert ld.pool.active_conns_per_node() is None


def test_io_scaling_throughput_not_much_worse(store_uuids):
    store, uuids = store_uuids

    def run(io_scaling: bool) -> float:
        cfg = LoaderConfig(batch_size=128, prefetch_buffers=8, route="med",
                           flow_control="adaptive", io_scaling=io_scaling,
                           seed=4)
        ld = CassandraLoader(store, uuids, cfg)
        return tight_loop(ld, 25)["throughput_Bps"]

    assert run(True) > 0.7 * run(False)
