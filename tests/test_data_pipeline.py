"""Data pipeline: token codec, DeviceFeed, per-host sharding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CassandraLoader, KVStore, LoaderConfig
from repro.data.datasets import (SyntheticTokenDataset, decode_token_record,
                                 encode_token_record, ingest)
from repro.data.pipeline import DeviceFeed, batch_to_numpy


@given(n=st.integers(1, 300), label=st.integers(-2**31, 2**31 - 1),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_token_record_roundtrip(n, label, seed):
    toks = np.random.default_rng(seed).integers(0, 2**31 - 1, size=n,
                                                dtype=np.int32)
    blob = encode_token_record(toks, label)
    toks2, label2 = decode_token_record(blob)
    assert label2 == label
    np.testing.assert_array_equal(toks, toks2)


def test_token_record_rejects_garbage():
    with pytest.raises(ValueError):
        decode_token_record(b"NOPE" + b"\x00" * 16)


@pytest.fixture(scope="module")
def token_store():
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=512, seq_len=24,
                                                vocab=1000, seed=3))
    return store, uuids


def test_batch_to_numpy_shapes(token_store):
    store, uuids = token_store
    ld = CassandraLoader(store, uuids, LoaderConfig(
        batch_size=8, prefetch_buffers=2, io_threads=2, route="low",
        materialize=True, seed=4)).start()
    batch = ld.next_batch()
    arrs = batch_to_numpy(batch, seq_len=24)
    assert arrs["tokens"].shape == (8, 24)
    assert arrs["loss_mask"].shape == (8, 24)
    assert (arrs["loss_mask"] == 1.0).all()      # full-length sequences
    assert arrs["tokens"].dtype == np.int32


def test_device_feed_yields_device_arrays(token_store):
    store, uuids = token_store
    ld = CassandraLoader(store, uuids, LoaderConfig(
        batch_size=4, prefetch_buffers=2, io_threads=2, route="low",
        materialize=True, seed=5))
    feed = DeviceFeed(ld, seq_len=24)
    dev_batch, meta = next(feed)
    assert isinstance(dev_batch["tokens"], jax.Array)
    assert dev_batch["tokens"].shape == (4, 24)
    # payload contents survive the trip
    from repro.data.datasets import decode_token_record
    toks0, _ = decode_token_record(meta.samples[0].payload)
    np.testing.assert_array_equal(np.asarray(dev_batch["tokens"][0]),
                                  toks0[:24])


def test_per_host_sharding_is_partition(token_store):
    store, uuids = token_store
    seen = []
    for shard in range(4):
        ld = CassandraLoader(store, uuids, LoaderConfig(
            batch_size=4, prefetch_buffers=2, io_threads=2, route="low",
            materialize=True, seed=6, shard_id=shard, num_shards=4))
        seen.extend(str(u) for u in ld.plan._uuids)
    assert len(seen) == len(uuids)
    assert set(seen) == {str(u) for u in uuids}
