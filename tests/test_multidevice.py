"""Multi-device features (pipeline parallelism, compressed DP all-reduce,
small-mesh dry-run cells) — run in subprocesses with 8 forced host devices
so the main pytest process keeps its single-device view.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, timeout: int = 420) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=ENV, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_pipeline_parallel_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline_parallel import (pipeline_forward,
                                                   stack_stage_params)
        S, M = 4, 8                      # stages, microbatches
        mesh = jax.make_mesh((S,), ("stage",))
        L, d = 8, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, d, d)) * 0.2

        def stage_fn(params, x):         # params (L/S, d, d)
            def body(h, wl):
                return jnp.tanh(h @ wl), None
            h, _ = jax.lax.scan(body, x, params)
            return h

        x = jax.random.normal(jax.random.PRNGKey(1), (M, 4, d))
        piped = pipeline_forward(stage_fn, S, M, mesh)
        got = piped(stack_stage_params(w, S), x)

        # sequential reference
        def ref_one(xi):
            h = xi
            for l in range(L):
                h = jnp.tanh(h @ w[l])
            return h
        want = jax.vmap(ref_one)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("PP-OK")
    """)
    assert "PP-OK" in out


def test_compressed_psum_error_feedback_converges():
    """Single-step int8 psum is approximate (mean-scale); error feedback
    must make the CUMULATIVE applied update converge to the true mean."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import compressed_psum_grads
        try:
            from jax import shard_map
        except ImportError:                      # jax 0.4.x spelling
            from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 16))}
        errors = {"w": jnp.zeros((8, 4, 16))}

        f = shard_map(lambda g, e: compressed_psum_grads(g, e, "data"),
                      mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")))
        applied = jnp.zeros((8, 4, 16))
        steps = 12
        for _ in range(steps):
            out, errors = f(grads, errors)
            applied = applied + out["w"]
        mean = grads["w"].mean(axis=0, keepdims=True) * steps
        err = np.abs(np.asarray(applied) - np.asarray(mean)).max()
        scale = np.abs(np.asarray(mean)).max()
        assert err < 0.08 * scale, (err, scale)
        print("EF-OK")
    """)
    assert "EF-OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("qwen3_4b", "train_4k"),          # dense + qk_norm + GQA
    ("grok_1_314b", "prefill_32k"),    # MoE dispatch
    ("hymba_1_5b", "long_500k"),       # hybrid SWA+SSM decode
    ("whisper_tiny", "decode_32k"),    # enc-dec cross-attention cache
])
def test_dryrun_cell_compiles_small_mesh(arch, shape):
    out = _run(f"""
        import jax, dataclasses
        import repro.configs.base as B
        B.SHAPES = {{k: dataclasses.replace(v,
                        seq_len=min(v.seq_len, 256),
                        global_batch=min(v.global_batch, 8))
                    for k, v in B.SHAPES.items()}}
        import repro.launch.dryrun_lib as D
        D.SHAPES = B.SHAPES
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        res = D.run_cell("{arch}", "{shape}", mesh, verbose=False)
        assert res["flops_per_device"] > 0
        assert res["memory"]["temp_bytes"] >= 0
        print("CELL-OK", res["arch"], res["shape"])
    """)
    assert "CELL-OK" in out
