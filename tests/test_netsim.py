"""Unit tests for the network/storage simulator."""

import heapq
import math
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.netsim import (AIMDBandwidth, FifoResource, RateResource,
                               RouteProfile, SCYLLA, CASSANDRA, SimServerNode,
                               TIERS, VirtualClock)


def test_virtual_clock_orders_events():
    clk = VirtualClock()
    seen = []
    clk.schedule(2.0, seen.append, "b")
    clk.schedule(1.0, seen.append, "a")
    clk.schedule(3.0, seen.append, "c")
    clk.drain()
    assert seen == ["a", "b", "c"]
    assert clk.now() == pytest.approx(3.0)


def test_virtual_clock_run_until():
    clk = VirtualClock()
    box = []
    clk.schedule(5.0, box.append, 1)
    assert clk.run_until(lambda: len(box) == 1, timeout=10.0)
    assert clk.now() == pytest.approx(5.0)


def test_fifo_resource_serializes():
    f = FifoResource("x")
    assert f.acquire(0.0, 1.0) == pytest.approx(1.0)
    assert f.acquire(0.5, 1.0) == pytest.approx(2.0)   # queues behind job 1
    assert f.acquire(10.0, 1.0) == pytest.approx(11.0)  # idle gap respected


def test_rate_resource_tracks_bytes():
    r = RateResource("pipe", 100.0)
    t = r.acquire(0.0, 200)
    assert t == pytest.approx(2.0)
    assert r.bytes_total == 200


def test_aimd_decreases_on_loss_and_recovers():
    route = RouteProfile("t", rtt=0.1, conn_capacity=1e8, loss_per_byte=1e-6,
                         loss_spread=1.0)
    bw = AIMDBandwidth(np.random.default_rng(0), route)
    r0 = bw.rate
    # force events: huge transfer => Poisson mean >> 1
    bw.transfer_seconds(10_000_000, now=0.0)
    assert bw.rate < r0
    # loss-free route ramps toward capacity
    route2 = RouteProfile("t2", rtt=0.1, conn_capacity=1e8, loss_per_byte=0.0)
    bw2 = AIMDBandwidth(np.random.default_rng(0), route2)
    assert bw2.rate == pytest.approx(bw2.capacity)


def test_aimd_burst_state_transitions():
    route = RouteProfile("t", rtt=0.1, conn_capacity=1e8, loss_per_byte=1e-9,
                         burst_factor=100.0, burst_on_mean=1.0, burst_off_mean=1.0)
    bw = AIMDBandwidth(np.random.default_rng(3), route)
    states = set()
    for k in range(200):
        bw._advance_state(k * 0.5)
        states.add(bw._congested)
    assert states == {True, False}


def test_cassandra_model_reads_more_disk_than_scylla():
    rng = np.random.default_rng(0)
    sc = SimServerNode("s", SCYLLA, rng)
    ca = SimServerNode("c", np.random.default_rng(0) and CASSANDRA,
                       np.random.default_rng(1))
    sc.serve(0.0, 1_000_000)
    ca.serve(0.0, 1_000_000)
    assert ca.disk_bytes == pytest.approx(2.25e6, rel=0.01)
    assert sc.disk_bytes == 1_000_000


def test_tier_table_is_monotone_in_latency():
    assert TIERS["low"].rtt < TIERS["med"].rtt < TIERS["high"].rtt


def test_multihost_sim_determinism():
    """Two ``MultiHostRun`` sims with the same seed produce byte-identical
    reports — the whole simulation runs on the ``VirtualClock``, so any
    wall-clock leakage (time.time() creeping into scheduling or stats)
    would show up as float drift here."""
    from repro.core import KVStore, MultiHostConfig, MultiHostRun
    from repro.data.datasets import SyntheticImageDataset, ingest

    def go():
        store = KVStore()
        uuids = ingest(store, SyntheticImageDataset(n_samples=3000, seed=3))
        cfg = MultiHostConfig(n_hosts=2, batch_size=64, prefetch_buffers=2,
                              io_threads=2, route="low", n_nodes=4,
                              replication_factor=2, hedge_after=0.5, seed=9,
                              node_egress_bandwidth=2e8,
                              placement="token_aware")
        run = MultiHostRun(store, uuids, cfg)
        rep = run.run(4)
        rep["checkpoint"] = run.checkpoint()
        return rep

    r1, r2 = go(), go()
    assert r1 == r2                    # every float, exactly
    assert repr(r1) == repr(r2)        # and byte-identical serialized


def test_deterministic_replay():
    """Same seed => byte-identical event trace (required for benchmarks)."""

    def run():
        clk = VirtualClock()
        rng = np.random.default_rng(42)
        node = SimServerNode("n", SCYLLA, np.random.default_rng(7))
        from repro.core.netsim import RateResource, SimConnection
        ingress = RateResource("i", 1e9)
        conn = SimConnection(0, clk, node, TIERS["high"], rng, ingress)
        done = []
        for _ in range(50):
            conn.request(115_000, done.append)
        clk.drain()
        return done

    assert run() == run()


# -- event-core ordering property (calendar queue vs reference heap) --------

class _ReferenceClock:
    """The pre-calendar event core: one binary heap of (time, seq) records.

    This is the ordering oracle the calendar-queue ``VirtualClock`` must
    match bit-identically — same ``delay <= 0`` clamp, same tie-break, same
    cancellation semantics (records are skipped at pop time, not removed).
    """

    class _Handle:
        def __init__(self, rec):
            self._rec = rec

        def cancel(self):
            if self._rec is None or self._rec[2] is None:
                return False
            self._rec[2] = None
            self._rec = None
            return True

    def __init__(self):
        self._t = 0.0
        self._seq = 0
        self._heap = []

    def now(self):
        return self._t

    def schedule_cancellable(self, delay, fn, *args):
        t = self._t + delay if delay > 0.0 else self._t
        rec = [t, self._seq, fn, args]
        self._seq += 1
        heapq.heappush(self._heap, rec)
        return self._Handle(rec)

    def drain(self):
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            if fn is None:
                continue
            if t > self._t:
                self._t = t
            fn(*args)


# Delay menu stresses every placement path: 0.0 (same-time tie-break on
# seq), sub-slot values, the exact slot width and its boundary, mid-ring,
# the ring horizon (1.024 s) and beyond it (far-heap spill + jump-to-head).
_DELAYS = (0.0, 0.0, 3e-4, 1e-3, 0.002, 0.0021, 0.0155, 0.25,
           1.023, 1.024, 1.5, 4.2)


def _event_program(clock, seed, n_initial):
    """Randomized interleaved schedule/cancel workload; returns fire log.

    The same (seed, n_initial) drives the same rng draw sequence on both
    clocks *as long as the fire order matches* — any ordering divergence
    desynchronizes the draws and shows up as a log mismatch."""
    rng = random.Random(seed)
    log = []
    pending = []
    counter = iter(range(10 ** 9))

    def add(depth):
        label = next(counter)
        h = clock.schedule_cancellable(rng.choice(_DELAYS), fire, label, depth)
        pending.append(h)

    def fire(label, depth):
        log.append((label, clock.now()))
        if depth < 3 and rng.random() < 0.6:
            for _ in range(rng.randint(1, 2)):
                add(depth + 1)
        if pending and rng.random() < 0.35:
            # may already have fired — cancel must be a safe no-op then
            pending.pop(rng.randrange(len(pending))).cancel()

    for _ in range(n_initial):
        add(0)
    clock.drain()
    return log


@given(seed=st.integers(0, 2 ** 31 - 1), n_initial=st.integers(1, 40))
@settings(max_examples=25, deadline=None)
def test_calendar_queue_matches_reference_heap(seed, n_initial):
    """Pop order under arbitrary interleaved schedule/cancel sequences is
    bit-identical to the reference (time, seq) heap — the invariant every
    committed determinism baseline rests on."""
    real = VirtualClock()
    ref = _ReferenceClock()
    log_real = _event_program(real, seed, n_initial)
    log_ref = _event_program(ref, seed, n_initial)
    assert log_real == log_ref
    assert real.now() == ref.now()
    assert real.events_processed == len(log_real)


def test_event_handle_cancel_semantics():
    clk = VirtualClock()
    fired = []
    h1 = clk.schedule_cancellable(1.0, fired.append, "a")
    h2 = clk.schedule_cancellable(2.0, fired.append, "b")
    assert h1.cancel() is True          # this call killed it
    assert h1.cancel() is False         # double-cancel is a no-op
    clk.drain()
    assert fired == ["b"]
    assert h2.cancel() is False         # already fired
    assert h2.cancelled


def test_cancelled_inf_timer_never_fires():
    clk = VirtualClock()
    fired = []
    h = clk.schedule_cancellable(math.inf, fired.append, "never")
    clk.schedule(1.0, fired.append, "a")
    assert h.cancel()
    clk.drain()
    assert fired == ["a"]
    assert clk.now() == pytest.approx(1.0)


def test_events_processed_counts_fired_only():
    clk = VirtualClock()
    for i in range(5):
        clk.schedule(0.001 * i, lambda: None)
    h = clk.schedule_cancellable(0.5, lambda: None)
    h.cancel()
    clk.drain()
    assert clk.events_processed == 5
