"""Unit tests for the network/storage simulator."""

import numpy as np
import pytest

from repro.core.netsim import (AIMDBandwidth, FifoResource, RateResource,
                               RouteProfile, SCYLLA, CASSANDRA, SimServerNode,
                               TIERS, VirtualClock)


def test_virtual_clock_orders_events():
    clk = VirtualClock()
    seen = []
    clk.schedule(2.0, seen.append, "b")
    clk.schedule(1.0, seen.append, "a")
    clk.schedule(3.0, seen.append, "c")
    clk.drain()
    assert seen == ["a", "b", "c"]
    assert clk.now() == pytest.approx(3.0)


def test_virtual_clock_run_until():
    clk = VirtualClock()
    box = []
    clk.schedule(5.0, box.append, 1)
    assert clk.run_until(lambda: len(box) == 1, timeout=10.0)
    assert clk.now() == pytest.approx(5.0)


def test_fifo_resource_serializes():
    f = FifoResource("x")
    assert f.acquire(0.0, 1.0) == pytest.approx(1.0)
    assert f.acquire(0.5, 1.0) == pytest.approx(2.0)   # queues behind job 1
    assert f.acquire(10.0, 1.0) == pytest.approx(11.0)  # idle gap respected


def test_rate_resource_tracks_bytes():
    r = RateResource("pipe", 100.0)
    t = r.acquire(0.0, 200)
    assert t == pytest.approx(2.0)
    assert r.bytes_total == 200


def test_aimd_decreases_on_loss_and_recovers():
    route = RouteProfile("t", rtt=0.1, conn_capacity=1e8, loss_per_byte=1e-6,
                         loss_spread=1.0)
    bw = AIMDBandwidth(np.random.default_rng(0), route)
    r0 = bw.rate
    # force events: huge transfer => Poisson mean >> 1
    bw.transfer_seconds(10_000_000, now=0.0)
    assert bw.rate < r0
    # loss-free route ramps toward capacity
    route2 = RouteProfile("t2", rtt=0.1, conn_capacity=1e8, loss_per_byte=0.0)
    bw2 = AIMDBandwidth(np.random.default_rng(0), route2)
    assert bw2.rate == pytest.approx(bw2.capacity)


def test_aimd_burst_state_transitions():
    route = RouteProfile("t", rtt=0.1, conn_capacity=1e8, loss_per_byte=1e-9,
                         burst_factor=100.0, burst_on_mean=1.0, burst_off_mean=1.0)
    bw = AIMDBandwidth(np.random.default_rng(3), route)
    states = set()
    for k in range(200):
        bw._advance_state(k * 0.5)
        states.add(bw._congested)
    assert states == {True, False}


def test_cassandra_model_reads_more_disk_than_scylla():
    rng = np.random.default_rng(0)
    sc = SimServerNode("s", SCYLLA, rng)
    ca = SimServerNode("c", np.random.default_rng(0) and CASSANDRA,
                       np.random.default_rng(1))
    sc.serve(0.0, 1_000_000)
    ca.serve(0.0, 1_000_000)
    assert ca.disk_bytes == pytest.approx(2.25e6, rel=0.01)
    assert sc.disk_bytes == 1_000_000


def test_tier_table_is_monotone_in_latency():
    assert TIERS["low"].rtt < TIERS["med"].rtt < TIERS["high"].rtt


def test_multihost_sim_determinism():
    """Two ``MultiHostRun`` sims with the same seed produce byte-identical
    reports — the whole simulation runs on the ``VirtualClock``, so any
    wall-clock leakage (time.time() creeping into scheduling or stats)
    would show up as float drift here."""
    from repro.core import KVStore, MultiHostConfig, MultiHostRun
    from repro.data.datasets import SyntheticImageDataset, ingest

    def go():
        store = KVStore()
        uuids = ingest(store, SyntheticImageDataset(n_samples=3000, seed=3))
        cfg = MultiHostConfig(n_hosts=2, batch_size=64, prefetch_buffers=2,
                              io_threads=2, route="low", n_nodes=4,
                              replication_factor=2, hedge_after=0.5, seed=9,
                              node_egress_bandwidth=2e8,
                              placement="token_aware")
        run = MultiHostRun(store, uuids, cfg)
        rep = run.run(4)
        rep["checkpoint"] = run.checkpoint()
        return rep

    r1, r2 = go(), go()
    assert r1 == r2                    # every float, exactly
    assert repr(r1) == repr(r2)        # and byte-identical serialized


def test_deterministic_replay():
    """Same seed => byte-identical event trace (required for benchmarks)."""

    def run():
        clk = VirtualClock()
        rng = np.random.default_rng(42)
        node = SimServerNode("n", SCYLLA, np.random.default_rng(7))
        from repro.core.netsim import RateResource, SimConnection
        ingress = RateResource("i", 1e9)
        conn = SimConnection(0, clk, node, TIERS["high"], rng, ingress)
        done = []
        for _ in range(50):
            conn.request(115_000, done.append)
        clk.drain()
        return done

    assert run() == run()
