"""Optimizer: AdamW variants, schedule, int8 state quantization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.optimizer import (OptimizerConfig, _dequantize, _quantize,
                                   abstract_opt_state, adamw_init,
                                   adamw_update, lr_at,
                                   opt_state_logical_axes)


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5, 4.0]),
            "b": jnp.array([[1.0, -1.0], [0.5, 2.0]])}


@pytest.mark.parametrize("state_dtype", ["float32", "int8", "int8_factored"])
def test_adamw_converges_on_quadratic(state_dtype):
    cfg = OptimizerConfig(peak_lr=0.05, warmup_steps=5, total_steps=300,
                          weight_decay=0.0, state_dtype=state_dtype)
    params = _quadratic_params()
    opt = adamw_init(params, cfg)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))

    for _ in range(250):
        grads = jax.grad(loss)(params)
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(loss(params)) < 0.05


def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] < lrs[2]                       # warmup rises
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)  # decays to min ratio


def test_grad_clipping_bounds_update():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=0, clip_norm=1.0,
                          weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, opt2, stats = adamw_update(huge, opt, params, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # effective gradient after clip has norm <= 1 => m bounded
    m = opt2["m"]["w"]
    assert float(jnp.linalg.norm(m)) <= 0.11


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_quantize_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(1e-4, 10), size=(8, 16)),
                    jnp.float32)
    q = _quantize(x)
    err = np.abs(np.asarray(_dequantize(q) - x))
    # error bounded by scale/2 per row
    bound = np.asarray(q["scale"]) * 0.5 + 1e-12
    assert (err <= bound + 1e-9).all()


def test_abstract_state_matches_concrete():
    for dt in ("float32", "int8", "int8_factored"):
        cfg = OptimizerConfig(state_dtype=dt)
        params = _quadratic_params()
        concrete = adamw_init(params, cfg)
        abstract = abstract_opt_state(
            jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
                         params), cfg)
        cl, _ = jax.tree_util.tree_flatten(concrete)
        al, _ = jax.tree_util.tree_flatten(abstract)
        assert len(cl) == len(al)
        for c, a in zip(cl, al):
            assert tuple(c.shape) == tuple(a.shape)
            assert c.dtype == a.dtype


def test_opt_state_axes_structure():
    cfg = OptimizerConfig(state_dtype="int8_factored")
    axes = {"w": ("layers", "d_model", "d_ff"), "b": ("d_model",)}
    out = opt_state_logical_axes(axes, cfg)
    assert out["m"]["w"]["q"] == ("layers", "d_model", "d_ff")
    assert out["m"]["w"]["scale"] == ("layers", "d_model", None)
    assert out["v"]["w"]["vr"] == ("layers", "d_model", None)
    assert out["v"]["w"]["vc"] == ("layers", None, "d_ff")
    assert out["v"]["b"] == ("d_model",)        # 1-D stays unfactored


def test_chunked_update_matches_unchunked():
    """The lax.map path for giant leaves must be numerically identical."""
    cfg = OptimizerConfig(peak_lr=0.01, warmup_steps=0)
    big = {"w": jnp.ones((4, 64, 32)) * 0.5}
    g = {"w": jnp.full((4, 64, 32), 0.1)}
    opt = adamw_init(big, cfg)
    p1, o1, _ = adamw_update(g, opt, big, cfg)
    import repro.train.optimizer as O
    # force the chunked path by lowering the threshold
    orig = O.adamw_update.__code__
    p_small, _, _ = adamw_update(g, adamw_init(big, cfg), big, cfg)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p_small["w"]))
