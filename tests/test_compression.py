"""Gradient compression: int8 + error feedback properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.train.compression import (compress_leaf, dequantize_int8,
                                     init_error_feedback, quantize_int8)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3.0
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale).reshape(x.shape) - x)
    assert float((err <= scale * 0.5 + 1e-9).all())


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_error_feedback_accumulates_lost_mass(seed):
    """Sum of (compressed + next-step error) equals the true gradient."""
    g = jnp.asarray(np.random.default_rng(seed).normal(size=(8, 32)),
                    jnp.float32)
    err = jnp.zeros_like(g)
    comp, new_err = compress_leaf(g, err)
    np.testing.assert_allclose(np.asarray(comp + new_err), np.asarray(g),
                               rtol=1e-5, atol=1e-5)


def test_error_feedback_contracts_over_steps():
    """Repeated EF compression of a constant gradient: the *cumulative*
    applied update converges to the true cumulative gradient."""
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 128)) * 0.37
    err = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for step in range(20):
        comp, err = compress_leaf(g, err)
        applied = applied + comp
    target = g * 20
    rel = float(jnp.abs(applied - target).max() / jnp.abs(target).max())
    assert rel < 0.02


def test_wire_bytes_are_quarter_of_f32():
    x = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    q, scale = quantize_int8(x)
    wire = q.nbytes + scale.nbytes
    assert wire < x.nbytes / 3        # ~4x compression (+ scale overhead)


def test_init_error_feedback_structure():
    params = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.zeros(5)}}
    ef = init_error_feedback(params)
    assert jax.tree.structure(ef) == jax.tree.structure(params)
    assert all(float(jnp.abs(l).max()) == 0 for l in jax.tree.leaves(ef))
