"""Time-varying routes and the declarative scenario matrix.

Covers the schedule sampler (``netsim.RouteSchedule``/``RouteProfile``),
the flow controller's re-convergence machinery (min-RTT anchor, dead-band
ratchet, regime shifts, load-aware backoff), replica demotion consistency,
and the ``core/scenarios.py`` declarative layer the benchmark matrix runs.
"""

import json
import math
import uuid as _uuid

import pytest

from repro.core import (CassandraLoader, Cluster, ConnectionPool,
                        FlowControlConfig, FlowController, KVStore,
                        LoaderConfig, OracleDepthController, Scenario,
                        SCENARIOS, matrix, run_cell)
from repro.core.netsim import TIERS, RouteProfile, RouteSchedule, VirtualClock
from repro.core.replication import ReplicaCache
from repro.data.datasets import SyntheticImageDataset, ingest

from dataclasses import replace


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=12_000, seed=11))
    return store, uuids


# ---------------------------------------------------------------------------
# Schedule sampling (netsim)
# ---------------------------------------------------------------------------

def test_schedule_step_ramp_sinusoid_values():
    step = RouteSchedule("latency", "step", factor=4.0, at=2.0)
    assert step.multiplier(1.9) == 1.0
    assert step.multiplier(2.0) == 4.0
    assert step.multiplier(100.0) == 4.0        # until defaults to forever

    ramp = RouteSchedule("latency", "ramp", factor=9.0, at=2.0, until=4.0)
    assert ramp.multiplier(2.0) == 1.0
    assert ramp.multiplier(3.0) == pytest.approx(5.0)   # halfway
    assert ramp.multiplier(4.0) == 9.0
    assert ramp.multiplier(50.0) == 9.0                 # holds after

    sine = RouteSchedule("bandwidth", "sinusoid", amplitude=0.5, period=4.0)
    vals = [sine.multiplier(t) for t in (0.0, 1.0, 2.0, 3.0, 4.0)]
    assert vals[0] == pytest.approx(1.0)
    assert max(vals) == pytest.approx(1.5)
    assert min(vals) == pytest.approx(0.5)
    assert sine.multiplier(6.0) == pytest.approx(sine.multiplier(2.0))


def test_schedules_compose_and_random_walk_is_clamped_and_deterministic():
    prof = RouteProfile(
        "combo", rtt=0.1, conn_capacity=1e8, loss_per_byte=0.0,
        schedules=(RouteSchedule("latency", "step", factor=3.0, at=1.0),
                   RouteSchedule("latency", "step", factor=2.0, at=2.0)))
    assert prof.latency_multiplier(0.5) == 1.0
    assert prof.latency_multiplier(1.5) == 3.0
    assert prof.latency_multiplier(2.5) == 6.0          # multiplicative

    rw = RouteSchedule("bandwidth", "random_walk", sigma=1.5, interval=0.25,
                       seed=3)
    series = [rw.multiplier(t * 0.25) for t in range(200)]
    assert series == [rw.multiplier(t * 0.25) for t in range(200)]  # pure fn
    assert all(RouteSchedule.MIN_MULT <= m <= RouteSchedule.MAX_MULT
               for m in series)
    assert len(set(series)) > 10                        # actually wanders


def test_outage_windows():
    prof = RouteProfile("flaky", rtt=0.01, conn_capacity=1e8,
                        loss_per_byte=0.0,
                        outages=((2.0, 0.5), (5.0, 1.0)))
    assert not prof.is_static
    for t, down in ((1.99, False), (2.0, True), (2.49, True), (2.5, False),
                    (5.5, True), (6.0, False)):
        assert prof.down_at(t) is down


def test_neutral_schedule_is_bit_identical_to_static(store_uuids):
    """A schedule whose multiplier is identically 1.0 must not perturb a
    single event time: the dynamic sampling path multiplies the same
    floats by 1.0, so the virtual clocks agree exactly."""
    store, uuids = store_uuids

    def end_time(route):
        cfg = LoaderConfig(batch_size=64, prefetch_buffers=4, io_threads=2,
                           route=route, seed=5)
        ld = CassandraLoader(store, uuids[:4000], cfg)
        ld.start()
        for _ in range(20):
            ld.next_batch(timeout=1000.0)
        return ld.clock.now()

    static = TIERS["med"]
    neutral = replace(static, schedules=(
        RouteSchedule("latency", "step", factor=1.0, at=0.0),
        RouteSchedule("bandwidth", "step", factor=1.0, at=0.0)))
    assert not neutral.is_static
    assert end_time(neutral) == end_time(static)


# ---------------------------------------------------------------------------
# FlowController re-convergence (unit level, stub clock)
# ---------------------------------------------------------------------------

class _StubClock:
    def __init__(self):
        self.t = 0.0

    def now(self):
        return self.t


def _controller(**kw):
    cfg = FlowControlConfig(rtt_window=4.0, regime_buckets=1,
                            probe_rtt_interval=1e9, **kw)
    clock = _StubClock()
    return FlowController(cfg, batch_size=64, clock=clock), clock


def _feed(ctl, clock, rtt, duration, dt=0.1, nbytes=100_000):
    end = clock.t + duration
    while clock.t < end:
        clock.t += dt
        ctl.on_complete(clock.t - rtt, clock.t, nbytes)


def test_min_rtt_anchor_immune_to_queue_drift():
    """Samples inflated by less than the budget gain are self-queueing by
    definition; the windowed filter alone would let the 0.10 s floor expire
    and re-anchor at the queued 0.15 s, feeding the queue back into the BDP
    estimate.  The anchor must hold."""
    ctl, clock = _controller()
    _feed(ctl, clock, rtt=0.10, duration=2.0)
    _feed(ctl, clock, rtt=0.15, duration=20.0)   # 5x the rtt_window
    assert ctl.min_rtt() == pytest.approx(0.10)
    assert ctl.regime_shifts == 0


def test_dead_band_ratchet_tracks_slow_creep():
    """A bucket floor above gain x anchor cannot be our own queue — the
    route moved, but not regime_factor-far.  The anchor must ratchet up
    (to at least done_min / gain) without a re-slow-start, or the budget
    would spiral down on slow ramps."""
    ctl, clock = _controller()
    _feed(ctl, clock, rtt=0.10, duration=2.0)
    _feed(ctl, clock, rtt=0.25, duration=10.0)   # 2.5x: gain < 2.5 < 3.0
    gain = ctl.cfg.gain
    assert 0.25 / gain <= ctl.min_rtt() <= 0.25 + 1e-9
    assert ctl.regime_shifts == 0                # no full shift declared


def test_regime_shift_reanchors_and_reslowstarts():
    ctl, clock = _controller()
    _feed(ctl, clock, rtt=0.10, duration=2.0)
    ctl._slow_start = False
    _feed(ctl, clock, rtt=0.50, duration=3.0)    # 5x > regime_factor 3.0
    assert ctl.regime_shifts == 1
    assert ctl.min_rtt() == pytest.approx(0.50)
    assert ctl._slow_start                        # re-probing the new BDP


def test_load_aware_backoff_ignores_self_serialization():
    """Constant-RTT operation — however slow — explains itself via
    budget/delivery_rate; only RTTs far beyond propagation + own-load
    serialization may back the budget off."""
    ctl, clock = _controller()
    _feed(ctl, clock, rtt=0.30, duration=8.0, dt=0.01)
    assert ctl.backoffs == 0
    # now genuine congestion: RTT 30x with the same delivery cadence
    _feed(ctl, clock, rtt=9.0, duration=2.0, dt=0.01)
    assert ctl.backoffs >= 1


# ---------------------------------------------------------------------------
# Re-convergence, end to end (loader on a scheduled route)
# ---------------------------------------------------------------------------

def _adaptive_run(store, uuids, route, n_batches, B=64):
    flow = FlowControlConfig(rtt_window=4.0, regime_buckets=1,
                             probe_rtt_interval=6.0, ceiling_batches=64)
    cfg = LoaderConfig(batch_size=B, io_threads=2, route=route, seed=7,
                       flow_control="adaptive", flow=flow)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    for _ in range(n_batches):
        ld.next_batch(timeout=3000.0)
    return ld


def test_controller_reconverges_after_latency_step(store_uuids):
    """After a x8 latency step the controller must declare a regime shift,
    re-anchor its min-RTT to the new propagation delay, and grow the
    budget toward the multiplied BDP instead of staying pinned."""
    store, uuids = store_uuids
    r1 = 0.02 * 8
    route = RouteProfile(
        "step8", rtt=0.02, conn_capacity=8e7, loss_per_byte=0.0,
        schedules=(RouteSchedule("latency", "step", factor=8.0, at=1.0),))
    ld = _adaptive_run(store, uuids[:12_000], route, n_batches=90)
    ctl = ld.flow_controller
    assert ctl.regime_shifts >= 1
    assert r1 * 0.9 <= ctl.min_rtt() <= r1 * 2.0
    # the budget rebuilt: well above one batch, tracking the new BDP
    assert ctl.depth(64) >= 3


def test_controller_tracks_latency_ramp_without_collapse(store_uuids):
    """A slow x2.5 ramp never crosses the regime factor; the dead-band
    ratchet alone must keep the budget alive and the pipe full."""
    store, uuids = store_uuids
    route = RouteProfile(
        "creep", rtt=0.03, conn_capacity=8e7, loss_per_byte=0.0,
        schedules=(RouteSchedule("latency", "ramp", factor=2.5, at=1.0,
                                 until=3.0),))
    ld = _adaptive_run(store, uuids[:12_000], route, n_batches=80)
    ctl = ld.flow_controller
    assert ctl.min_rtt() > 0.03                  # anchor ratcheted up
    assert ctl.depth(64) >= 2                    # no spiral to the floor


# ---------------------------------------------------------------------------
# Replica demotion: cold entries go, stale reads stay impossible
# ---------------------------------------------------------------------------

def test_demotion_drops_cold_never_serves_stale():
    cache = ReplicaCache(capacity=8)
    keys = [_uuid.uuid4() for _ in range(4)]
    for k in keys:
        tok = cache.begin_promotion(k, "edge", version=1, now=0.0)
        cache.commit_promotion(k, tok)
    assert all(cache.serving_cluster(k, 1, now=1.0) == "edge" for k in keys)

    # hotset rotates away from keys[2:]; they go cold past demote_after
    hot = set(keys[:2])
    n = cache.demote_cold(now=3.0, is_hot=lambda k: k in hot,
                          demote_after=1.5)
    assert n == 2 and cache.demotions == 2
    for k in keys[2:]:
        assert cache.get(k) is None
        assert cache.serving_cluster(k, 1, now=3.0) is None
    # survivors still serve...
    assert cache.serving_cluster(keys[0], 1, now=3.0) == "edge"
    # ...but never at a stale version, demoted or not
    assert cache.serving_cluster(keys[0], 2, now=3.0) is None
    assert cache.stale_blocked == 1 and cache.get(keys[0]) is None


def test_demotion_over_rotating_hotsets_serves_only_live_current():
    """Property over three hotset rotations: every successful serve is for
    a key that is currently promoted and at the current version."""
    cache = ReplicaCache(capacity=16)
    keys = [_uuid.uuid4() for _ in range(12)]
    version, now = 1, 0.0
    for rotation in range(3):
        hot = set(keys[rotation * 4:(rotation + 1) * 4])
        for k in hot:
            tok = cache.begin_promotion(k, "edge", version, now)
            if tok is not None:
                cache.commit_promotion(k, tok)
        now += 2.0
        cache.demote_cold(now, is_hot=lambda k: k in hot, demote_after=1.0)
        for k in keys:
            got = cache.serving_cluster(k, version, now)
            if got is not None:
                e = cache.get(k)
                assert e is not None and e.live and e.version == version
    assert cache.demotions >= 4                  # rotations actually demoted


# ---------------------------------------------------------------------------
# Per-route admission (satellite: prefetcher consults the pool's budget)
# ---------------------------------------------------------------------------

def test_pool_admit_tracks_controller_budget(store_uuids):
    store, uuids = store_uuids
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, rf=1,
                      seed=3)
    pool = ConnectionPool(clock, cluster, TIERS["med"], io_threads=2, seed=3)
    assert pool.admit(uuids[0])                  # static: always admissible
    ctl = pool.attach_flow_control(FlowControlConfig(), batch_size=64)
    budget = ctl.budget()
    assert budget >= 64
    for u in uuids[:budget]:                     # fill to the budget...
        pool.fetch(u, lambda res: None)
    assert not pool.admit(uuids[budget])         # ...and admission closes
    clock.run_until(lambda: pool.inflight == 0, timeout=60.0)
    assert pool.admit(uuids[budget])             # drained: open again


# ---------------------------------------------------------------------------
# Declarative scenarios + oracle
# ---------------------------------------------------------------------------

def test_scenarios_roundtrip_through_json():
    for sc in SCENARIOS.values():
        back = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert back == sc


def test_registry_shapes():
    assert set(s.name for s in matrix(quick=True)) <= set(SCENARIOS)
    quick = {s.name: s for s in matrix(quick=True)}
    full = {s.name: s for s in matrix(quick=False)}
    assert "rwalk" in full and "rwalk" not in quick
    for name, sc in quick.items():
        assert full[name].n_batches == 2 * sc.n_batches
    assert not SCENARIOS["steady"].dynamic
    assert all(SCENARIOS[n].dynamic for n in SCENARIOS if n != "steady")


def test_oracle_depth_follows_schedule_and_outages():
    clock = _StubClock()
    route = RouteProfile(
        "orc", rtt=0.15, conn_capacity=30e6, loss_per_byte=0.0,
        schedules=(RouteSchedule("latency", "step", factor=16.0, at=5.0),),
        outages=((20.0, 1.0),))
    oc = OracleDepthController(clock, route, n_conns=8,
                               sample_bytes=115_000, batch_size=128)
    clock.t = 1.0
    before = oc.depth()
    clock.t = 6.0
    after = oc.depth()
    assert after > before                        # BDP multiplied with RTT
    assert after >= 8 * before * 0.5             # roughly tracks the x16
    clock.t = 20.5
    assert oc.depth() == 1                       # down link: nothing to buffer
    clock.t = 22.0
    assert oc.depth() == after


def test_run_cell_modes_smoke(store_uuids):
    store, uuids = store_uuids
    sc = Scenario("tiny", rtt=0.01, n_batches=4, batch_size=32,
                  io_threads=2,
                  schedules=(RouteSchedule("latency", "step", factor=2.0,
                                           at=0.5),))
    out = {m: run_cell(store, uuids[:2000], sc, m)
           for m in ("static-2", "adaptive", "oracle")}
    for m, r in out.items():
        assert r["MBps"] > 0.0 and r["t_end_s"] > 0.0, m
    assert "steady_depth" in out["adaptive"]
    with pytest.raises(ValueError, match="unknown mode"):
        run_cell(store, uuids[:2000], sc, "psychic")
