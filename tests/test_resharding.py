"""Property-based invariants for elastic N->M strip reflow and placement.

The loader-landscape lesson (Ofeidis et al.): loaders silently diverge under
restart.  These properties pin the contract down: for arbitrary dataset
size, seed, host counts and checkpoint position, the reflowed strips are
pairwise disjoint, balanced, and — together with what was delivered before
the checkpoint — cover every uuid exactly once per epoch.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cluster import TokenRing
from repro.core.federation import FederatedRing, federated_preferred_subsets
from repro.core.kvstore import make_uuid
from repro.core.placement import (global_order, preferred_node_subsets,
                                  replica_local_fraction, split_contiguous,
                                  split_strips)
from repro.core.prefetcher import EpochPlan, compute_reflow

N_NODES = 4
RF = 2


def _uuids(n, seed=7):
    rng = np.random.default_rng(seed)
    return [make_uuid(rng) for _ in range(n)]


def _ring(seed=5):
    return TokenRing([f"node{i}" for i in range(N_NODES)], seed=seed)


def _reshard(uuids, seed, old_n, new_n, consumed_each, token_aware):
    """The same reflow pipeline MultiHostRun._start_resharded runs: old
    plans at a lockstep boundary -> per-epoch tails -> M new plans with
    transition overrides.  Returns (old_plans, positions, new_plans,
    start_epoch, last_transition_epoch)."""
    old_plans = [EpochPlan(uuids, seed=seed, shard_id=i, num_shards=old_n)
                 for i in range(old_n)]
    positions = [p.advance(0, 0, consumed_each) for p in old_plans]
    start_epoch, tails = compute_reflow(old_plans, positions)
    if token_aware:
        ring, pref = _ring(), preferred_node_subsets(
            [f"node{i}" for i in range(N_NODES)], new_n)
        split = lambda s: split_strips(s, new_n, "token_aware", ring=ring,
                                       rf=RF, preferred=pref)
        steady = split(global_order(uuids, seed, new_n))
        new_plans = [EpochPlan.from_samples(steady[j], seed, j, new_n)
                     for j in range(new_n)]
    else:
        split = lambda s: split_strips(s, new_n)
        new_plans = [EpochPlan(uuids, seed=seed, shard_id=j, num_shards=new_n)
                     for j in range(new_n)]
    for epoch, tail in tails.items():
        for plan, strip in zip(new_plans, split(tail)):
            plan.install_overrides({epoch: strip})
    return old_plans, positions, new_plans, start_epoch, max(tails)


def _delivered_before(plan, position, epoch):
    """What one old shard already delivered for ``epoch`` pre-checkpoint."""
    e_i, c_i = position
    if epoch < e_i:
        return plan.permutation(epoch)       # epoch fully delivered
    if epoch == e_i:
        return plan.permutation(epoch)[:c_i]
    return []


@given(n=st.integers(1, 90), old_n=st.integers(1, 8), new_n=st.integers(1, 8),
       seed=st.integers(0, 99), consumed=st.integers(0, 150),
       token_aware=st.booleans())
@settings(max_examples=40, deadline=None)
def test_reflow_exactly_once_per_epoch(n, old_n, new_n, seed, consumed,
                                       token_aware):
    """Pre-checkpoint deliveries + post-reshard strips == every uuid exactly
    once, for every epoch touched by the transition and the first steady
    epoch after it; strips are pairwise disjoint and balanced."""
    old_n, new_n = min(old_n, n), min(new_n, n)   # no empty steady shards
    uuids = _uuids(n)
    universe = {str(u) for u in uuids}
    old_plans, positions, new_plans, e_start, e_last = _reshard(
        uuids, seed, old_n, new_n, consumed, token_aware)

    for epoch in range(e_start, e_last + 2):      # transition + one steady
        pre = [u for plan, pos in zip(old_plans, positions)
               for u in _delivered_before(plan, pos, epoch)]
        post_strips = [plan.permutation(epoch) for plan in new_plans]
        post = [u for strip in post_strips for u in strip]
        flat = [str(u) for u in pre + post]
        assert len(flat) == n                     # exactly once...
        assert set(flat) == universe              # ...and jointly covering
        # pairwise disjoint post strips (per epoch)
        post_flat = [str(u) for u in post]
        assert len(post_flat) == len(set(post_flat))
        # balanced reflow strips: remainders spread, sizes differ by <= 1
        sizes = sorted(len(s) for s in post_strips)
        assert sizes[-1] - sizes[0] <= 1


def test_reflow_composes_across_multi_epoch_transition():
    """Resharding twice, with the second checkpoint taken before the fastest
    shard's transition epoch: pending overrides *beyond* every shard's
    current epoch must extend the reflow window, or the partially-delivered
    later epoch would be re-delivered in full (regression: duplicates)."""
    uuids = _uuids(7)                       # 2 hosts -> strips of 3 and 4
    universe = {str(u) for u in uuids}
    old = [EpochPlan(uuids, seed=0, shard_id=i, num_shards=2)
           for i in range(2)]
    positions = [p.advance(0, 0, 14) for p in old]
    assert sorted(e for e, _ in positions) == [3, 4]    # epochs drifted apart

    e_mid, tails = compute_reflow(old, positions)
    mid = [EpochPlan(uuids, seed=0, shard_id=j, num_shards=2)
           for j in range(2)]
    for e, tail in tails.items():
        for plan, strip in zip(mid, split_strips(tail, 2)):
            plan.install_overrides({e: strip})
    pos_mid = [(e_mid, 0)] * 2              # immediate re-reshard: positions
    # sit at epoch 3, but epoch-4 overrides are still pending
    e2, tails2 = compute_reflow(mid, pos_mid)
    assert max(tails2) == 4                 # window reaches the pending epoch
    final = [EpochPlan(uuids, seed=0, shard_id=j, num_shards=3)
             for j in range(3)]
    for e, tail in tails2.items():
        for plan, strip in zip(final, split_strips(tail, 3)):
            plan.install_overrides({e: strip})

    for epoch in range(e2, max(tails2) + 2):
        pre1 = [u for p, pos in zip(old, positions)
                for u in _delivered_before(p, pos, epoch)]
        pre2 = [u for p, pos in zip(mid, pos_mid)
                for u in _delivered_before(p, pos, epoch)]
        post = [u for p in final for u in p.permutation(epoch)]
        flat = [str(u) for u in pre1 + pre2 + post]
        assert len(flat) == 7
        assert set(flat) == universe


def _fed_ring(seed=5):
    """A 2-cluster federation keyspace (local + intercontinental shape),
    rebuilt purely from metadata — the same path elastic restores use."""
    meta = [{"name": "us", "n_nodes": 3, "ring_seed": seed, "rf": 2,
             "weight": 2},
            {"name": "eu", "n_nodes": 2, "ring_seed": seed + 1, "rf": 1,
             "weight": 1}]
    return FederatedRing.from_metadata(meta), {
        m["name"]: [f"{m['name']}/node{i}" for i in range(m["n_nodes"])]
        for m in meta}


@given(n=st.integers(1, 90), old_n=st.integers(1, 8), new_n=st.integers(1, 8),
       seed=st.integers(0, 99), consumed=st.integers(0, 150))
@settings(max_examples=30, deadline=None)
def test_reflow_exactly_once_across_federation(n, old_n, new_n, seed,
                                               consumed):
    """Exactly-once-per-epoch through an N->M resize when the keyspace spans
    a 2-cluster federation and both the old and the new strips are carved
    cluster-aware: pre-checkpoint deliveries + reflowed strips cover every
    uuid exactly once for every transition epoch and the first steady one."""
    old_n, new_n = min(old_n, n), min(new_n, n)
    uuids = _uuids(n)
    universe = {str(u) for u in uuids}
    ring, names_by_cluster = _fed_ring()

    def plans_for(m):
        pref = federated_preferred_subsets(names_by_cluster, m)
        split = lambda s: split_strips(s, m, "cluster_aware", ring=ring,
                                       rf=0, preferred=pref)
        steady = split(global_order(uuids, seed, m))
        return [EpochPlan.from_samples(steady[j], seed, j, m)
                for j in range(m)], split

    old_plans, _ = plans_for(old_n)
    positions = [p.advance(0, 0, consumed) for p in old_plans]
    e_start, tails = compute_reflow(old_plans, positions)
    new_plans, split = plans_for(new_n)
    for epoch, tail in tails.items():
        for plan, strip in zip(new_plans, split(tail)):
            plan.install_overrides({epoch: strip})

    for epoch in range(e_start, max(tails) + 2):
        pre = [u for plan, pos in zip(old_plans, positions)
               for u in _delivered_before(plan, pos, epoch)]
        post_strips = [plan.permutation(epoch) for plan in new_plans]
        post = [u for strip in post_strips for u in strip]
        flat = [str(u) for u in pre + post]
        assert len(flat) == n
        assert set(flat) == universe
        sizes = sorted(len(s) for s in post_strips)
        assert sizes[-1] - sizes[0] <= 1


@given(n=st.integers(2, 90), old_n=st.integers(1, 8), new_n=st.integers(1, 8),
       seed=st.integers(0, 99), consumed=st.integers(0, 150))
@settings(max_examples=25, deadline=None)
def test_reflow_converges_to_fresh_m_host_sharding(n, old_n, new_n, seed,
                                                   consumed):
    """Past the transition, a resharded run is indistinguishable from a run
    that started with M hosts: identical per-epoch permutations."""
    old_n, new_n = min(old_n, n), min(new_n, n)
    uuids = _uuids(n)
    _, _, new_plans, _, e_last = _reshard(uuids, seed, old_n, new_n,
                                          consumed, token_aware=False)
    fresh = [EpochPlan(uuids, seed=seed, shard_id=j, num_shards=new_n)
             for j in range(new_n)]
    for epoch in (e_last + 1, e_last + 3):
        for reflowed, plain in zip(new_plans, fresh):
            assert reflowed.permutation(epoch) == plain.permutation(epoch)


@given(n=st.integers(1, 200), num_shards=st.integers(1, 9),
       consumed=st.integers(0, 500), extra=st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_advance_odometer_matches_iteration(n, num_shards, consumed, extra):
    """plan.advance == naively walking the (epoch, cursor) odometer, with
    and without transition overrides of a different length."""
    num_shards = min(num_shards, n)
    uuids = _uuids(n)
    plan = EpochPlan(uuids, seed=3, shard_id=0, num_shards=num_shards)
    # pin epoch 0 to a shorter override (a reflow transition strip)
    override = plan.permutation(1)[:max(len(plan) // 2, 1)]
    plan.install_overrides({0: override})
    e, c = 0, 0
    for _ in range(consumed):
        c += 1
        while c >= plan.epoch_length(e):
            c -= plan.epoch_length(e)
            e += 1
    assert plan.advance(0, 0, consumed) == (e, c)
    # advancing from a mid-stream position agrees too
    assert plan.advance(e, c, extra) == plan.advance(0, 0, consumed + extra)


def test_epoch_overrides_round_trip_and_expire():
    uuids = _uuids(40)
    plan = EpochPlan(uuids, seed=1, shard_id=0, num_shards=2)
    strip = uuids[:7]
    plan.install_overrides({2: strip})
    assert plan.epoch_length(2) == 7
    assert plan.permutation(2) == strip
    assert plan.epoch_length(3) == len(plan)
    assert plan.pending_overrides(2) == {2: strip}
    assert plan.pending_overrides(3) == {}        # consumed overrides drop
    # the override epoch participates in the infinite stream exactly once
    stream = plan.iter_from(2, 0)
    got = [next(stream) for _ in range(7 + len(plan))]
    assert [u for e, u in got[:7]] == strip
    assert all(e == 3 for e, u in got[7:])


def test_from_samples_is_verbatim():
    uuids = _uuids(10)
    plan = EpochPlan.from_samples(uuids, seed=9, shard_id=1, num_shards=3)
    assert plan._uuids == uuids and len(plan) == 10
    assert (plan.shard_id, plan.num_shards) == (1, 3)


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------

@given(n=st.integers(0, 300), n_hosts=st.integers(1, 9),
       seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_token_aware_split_is_balanced_partition(n, n_hosts, seed):
    """token_aware keeps the exact sharding semantics of contiguous: a
    balanced partition (sizes differ by <= 1, nothing lost or duplicated)."""
    uuids = _uuids(n, seed=seed)
    names = [f"node{i}" for i in range(N_NODES)]
    strips = split_strips(uuids, n_hosts, "token_aware", ring=_ring(), rf=RF,
                          preferred=preferred_node_subsets(names, n_hosts))
    sizes = [len(s) for s in strips]
    assert sum(sizes) == n and max(sizes) - min(sizes) <= 1 if sizes else True
    flat = [str(u) for s in strips for u in s]
    assert len(flat) == len(set(flat)) == n
    assert set(flat) == {str(u) for u in uuids}


def test_token_aware_beats_contiguous_on_replica_locality():
    """4 hosts on a 4-node rf=2 ring: greedy replica-skew should make nearly
    every key replica-local, while contiguous placement sits near the
    combinatorial baseline (~50%)."""
    uuids = _uuids(400)
    names = [f"node{i}" for i in range(N_NODES)]
    ring, pref = _ring(), preferred_node_subsets(names, 4)
    token = split_strips(uuids, 4, "token_aware", ring=ring, rf=RF,
                         preferred=pref)
    contig = split_contiguous(uuids, 4)
    f_token = replica_local_fraction(token, ring, RF, pref)
    f_contig = replica_local_fraction(contig, ring, RF, pref)
    assert f_token > 0.9
    assert f_token > f_contig + 0.2


def test_preferred_node_subsets_cover_and_wrap():
    names = [f"node{i}" for i in range(4)]
    two = preferred_node_subsets(names, 2)       # fewer hosts: disjoint stripes
    assert two == [("node0", "node2"), ("node1", "node3")]
    six = preferred_node_subsets(names, 6)       # more hosts: wrap around
    assert six[0] == ("node0",) and six[4] == ("node0",)
    for subsets in (two, six):
        assert set().union(*map(set, subsets)) == set(names)


def test_split_strips_rejects_unknown_policy_and_missing_ring():
    uuids = _uuids(8)
    with pytest.raises(ValueError):
        split_strips(uuids, 2, "round_robin")
    with pytest.raises(ValueError):
        split_strips(uuids, 2, "token_aware")    # no ring / preference map
