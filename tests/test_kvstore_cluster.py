"""KV store, token ring, and token-aware routing tests."""

import numpy as np
import pytest

from repro.core import (Cluster, KVStore, DataRow, MetaRow, TokenRing,
                        VirtualClock, make_uuid)
from repro.core.kvstore import token_of


def _rows(n=100, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n):
        u = make_uuid(rng)
        yield (DataRow(u, i % 7, 1000 + i, payload=b"x" * 16),
               MetaRow(u, f"e{i % 11}", i % 7))


def test_atomic_insert_and_get():
    store = KVStore()
    rows = list(_rows(10))
    store.insert_many(rows)
    assert len(store) == 10
    data, meta = rows[3]
    assert store.get_data(data.uuid).label == data.label
    assert store.get_meta(data.uuid).entity_id == meta.entity_id


def test_atomic_insert_rejects_mismatched_uuid():
    store = KVStore()
    rng = np.random.default_rng(0)
    d = DataRow(make_uuid(rng), 0, 10)
    m = MetaRow(make_uuid(rng), "e", 0)
    with pytest.raises(ValueError):
        store.insert_atomic(d, m)


def test_missing_uuid_raises():
    store = KVStore()
    with pytest.raises(KeyError):
        store.get_data(make_uuid(np.random.default_rng(0)))


def test_token_ring_balance():
    ring = TokenRing([f"n{i}" for i in range(4)], vnodes=128)
    rng = np.random.default_rng(0)
    counts = {f"n{i}": 0 for i in range(4)}
    for _ in range(4000):
        u = make_uuid(rng)
        counts[ring.replicas(u, 1)[0]] += 1
    # with 128 vnodes the split should be within ~25% of fair share
    for c in counts.values():
        assert 700 < c < 1300


def test_token_ring_replication_distinct():
    ring = TokenRing(["a", "b", "c"], vnodes=32)
    rng = np.random.default_rng(1)
    for _ in range(200):
        reps = ring.replicas(make_uuid(rng), 2)
        assert len(reps) == 2 and len(set(reps)) == 2


def test_token_ring_deterministic():
    ring1 = TokenRing(["a", "b"], seed=5)
    ring2 = TokenRing(["a", "b"], seed=5)
    u = make_uuid(np.random.default_rng(2))
    assert ring1.replicas(u, 2) == ring2.replicas(u, 2)


def test_cluster_routes_to_replicas():
    store = KVStore()
    store.insert_many(_rows(50))
    clk = VirtualClock()
    cluster = Cluster(clk, store, backend="scylla", n_nodes=3, rf=2)
    for u in store.uuids()[:20]:
        nodes = cluster.replica_nodes(u)
        assert len(nodes) == 2
        names = cluster.ring.replicas(u, 2)
        assert [n.name for n in nodes] == names


def test_token_of_stable():
    u = make_uuid(np.random.default_rng(9))
    assert token_of(u) == token_of(u)
