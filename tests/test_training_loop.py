"""Loader -> training loop closure: per-step data-stall accounting
(``core.stats.StepStats``), exactly-once checkpointing through
``DeviceFeed``, and the goodput-facing ``run_training`` surface."""

import json
import os

import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import CassandraLoader, KVStore, LoaderConfig
from repro.core.stats import StepStats
from repro.data.datasets import SyntheticTokenDataset, ingest
from repro.data.pipeline import DeviceFeed
from repro.models import build_model
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import OptimizerConfig

SEQ = 24
B = 8


class StubClock:
    """now()-only clock for StepStats units."""

    def __init__(self) -> None:
        self.t = 0.0

    def now(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# StepStats units (stub clock)
# ---------------------------------------------------------------------------

def test_step_stats_stall_fraction_and_goodput():
    clk = StubClock()
    ss = StepStats(clk)
    # 4 steps: waits 1,0,3,0 against computes of 4 -> stall 4/20
    for wait, compute in [(1.0, 4.0), (0.0, 4.0), (3.0, 4.0), (0.0, 4.0)]:
        ss.on_wait(wait, blocked=wait > 0)
        clk.t += wait + compute
        ss.on_compute(compute)
    assert ss.steps == 4
    assert ss.stall_frac() == pytest.approx(4.0 / 20.0)
    assert ss.goodput_sps(batch_size=32) == pytest.approx(4 * 32 / 20.0)
    assert ss.blocked == 2 and ss.buffer_hits == 2
    # skip drops leading steps from both series
    assert ss.stall_frac(skip=2) == pytest.approx(3.0 / 11.0)


def test_step_stats_pairs_only_closed_steps():
    ss = StepStats(StubClock())
    ss.on_wait(5.0)            # open step: wait recorded, no compute yet
    assert ss.steps == 0
    assert ss.stall_frac() == 0.0
    assert ss.goodput_sps(32) == 0.0
    ss.on_compute(5.0)
    assert ss.steps == 1
    assert ss.stall_frac() == pytest.approx(0.5)


def test_step_stats_stall_windows_reuses_windowed_series():
    clk = StubClock()
    ss = StepStats(clk)
    # one stalled step ending at t=1, one clean step ending at t=3
    ss.on_wait(0.8)
    clk.t = 1.0
    ss.on_compute(0.2)
    ss.on_wait(0.0, blocked=False)
    clk.t = 3.0
    ss.on_compute(2.0)
    win = ss.stall_windows(window=1.0)
    assert [t for t, _ in win] == [0.0, 1.0, 2.0, 3.0]
    # 0.8 stalled seconds land in the window containing t_end=1.0
    assert win[1][1] == pytest.approx(0.8)
    assert win[2][1] == 0.0


def test_step_stats_summary_schema():
    ss = StepStats(StubClock())
    ss.on_wait(1.0)
    ss.on_compute(3.0)
    s = ss.summary(batch_size=16)
    assert {"steps", "stall_frac", "goodput_sps", "buffer_hits", "blocked",
            "wait_s", "compute_s"} <= set(s)
    assert s["stall_frac"] == pytest.approx(0.25)
    assert s["wait_s"]["max"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# DeviceFeed accounting + consumer-facing checkpoint position
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def token_store():
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=512, seq_len=SEQ,
                                                vocab=512, seed=7))
    return store, uuids


def _loader(token_store, **kw):
    store, uuids = token_store
    base = dict(batch_size=B, prefetch_buffers=2, io_threads=2, route="low",
                materialize=True, seed=11)
    base.update(kw)
    return CassandraLoader(store, uuids, LoaderConfig(**base))


def test_device_feed_reports_waits(token_store):
    loader = _loader(token_store)
    feed = DeviceFeed(loader, SEQ)
    for _ in range(6):
        next(feed)
    ss = feed.step_stats
    assert len(ss.wait_s) == 6
    assert ss.buffer_hits + ss.blocked == 6
    # waits are on the loader's (virtual) clock and can't be negative
    assert all(w >= 0.0 for w in ss.wait_s)
    # the first __next__ fills the double buffer cold -> it must block
    assert ss.wait_s[0] > 0.0


def test_device_feed_stall_slow_route_exceeds_fast(token_store):
    """A 150 ms route stalls a tight consumer more than a local one."""

    def stall_for(route):
        # depth-1 in-order loading: every refill waits on the network
        loader = _loader(token_store, route=route, prefetch_buffers=1,
                         out_of_order=False, incremental_ramp=False)
        feed = DeviceFeed(loader, SEQ, prefetch=1)
        ss = feed.step_stats
        for _ in range(8):
            next(feed)
            loader.clock.sleep(0.001)            # near-zero compute
            ss.on_compute(0.001, t_end=loader.clock.now())
        return ss.stall_frac(skip=1)

    slow, fast = stall_for("high"), stall_for("local")
    assert slow > fast
    assert slow > 0.5          # RTT-bound: almost all wall time is stall


def test_device_feed_state_rewinds_queued_batches(token_store):
    loader = _loader(token_store, out_of_order=False)
    feed = DeviceFeed(loader, SEQ, prefetch=2)
    for _ in range(3):
        next(feed)
    # loader has pulled 3 + prefetch batches; the trainer saw only 3
    assert loader.state()["consumed"] == 3 + 2
    pos = feed.state()
    assert pos["consumed"] == 3
    assert pos["cursor"] == 3 * B
    assert len(feed._queue) == 2


def test_loader_public_started_and_ready(token_store):
    loader = _loader(token_store)
    assert not loader.started
    feed = DeviceFeed(loader, SEQ)
    next(feed)                     # feed starts the loader itself
    assert loader.started
    assert loader.ready_batches >= 0


def test_device_feed_restore_exactly_once(token_store):
    """checkpoint->restore through feed.state(): the epoch-0 prefix is
    delivered with no sample skipped or duplicated."""
    store, uuids = token_store
    n_total = len(uuids) // B
    k = 7
    seen = []
    loader = _loader(token_store, out_of_order=False)
    feed = DeviceFeed(loader, SEQ)
    for _ in range(k):
        _, meta = next(feed)
        seen.extend(str(s.uuid) for s in meta.samples)
    pos = feed.state()
    loader.close()

    loader2 = _loader(token_store, out_of_order=False)
    loader2.start(epoch=pos["epoch"], cursor=pos["cursor"])
    feed2 = DeviceFeed(loader2, SEQ)
    for _ in range(n_total - k):
        _, meta = next(feed2)
        seen.extend(str(s.uuid) for s in meta.samples)
    loader2.close()

    want = [str(u) for u in loader2.plan.permutation(0)[:n_total * B]]
    assert len(seen) == len(set(seen))          # no duplicates
    assert sorted(seen) == sorted(want)         # nothing skipped


def test_loader_state_would_skip_queued_batches(token_store):
    """The regression the feed-side checkpoint fixes: restoring from
    loader.state() (cursor past the queued batches) skips samples."""
    loader = _loader(token_store, out_of_order=False)
    feed = DeviceFeed(loader, SEQ, prefetch=2)
    next(feed)
    skewed, exact = loader.state(), feed.state()
    assert skewed["cursor"] - exact["cursor"] == 2 * B


# ---------------------------------------------------------------------------
# run_training end to end (jitted tiny model)
# ---------------------------------------------------------------------------

def _tiny_model():
    return build_model(ArchConfig(
        name="loop-test-lm", family="dense", n_layers=1, d_model=32,
        n_heads=2, n_kv_heads=1, d_ff=64, vocab=512, head_dim=16,
        dtype="float32", remat=False))


@pytest.mark.slow
def test_history_schema_and_stats(token_store):
    store, uuids = token_store
    res = run_training(
        _tiny_model(), store, uuids,
        LoaderConfig(batch_size=B, prefetch_buffers=2, io_threads=2,
                     route="low", materialize=True, seed=3),
        TrainLoopConfig(total_steps=6, seq_len=SEQ, log_every=2,
                        charge_step_time=0.01),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=6))
    for rec in res["history"]:
        # static-mode schema: the pre-existing keys survive unchanged...
        assert {"step", "loss", "sps"} <= set(rec)
        # ...and the stall accounting rides along
        assert 0.0 <= rec["stall_frac"] <= 1.0
        assert rec["goodput_sps"] >= 0.0
    s = res["stats"]
    assert s["steps"] == 6
    assert 0.0 <= s["stall_frac"] <= 1.0
    # pinned compute: goodput can't exceed the compute bound
    assert s["goodput_sps"] <= B / 0.01 * 1.001
    assert res["step_stats"].steps == 6


@pytest.mark.slow
def test_checkpoint_restore_bit_exact_loss_curve(token_store, tmp_path):
    """Interrupting at a checkpoint and restoring replays the identical
    sample stream through DeviceFeed: the loss curve is bit-exact."""
    store, uuids = token_store
    loader_cfg = LoaderConfig(batch_size=B, prefetch_buffers=2, io_threads=2,
                              route="low", out_of_order=False,
                              materialize=True, seed=5)
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=8)

    losses_a = []
    run_training(_tiny_model(), store, uuids, loader_cfg,
                 TrainLoopConfig(total_steps=8, seq_len=SEQ, log_every=1,
                                 charge_step_time=0.01),
                 opt, on_metrics=lambda m: losses_a.append(m["loss"]))

    ckpt = str(tmp_path / "ckpt")
    losses_b = []
    run_training(_tiny_model(), store, uuids, loader_cfg,
                 TrainLoopConfig(total_steps=4, seq_len=SEQ, log_every=1,
                                 checkpoint_every=4, checkpoint_dir=ckpt,
                                 charge_step_time=0.01),
                 opt, on_metrics=lambda m: losses_b.append(m["loss"]))
    run_training(_tiny_model(), store, uuids, loader_cfg,
                 TrainLoopConfig(total_steps=8, seq_len=SEQ, log_every=1,
                                 checkpoint_every=4, checkpoint_dir=ckpt,
                                 charge_step_time=0.01),
                 opt, on_metrics=lambda m: losses_b.append(m["loss"]))
    assert losses_b == losses_a    # no skipped/duplicated samples anywhere


@pytest.mark.slow
def test_checkpoint_carries_flow_snapshot(token_store, tmp_path):
    store, uuids = token_store
    ckpt = str(tmp_path / "flow_ckpt")
    run_training(
        _tiny_model(), store, uuids,
        LoaderConfig(batch_size=B, prefetch_buffers=2, io_threads=2,
                     route="med", materialize=True, flow_control="adaptive",
                     seed=9),
        TrainLoopConfig(total_steps=4, seq_len=SEQ, checkpoint_every=4,
                        checkpoint_dir=ckpt, charge_step_time=0.01),
        OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=4))
    latest = sorted(os.listdir(ckpt))[-1]
    with open(os.path.join(ckpt, latest, "manifest.json")) as f:
        manifest = json.load(f)
    flow = manifest["extra"]["loader"]["flow"]
    assert flow["budget"] > 0            # measured operating point rides along
    # restoring it re-seeds a fresh adaptive loader past slow start
    loader = _loader(token_store, flow_control="adaptive")
    loader.restore_flow(flow)
    assert loader.flow_controller._slow_start is False


def test_flow_snapshot_none_in_static_mode(token_store):
    loader = _loader(token_store)
    assert loader.flow_snapshot() is None
    loader_a = _loader(token_store, flow_control="adaptive")
    snap = loader_a.flow_snapshot()
    assert isinstance(snap, dict) and "budget" in snap
