"""Checkpoint manager: atomic save/restore, GC, loader-position roundtrip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))},
                    "step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(10, state, extra={"loader": {"epoch": 1, "cursor": 320}})
    restored, manifest = mgr.restore(state)
    assert manifest["step"] == 10
    assert manifest["extra"]["loader"]["cursor"] == 320
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (10, 20, 30, 40):
        mgr.save(s, state)
    assert mgr.latest_step() == 40
    assert mgr.all_steps() == [30, 40]           # keep=2 GC'd older


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    bad = _state()
    bad["params"]["w"] = jnp.zeros((5, 8))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_restore_missing_dir_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "empty"))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_elastic_restore_with_shardings(tmp_path):
    """Restore with explicit target shardings (single-device here — the
    mechanism is device_put against a sharding tree)."""
    from jax.sharding import NamedSharding, PartitionSpec
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(3, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), state)
    restored, _ = mgr.restore(state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
