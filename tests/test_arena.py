"""Pinned arena: slab reuse, zero-copy views, arena-backed batch assembly."""

import numpy as np
import pytest

from repro.core import (ArenaSlab, CassandraLoader, KVStore, LoaderConfig,
                        PinnedArena)
from repro.data.datasets import (SyntheticPixelDataset, SyntheticTokenDataset,
                                 decode_token_record, ingest)


# -- slab mechanics ----------------------------------------------------------


def test_slab_write_view_roundtrip():
    slab = ArenaSlab(batch_size=4, slot_bytes=16)
    slab.write(0, b"hello", 5)
    slab.write(1, b"0123456789abcdefOVERFLOW", 24)   # clipped to the slot
    slab.write(2, None, 8)                           # missing payload
    assert bytes(slab.view(0)) == b"hello"
    assert bytes(slab.view(1)) == b"0123456789abcdef"
    assert bytes(slab.view(2)) == b""
    assert bytes(slab.view(0, size=3)) == b"hel"


def test_slab_reuse_zeroes_stale_tail():
    arena = PinnedArena(batch_size=2, slot_bytes=8)
    slab = arena.acquire()
    slab.write(0, b"AAAAAAAA", 8)
    slab.release()
    again = arena.acquire()
    assert again is slab                             # same buffer recycled
    again.write(0, b"bb", 2)
    # a shorter write must not leak the previous batch's bytes
    assert bytes(again.buf[0]) == b"bb" + b"\x00" * 6
    assert bytes(again.view(0)) == b"bb"


def test_slab_pixels_view_shares_memory():
    arena = PinnedArena(batch_size=2, slot_bytes=12)
    slab = arena.acquire()
    slab.write(0, bytes(range(12)), 12)
    px = slab.pixels(2, 2, 3)
    assert px.shape == (2, 2, 2, 3)
    assert px.base is not None                       # a view, not a copy
    np.testing.assert_array_equal(px[0].ravel(), np.arange(12))
    with pytest.raises(ValueError):
        slab.pixels(4, 4, 3)                         # larger than the slot


def test_arena_reuse_and_idempotent_release():
    arena = PinnedArena(batch_size=2, slot_bytes=4, initial_slabs=2)
    a, b = arena.acquire(), arena.acquire()
    assert arena.slabs_created == 2 and arena.outstanding == 2
    a.release()
    a.release()                                      # idempotent
    st = arena.stats()
    assert st["outstanding"] == 1
    c = arena.acquire()
    assert c is a                                    # LIFO reuse
    assert arena.slabs_created == 2                  # nothing new allocated
    b.release(), c.release()
    assert arena.stats()["outstanding"] == 0
    with pytest.raises(ValueError):
        arena.release(ArenaSlab(3, 4))               # foreign geometry


def test_arena_grows_only_under_pressure():
    arena = PinnedArena(batch_size=1, slot_bytes=1, initial_slabs=1)
    held = [arena.acquire() for _ in range(4)]       # consumer hoards slabs
    assert arena.slabs_created == 4
    assert arena.stats()["high_water"] >= 4
    for s in held:
        s.release()
    for _ in range(10):
        arena.acquire().release()
    assert arena.slabs_created == 4                  # steady state: reuse


def test_bad_geometry_rejected():
    with pytest.raises(ValueError):
        PinnedArena(0, 16)
    with pytest.raises(ValueError):
        PinnedArena(16, 0)


# -- arena-backed loader batches ---------------------------------------------


@pytest.fixture(scope="module")
def token_store():
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=512, seq_len=32,
                                                seed=2))
    return store, uuids


def _arena_loader(store, uuids, **kw):
    cfg = LoaderConfig(batch_size=32, prefetch_buffers=2, route="local",
                       materialize=True, use_arena=True, seed=3, **kw)
    return CassandraLoader(store, uuids, cfg)


def test_arena_batch_payloads_decode(token_store):
    store, uuids = token_store
    ld = _arena_loader(store, uuids)
    ld.start()
    batch = ld.next_batch()
    assert batch.slab is not None
    assert all(s.payload is None for s in batch.samples)   # slab owns bytes
    for s, payload in zip(batch.samples, batch.payloads()):
        toks, label = decode_token_record(payload)         # memoryview OK
        assert label == s.label
        assert toks.size == 32
    assert batch.nbytes == sum(s.size for s in batch.samples)
    batch.release()
    assert ld.arena.stats()["outstanding"] < ld.arena.acquires


def test_arena_slabs_cycle_through_epoch(token_store):
    store, uuids = token_store
    ld = _arena_loader(store, uuids)
    ld.start()
    for _ in range(10):
        ld.next_batch().release()
    st = ld.arena.stats()
    assert st["reuses"] > 0
    # prefetch depth bounds the pool; never one-slab-per-batch
    assert st["slabs_created"] < 10


def test_pixels_requires_arena(token_store):
    store, uuids = token_store
    cfg = LoaderConfig(batch_size=8, prefetch_buffers=2, route="local",
                       materialize=True, seed=3)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    batch = ld.next_batch()
    assert batch.slab is None
    with pytest.raises(ValueError):
        batch.pixels(2, 4, 4)
    batch.release()                                  # no-op without a slab


def test_arena_pixel_batches_match_payload_bytes():
    ds = SyntheticPixelDataset(n_samples=128, h=8, w=8, c=3, seed=11)
    store = KVStore()
    uuids = ingest(store, ds)
    ld = _arena_loader(store, uuids, arena_slot_bytes=ds.nbytes)
    ld.start()
    batch = ld.next_batch()
    px = batch.pixels(ds.h, ds.w, ds.c)
    assert px.shape == (32, 8, 8, 3)
    for i, s in enumerate(batch.samples):
        expect = np.frombuffer(store.get_data(s.uuid).payload,
                               dtype=np.uint8).reshape(8, 8, 3)
        np.testing.assert_array_equal(px[i], expect)


def test_arena_ignored_without_materialize(token_store):
    store, uuids = token_store
    cfg = LoaderConfig(batch_size=8, prefetch_buffers=2, route="local",
                       use_arena=True, seed=3)     # lazy rows: no payloads
    ld = CassandraLoader(store, uuids, cfg)
    assert ld.arena is None
