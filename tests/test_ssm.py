"""SSM correctness: chunked-parallel training path == sequential decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.params import init_params


def _params(spec, seed=0):
    return init_params(spec, jax.random.PRNGKey(seed), jnp.float32)


def test_ssm_scan_chunked_matches_naive():
    rng = jax.random.PRNGKey(0)
    B, S, D, N = 2, 100, 8, 4
    da = jax.nn.sigmoid(jax.random.normal(rng, (B, S, D, N)))
    dbx = jax.random.normal(jax.random.PRNGKey(1), (B, S, D, N)) * 0.1
    h0 = jnp.zeros((B, D, N))
    h_seq, h_last = ssm._ssm_scan_chunked(da, dbx, h0, chunk=16)

    # naive sequential
    h = np.zeros((B, D, N))
    hs = []
    for t in range(S):
        h = np.asarray(da[:, t]) * h + np.asarray(dbx[:, t])
        hs.append(h.copy())
    np.testing.assert_allclose(np.asarray(h_seq), np.stack(hs, 1),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("S", [17, 64])
def test_mamba_train_equals_decode(S):
    d, d_inner, state = 16, 32, 4
    params = _params(ssm.mamba_spec(d, d_inner, state))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, S, d)) * 0.5
    full, _ = ssm.mamba_apply(params, x)

    st = ssm.mamba_init_state(2, d_inner, state, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, st = ssm.mamba_apply(params, x[:, t:t + 1], st)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("S,chunk", [(33, 8), (64, 16)])
def test_mlstm_train_equals_decode(S, chunk):
    d, H, Dh = 16, 2, 8
    params = _params(ssm.mlstm_spec(d, H, Dh))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, S, d)) * 0.5
    full, _ = ssm.mlstm_apply(params, x, chunk=chunk)

    st = ssm.mlstm_init_state(2, H, Dh)
    outs = []
    for t in range(S):
        o, st = ssm.mlstm_apply(params, x[:, t:t + 1], st, chunk=1)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=5e-3, atol=5e-4)


def test_mlstm_chunk_size_invariance():
    d, H, Dh = 16, 2, 8
    params = _params(ssm.mlstm_spec(d, H, Dh))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 48, d)) * 0.5
    a, _ = ssm.mlstm_apply(params, x, chunk=48)
    b, _ = ssm.mlstm_apply(params, x, chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)


def test_slstm_train_equals_decode():
    d, H = 16, 4
    params = _params(ssm.slstm_spec(d, H))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 20, d)) * 0.5
    full, _ = ssm.slstm_apply(params, x)

    st = ssm.slstm_init_state(2, d)
    outs = []
    for t in range(20):
        o, st = ssm.slstm_apply(params, x[:, t:t + 1], st)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-4, atol=1e-5)


def test_mamba_states_finite_long_seq():
    d, d_inner, state = 8, 16, 4
    params = _params(ssm.mamba_spec(d, d_inner, state))
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 512, d))
    out, st = ssm.mamba_apply(params, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(jnp.isfinite(st["h"])))
