"""Multi-tenant QoS: spec/scheduler validation, the weighted-fair water-fill
invariants (hypothesis-property-tested: conservation, weighted fairness,
work conservation, no starvation), admission control, the single-tenant
bit-identity regression, and tenancy through the multi-host checkpoint.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (FlowControlConfig, KVStore, MultiHostConfig,
                        MultiHostRun, QOS_CLASSES, TenantScheduler,
                        TenantSpec)
from repro.core.flowctl import SharedIngressLimiter
from repro.core.prefetcher import EpochPlan
from repro.core.replication import ZipfPlan
from repro.data.datasets import (SyntheticImageDataset, SyntheticTokenDataset,
                                 ingest)
from repro.data.pipeline import DeviceFeed

BW = 2.0e9                      # NIC bandwidth the unit tests schedule


@pytest.fixture(scope="module")
def store_uuids():
    return _shared_store()


_STORE_CACHE = None


def _shared_store():
    """Fixture-equivalent the @given property tests could call directly (the
    hypothesis shim's wrappers take no named params, so pytest cannot inject
    fixtures into them)."""
    global _STORE_CACHE
    if _STORE_CACHE is None:
        store = KVStore()
        uuids = ingest(store, SyntheticImageDataset(n_samples=20_000,
                                                    seed=11))
        _STORE_CACHE = (store, uuids)
    return _STORE_CACHE


class _Ctl:
    """The controller surface TenantScheduler consumes, without the BDP
    machinery: fixed measurements instead of filters."""

    def __init__(self, min_rtt=0.02, avg_bytes=100_000.0, rate=None,
                 inflight=0.0):
        self.cfg = FlowControlConfig()          # gain et al. at defaults
        self._min_rtt = min_rtt
        self._avg = avg_bytes
        self._rate = rate
        self._inflight = inflight

    def min_rtt(self):
        return self._min_rtt

    def avg_sample_bytes(self):
        return self._avg

    def delivery_rate(self):
        return self._rate

    def inflight_samples(self):
        return self._inflight


def _sched(specs, **kw):
    s = TenantScheduler(BW, specs, **kw)
    ctls = {}
    for spec in specs:
        c = _Ctl()
        s.assign(c, spec.name)
        ctls[spec.name] = c
    return s, ctls


# ---------------------------------------------------------------------------
# Spec / scheduler validation
# ---------------------------------------------------------------------------

def test_tenant_spec_defaults():
    t = TenantSpec("serve", qos="latency", weight=2.0)
    assert t.qos in QOS_CLASSES
    assert t.sampling == "uniform" and t.rate_floor is None


@pytest.mark.parametrize("kw", [
    dict(name=""),
    dict(name="t", qos="gold"),
    dict(name="t", weight=0.0),
    dict(name="t", weight=-1.0),
    dict(name="t", rate_floor=0.0),
    dict(name="t", rate_ceiling=-5.0),
    dict(name="t", rate_floor=2e9, rate_ceiling=1e9),
    dict(name="t", sampling="pareto"),
    dict(name="t", zipf_s=0.0),
])
def test_tenant_spec_rejects_bad(kw):
    with pytest.raises(ValueError):
        TenantSpec(**kw)


@pytest.mark.parametrize("specs,kw", [
    ((), {}),
    ((TenantSpec("a"), TenantSpec("a")), {}),
    ((TenantSpec("a", rate_floor=BW), TenantSpec("b", rate_floor=1.0)), {}),
    ((TenantSpec("a"),), dict(latency_burst=0.5)),
    ((TenantSpec("a"),), dict(demand_headroom=1.0)),
])
def test_scheduler_rejects_bad_config(specs, kw):
    with pytest.raises(ValueError):
        TenantScheduler(BW, specs, **kw)


# ---------------------------------------------------------------------------
# The water-fill, unit-level
# ---------------------------------------------------------------------------

def test_single_tenant_fair_cap_bit_identical_to_untenanted():
    """One default tenant degenerates to the equal-split limiter: same cap
    floats, so budgets (and therefore runs) cannot diverge."""
    base = SharedIngressLimiter(BW)
    sched = TenantScheduler(BW, (TenantSpec("solo"),))
    for lim in (base, sched):
        a, b = _Ctl(), _Ctl(min_rtt=0.04, avg_bytes=90_000.0)
        lim.register(a)
        lim.register(b)
        lim.on_complete(a, 0.02, 1.0, 100_000)
        lim.on_complete(b, 0.04, 1.0, 90_000)
        caps = (lim.fair_cap_samples(a), lim.fair_cap_samples(b))
        if lim is base:
            want = caps
    assert caps == want                          # exact ==, not approx


def test_weighted_shares_proportional():
    sched, _ = _sched((TenantSpec("a", weight=1.0),
                       TenantSpec("b", weight=3.0)))
    shares = sched.tenant_shares(now=0.0)
    assert shares["b"] == pytest.approx(3.0 * shares["a"], rel=1e-12)
    assert sum(shares.values()) == pytest.approx(BW, rel=1e-12)


def test_idle_tenant_share_redistributed():
    sched, ctls = _sched((TenantSpec("a"), TenantSpec("b")))
    sched.on_complete(ctls["a"], 0.02, 0.0, 100_000)    # a last seen at t=0
    sched.on_complete(ctls["b"], 0.02, 5.0, 100_000)    # b active at t=5
    shares = sched.tenant_shares()                      # now = 5.0
    assert shares.get("a", 0.0) == 0.0                  # idle: no share
    assert shares["b"] == pytest.approx(BW, rel=1e-12)  # ...redistributed


def test_floor_reserved_under_adversarial_weight():
    floor = 0.25 * BW
    sched, _ = _sched((TenantSpec("f", weight=1.0, rate_floor=floor),
                       TenantSpec("adv", weight=1000.0)))
    shares = sched.tenant_shares(now=0.0)
    assert shares["f"] >= floor * (1.0 - 1e-12)
    assert sum(shares.values()) == pytest.approx(BW, rel=1e-12)


def test_ceiling_closes_out_and_redistributes():
    ceil = 0.1 * BW
    sched, _ = _sched((TenantSpec("capped", rate_ceiling=ceil),
                       TenantSpec("open")))
    shares = sched.tenant_shares(now=0.0)
    assert shares["capped"] == pytest.approx(ceil, rel=1e-12)
    assert shares["open"] == pytest.approx(BW - ceil, rel=1e-12)


def test_demand_cap_redistributes_unused_share():
    """A tenant delivering well below its weight-share is closed out at
    measured demand x headroom; the surplus goes to the tenant that can use
    it (work conservation for low-demand, not just idle, tenants)."""
    sched = TenantScheduler(BW, (TenantSpec("slow"), TenantSpec("hungry")))
    slow = _Ctl(rate=100.0, avg_bytes=100_000.0)        # 1e7 B/s measured
    hungry = _Ctl()                                     # unmeasured: probing
    sched.assign(slow, "slow")
    sched.assign(hungry, "hungry")
    shares = sched.tenant_shares(now=0.0)
    want = 100.0 * 100_000.0 * sched.demand_headroom
    assert shares["slow"] == pytest.approx(want, rel=1e-12)
    assert shares["hungry"] == pytest.approx(BW - want, rel=1e-12)


def test_admit_batch_defers_at_share_latency_rides_burst():
    """At identical load just above the share BDP, the batch tenant defers
    and the latency tenant's burst headroom still admits."""
    specs = (TenantSpec("lat", qos="latency"), TenantSpec("bat", qos="batch"))
    sched = TenantScheduler(BW, specs)
    gain = FlowControlConfig().gain
    cap = gain * ((BW / 2) / 100_000.0) * 0.02          # share BDP, samples
    lat = _Ctl(inflight=1.05 * cap)
    bat = _Ctl(inflight=1.05 * cap)
    sched.assign(lat, "lat")
    sched.assign(bat, "bat")
    assert sched.admit(lat) is True                     # inside 1.25x burst
    assert sched.admit(bat) is False                    # strict at share
    assert sched.admit_denials["bat"] == 1
    assert sched.admit_denials["lat"] == 0
    assert sched.admit_checks["lat"] == sched.admit_checks["bat"] == 1


def test_admit_unmeasured_or_unassigned_always_passes():
    sched = TenantScheduler(BW, (TenantSpec("t"),))
    fresh = _Ctl(min_rtt=None, avg_bytes=None, inflight=1e9)
    sched.assign(fresh, "t")
    assert sched.admit(fresh) is True                   # still ramping
    outsider = _Ctl()
    assert sched.admit(outsider) is True                # not a tenant member


def test_scheduler_snapshot_restore_roundtrip():
    specs = (TenantSpec("a"), TenantSpec("b", weight=2.0, rate_floor=1e8))
    sched, ctls = _sched(specs)
    for i in range(5):
        sched.on_complete(ctls["a"], 0.02, 0.1 * i, 1000)
    sched.admit(ctls["a"])
    snap = sched.snapshot()
    assert snap["tenants"]["b"]["weight"] == 2.0
    assert snap["tenants"]["a"]["egress_bytes"] == 5000

    fresh, _ = _sched(specs)
    fresh.restore(snap)
    assert fresh.snapshot() == snap
    fresh.restore(None)                                 # no-op
    fresh.restore({"tenants": {"ghost": {"egress_bytes": 7}}})  # dropped
    assert fresh.snapshot() == snap


def test_report_sections_per_tenant():
    sched, ctls = _sched((TenantSpec("a", qos="latency"), TenantSpec("b")))
    sched.on_complete(ctls["a"], 0.03, 0.5, 2000)
    rep = sched.report()
    assert set(rep) == {"a", "b"}
    a = rep["a"]
    assert a["qos"] == "latency" and a["completions"] == 1
    assert a["egress_bytes"] == 2000
    assert a["request_latency_s"]["p50"] == pytest.approx(0.03)
    assert a["share_Bps"] > 0.0
    assert {"weight", "rate_floor", "rate_ceiling", "active_members",
            "admit_checks", "admit_denials"} <= set(a)


# ---------------------------------------------------------------------------
# Scheduling invariants, property-tested
# ---------------------------------------------------------------------------

@given(w1=st.integers(1, 100), w2=st.integers(1, 100), w3=st.integers(1, 100),
       f1=st.integers(0, 4), f2=st.integers(0, 4),
       measured=st.integers(0, 7))
@settings(max_examples=25, deadline=None)
def test_prop_shares_conserved_never_exceed_nic(w1, w2, w3, f1, f2,
                                                measured):
    """Conservation: whatever the weights, floors and measured demands,
    granted shares never sum above the NIC bandwidth."""
    specs = (TenantSpec("a", weight=float(w1),
                        rate_floor=f1 * BW / 10 or None),
             TenantSpec("b", weight=float(w2),
                        rate_floor=f2 * BW / 10 or None),
             TenantSpec("c", weight=float(w3)))
    sched = TenantScheduler(BW, specs)
    for i, spec in enumerate(specs):
        rate = 500.0 * (i + 1) if measured & (1 << i) else None
        sched.assign(_Ctl(rate=rate), spec.name)
    shares = sched.tenant_shares(now=0.0)
    assert sum(shares.values()) <= BW * (1 + 1e-9)
    assert all(v >= 0.0 for v in shares.values())


@given(w1=st.integers(1, 64), w2=st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_prop_backlogged_tenants_split_by_weight(w1, w2):
    """Weighted fairness: two backlogged tenants with no floors/ceilings
    split the NIC exactly in proportion to their weights."""
    sched, _ = _sched((TenantSpec("a", weight=float(w1)),
                       TenantSpec("b", weight=float(w2))))
    shares = sched.tenant_shares(now=0.0)
    assert shares["a"] * w2 == pytest.approx(shares["b"] * w1, rel=1e-9)
    assert sum(shares.values()) == pytest.approx(BW, rel=1e-9)


@given(n=st.integers(2, 4), mask=st.integers(0, 15),
       w=st.integers(1, 20))
@settings(max_examples=25, deadline=None)
def test_prop_work_conserving_idle_shares_redistributed(n, mask, w):
    """Work conservation: idle tenants get nothing, and the active tenants'
    shares still sum to the whole NIC — no slice is stranded."""
    active_mask = (mask % (1 << n)) | 1         # tenant 0 always active
    specs = tuple(TenantSpec(f"t{i}", weight=float(w if i else 1))
                  for i in range(n))
    sched, ctls = _sched(specs)
    for i, spec in enumerate(specs):
        t = 10.0 if active_mask & (1 << i) else 0.0
        sched.on_complete(ctls[spec.name], 0.02, t, 1000)
    shares = sched.tenant_shares()              # now = 10.0 > window
    active = [s.name for i, s in enumerate(specs) if active_mask & (1 << i)]
    idle = [s.name for i, s in enumerate(specs)
            if not active_mask & (1 << i)]
    assert all(shares.get(nm, 0.0) == 0.0 for nm in idle)
    assert sum(shares[nm] for nm in active) == pytest.approx(BW, rel=1e-9)


@given(adv_w=st.integers(1, 10**6), floor_tenths=st.integers(1, 9))
@settings(max_examples=25, deadline=None)
def test_prop_floor_tenant_never_starved(adv_w, floor_tenths):
    """No starvation: a floor-holding tenant with demand is granted at
    least its floor, however heavy the adversary's weight."""
    floor = floor_tenths * BW / 10
    sched, _ = _sched((TenantSpec("f", weight=1.0, rate_floor=floor),
                       TenantSpec("adv", weight=float(adv_w))))
    shares = sched.tenant_shares(now=0.0)
    assert shares["f"] >= floor * (1.0 - 1e-12)
    assert shares["adv"] <= (BW - floor) * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Tenancy through MultiHostRun
# ---------------------------------------------------------------------------

def _mh_cfg(n_hosts, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=128, io_threads=4,
                    route="med", n_nodes=4, replication_factor=2,
                    hedge_after=None, seed=9, flow_control="adaptive",
                    shared_client_ingress=True,
                    client_ingress_bandwidth=2.0e9)
    defaults.update(kw)
    return MultiHostConfig(**defaults)


SERVE = TenantSpec("serve", qos="latency", weight=3.0)
TRAIN = TenantSpec("train", qos="batch", weight=1.0,
                   sampling="zipf", zipf_s=1.2)


@pytest.mark.parametrize("kw", [
    dict(tenant_of_host=("a", "b")),                          # no tenants
    dict(tenants=(SERVE,), flow_control="static"),
    dict(tenants=(SERVE,), tenant_of_host=("serve",)),        # wrong length
    dict(tenants=(SERVE,), tenant_of_host=("serve", "ghost")),
    dict(tenants=(SERVE,), shared_client_ingress=False),
    dict(host_sampling=("uniform",)),                         # wrong length
    dict(host_sampling=("uniform", "pareto")),
])
def test_multihost_tenancy_config_rejected(store_uuids, kw):
    store, uuids = store_uuids
    with pytest.raises(ValueError):
        MultiHostRun(store, uuids[:4000], _mh_cfg(2, **kw))


def test_single_tenant_run_bit_identical_to_untenanted(store_uuids):
    """The QoS machinery on a one-tenant config must not move a single
    event: same virtual end time and same bytes as the untenanted run."""
    store, uuids = store_uuids
    small = uuids[:4000]

    def end_state(tenants):
        cfg = _mh_cfg(2, tenants=tenants)
        run = MultiHostRun(store, small, cfg).start()
        rep = run.run(6)
        return run.clock.now(), rep["aggregate_Bps"], rep["per_client_Bps"]

    assert end_state(None) == end_state((TenantSpec("solo"),))


def test_mixed_sampling_plans_and_tenant_report(store_uuids):
    store, uuids = store_uuids
    cfg = _mh_cfg(3, tenants=(SERVE, TRAIN),
                  tenant_of_host=("serve", "train", "train"))
    run = MultiHostRun(store, uuids[:6000], cfg).start()
    assert isinstance(run.loaders[0].plan, EpochPlan)    # uniform tenant
    assert isinstance(run.loaders[1].plan, ZipfPlan)     # zipf tenant
    assert isinstance(run.loaders[2].plan, ZipfPlan)
    rep = run.run(4)
    tenants = rep["tenants"]
    assert tenants["serve"]["hosts"] == [0]
    assert tenants["train"]["hosts"] == [1, 2]
    for entry in tenants.values():
        assert entry["egress_Bps"] > 0.0
        assert 0.0 <= entry["hit_frac"] <= 1.0
        assert entry["request_latency_s"]["p99"] > 0.0
    assert len(rep["request_latency_s"]) == 3
    assert rep["request_latency_s"][0]["p99"] > 0.0
    assert "tenants:" in run.describe()


def test_admission_wired_through_pool_and_never_drops(store_uuids):
    """route_admission consults the tenant scheduler: checks are counted,
    over-share tenants defer, and delivery still completes (advisory)."""
    store, uuids = store_uuids
    cfg = _mh_cfg(3, tenants=(SERVE, TRAIN),
                  tenant_of_host=("serve", "train", "train"),
                  route_admission=True)
    run = MultiHostRun(store, uuids[:6000], cfg).start()
    rep = run.run(4)
    assert rep["rounds"] == 4
    assert sum(run.limiter.admit_checks.values()) > 0
    assert all(b > 0 for b in rep["per_client_Bps"])     # nobody starved


def test_floor_tenant_share_honored_against_zipf_adversary(store_uuids):
    """Integration starvation check: a weight-1 floor tenant against a
    weight-8 zipf adversary still gets granted at least its floor."""
    store, uuids = store_uuids
    floor = 3.0e8
    specs = (TenantSpec("floor", qos="latency", weight=1.0,
                        rate_floor=floor),
             TenantSpec("adv", qos="batch", weight=8.0,
                        sampling="zipf", zipf_s=1.3))
    cfg = _mh_cfg(3, tenants=specs,
                  tenant_of_host=("floor", "adv", "adv"))
    run = MultiHostRun(store, uuids[:6000], cfg).start()
    rep = run.run(6)
    entry = rep["tenants"]["floor"]
    assert entry["share_Bps"] >= floor * (1.0 - 1e-9)
    assert entry["egress_Bps"] > 0.0


def test_tenanted_zipf_checkpoint_resumes_exactly(store_uuids):
    """Mixed uniform+zipf tenant checkpoint restored onto the same config
    continues the exact per-host sample streams (the per-host sampling map
    in the checkpoint decides exactness)."""
    store, uuids = store_uuids
    small = uuids[:3000]
    cfg = _mh_cfg(2, tenants=(SERVE, TRAIN), route="low",
                  out_of_order=False, batch_size=100)

    def collector(dst):
        def on_batch(host_id, batch):
            dst.setdefault(host_id, []).extend(str(u) for u in batch.uuids)
        return on_batch

    unbroken: dict = {}
    run = MultiHostRun(store, small, cfg).start()
    run.run(3, on_batch=collector(unbroken))
    ck = run.checkpoint()
    assert ck["host_sampling"] == ["uniform", "zipf"]
    assert ck["tenant_of_host"] == ["serve", "train"]
    assert ck["tenants"]["tenants"]["train"]["completions"] > 0
    continued: dict = {}
    run.run(4, on_batch=collector(continued))

    resumed: dict = {}
    restore = MultiHostRun(store, small, cfg).start(ck)
    restore.run(4, on_batch=collector(resumed))
    assert resumed == continued                  # same streams, same order


def test_elastic_restore_conserves_tenant_weights_and_counters(store_uuids):
    """N->M restore with the same tenant set: weights ride the checkpoint
    unchanged and the cumulative per-tenant counters re-seed exactly."""
    store, uuids = store_uuids
    specs = (TenantSpec("a", weight=2.0), TenantSpec("b", weight=5.0))
    run = MultiHostRun(store, uuids[:4000],
                       _mh_cfg(2, tenants=specs)).start()
    run.run(4)
    ck = run.checkpoint()
    for spec in specs:
        assert ck["tenants"]["tenants"][spec.name]["weight"] == spec.weight

    restore = MultiHostRun(store, uuids[:4000],
                           _mh_cfg(4, tenants=specs)).start(ck)
    assert restore.limiter.snapshot()["tenants"] == ck["tenants"]["tenants"]
    assert {n: t.weight for n, t in restore.limiter.tenants.items()} == \
        {"a": 2.0, "b": 5.0}
    rep = restore.run(2)                         # and it keeps loading
    assert rep["tenants"]["a"]["egress_Bps"] > 0.0


def test_device_feed_restore_exactly_once_tenanted():
    """The PR-7 consumer-facing checkpoint position composes with tenancy:
    patching a tenanted multi-host checkpoint with ``feed.state()`` makes
    the restore exactly-once (no sample skipped or duplicated)."""
    B, SEQ = 16, 24
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(n_samples=256, seq_len=SEQ,
                                                vocab=512, seed=7))
    cfg = _mh_cfg(1, tenants=(TenantSpec("solo"),), route="low",
                  out_of_order=False, batch_size=B, materialize=True)
    n_total, k = len(uuids) // B, 5
    seen = []

    run = MultiHostRun(store, uuids, cfg).start()
    feed = DeviceFeed(run.loaders[0], SEQ)
    for _ in range(k):
        _, meta = next(feed)
        seen.extend(str(s.uuid) for s in meta.samples)
    ck = run.checkpoint()
    assert ck["shards"][0]["cursor"] - feed.state()["cursor"] == 2 * B
    ck["shards"][0].update(feed.state())         # rewind the device queue

    restore = MultiHostRun(store, uuids, cfg).start(ck)
    feed2 = DeviceFeed(restore.loaders[0], SEQ)
    for _ in range(n_total - k):
        _, meta = next(feed2)
        seen.extend(str(s.uuid) for s in meta.samples)
    want = [str(u) for u in restore.loaders[0].plan.permutation(0)]
    assert len(seen) == len(set(seen))           # no duplicates
    assert sorted(seen) == sorted(want)          # nothing skipped
