"""End-to-end: training loop over the network loader + serving engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import KVStore, LoaderConfig
from repro.data.datasets import SyntheticTokenDataset, ingest
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine
from repro.train.loop import TrainLoopConfig, run_training
from repro.train.optimizer import OptimizerConfig

pytestmark = pytest.mark.slow      # end-to-end train/serve; -m "not slow" skips


def _tiny_arch(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
                dtype="float32", remat=False)
    base.update(kw)
    return ArchConfig(**base)


@pytest.fixture(scope="module")
def token_store():
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(
        n_samples=1024, seq_len=32, vocab=512, seed=0))
    return store, uuids


def test_training_loop_reduces_loss(token_store, tmp_path):
    store, uuids = token_store
    model = build_model(_tiny_arch())
    loader_cfg = LoaderConfig(batch_size=16, prefetch_buffers=4, io_threads=2,
                              route="high", materialize=True, seed=1)
    loop_cfg = TrainLoopConfig(total_steps=30, seq_len=32, log_every=5,
                               checkpoint_every=15,
                               checkpoint_dir=str(tmp_path / "ckpt"))
    res = run_training(model, store, uuids, loader_cfg, loop_cfg,
                       OptimizerConfig(peak_lr=3e-3, warmup_steps=3,
                                       total_steps=30))
    hist = res["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_training_restart_from_checkpoint(token_store, tmp_path):
    store, uuids = token_store
    ckpt_dir = str(tmp_path / "ckpt2")
    model = build_model(_tiny_arch())
    loader_cfg = LoaderConfig(batch_size=16, prefetch_buffers=2, io_threads=2,
                              route="low", materialize=True, seed=2)
    # phase 1: 20 steps with checkpoint at 10 and 20
    loop1 = TrainLoopConfig(total_steps=20, seq_len=32, checkpoint_every=10,
                            checkpoint_dir=ckpt_dir)
    run_training(model, store, uuids, loader_cfg, loop1)
    # phase 2: restart and continue to 30 — resumes from step 20
    loop2 = TrainLoopConfig(total_steps=30, seq_len=32, checkpoint_every=10,
                            checkpoint_dir=ckpt_dir)
    res = run_training(model, store, uuids, loader_cfg, loop2)
    assert res["history"][0]["step"] > 20
    from repro.train.checkpoint import CheckpointManager
    assert CheckpointManager(ckpt_dir).latest_step() == 30


def test_serving_engine_greedy_decode():
    cfg = _tiny_arch()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        ServeConfig(batch_slots=4, max_seq=64,
                                    max_new_tokens=8))
    prompts = [np.arange(5) + i for i in range(6)]   # 6 requests, 4 slots
    reqs = eng.run(prompts)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out_tokens)


def test_serving_deterministic():
    cfg = _tiny_arch()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def run_once():
        eng = ServingEngine(model, params,
                            ServeConfig(batch_slots=2, max_seq=32,
                                        max_new_tokens=6))
        return [r.out_tokens for r in eng.run([np.arange(4), np.arange(3)])]

    assert run_once() == run_once()
