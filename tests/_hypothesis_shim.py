"""Deterministic stand-in for the slice of the hypothesis API this suite uses.

When the real ``hypothesis`` package is installed (see requirements-dev.txt)
it is always preferred — ``conftest.py`` only installs this module under the
name ``hypothesis`` when the import fails.  The shim keeps the property tests
meaningful without the dependency: each ``@given`` test is run against a
deterministic sample of the strategy space (boundary values first, then
seeded pseudo-random draws), so the suite collects and exercises the same
code paths everywhere, while full randomized runs remain available wherever
hypothesis is actually installed.

Only ``given``, ``settings``, ``strategies.integers`` and
``strategies.booleans`` are provided — exactly what the tests import.
"""

from __future__ import annotations

import inspect
import random
from typing import Any, Callable, List

_MAX_EXAMPLES_CAP = 25          # keep the dependency-free run fast
_SHIM_SEED = 0x5EED


class _Strategy:
    """A strategy = boundary examples + a seeded random draw."""

    def __init__(self, boundaries: List[Any], draw: Callable[[random.Random], Any]):
        self._boundaries = boundaries
        self._draw = draw

    def example(self, i: int, rng: random.Random) -> Any:
        if i < len(self._boundaries):
            return self._boundaries[i]
        return self._draw(rng)


class _StrategiesModule:
    @staticmethod
    def integers(min_value: int = None, max_value: int = None) -> _Strategy:
        lo = -(2 ** 63) if min_value is None else int(min_value)
        hi = 2 ** 63 - 1 if max_value is None else int(max_value)
        mid = min(max(0, lo), hi)
        bounds = list(dict.fromkeys([lo, hi, mid]))
        return _Strategy(bounds, lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)


strategies = _StrategiesModule()


def settings(**kw):
    """Decorator: record max_examples; deadline & friends are ignored."""

    def deco(fn):
        fn._shim_settings = dict(kw)
        return fn

    return deco


def given(*args, **strategy_kw):
    def deco(fn):
        if args:
            # hypothesis maps positional strategies to the *last* parameters
            params = [p for p in inspect.signature(fn).parameters]
            for name, strat in zip(params[len(params) - len(args):], args):
                strategy_kw.setdefault(name, strat)

        def wrapper(*a, **kw):
            opts = getattr(fn, "_shim_settings", None) \
                or getattr(wrapper, "_shim_settings", None) or {}
            n = min(int(opts.get("max_examples", 10)), _MAX_EXAMPLES_CAP)
            rng = random.Random(_SHIM_SEED)
            for i in range(n):
                drawn = {k: s.example(i, rng) for k, s in strategy_kw.items()}
                fn(*a, **kw, **drawn)

        # NOTE: no functools.wraps — pytest must see the (*a, **kw) signature,
        # not the original one, or it would try to inject the strategy
        # parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_shim = True
        return wrapper

    return deco


__all__ = ["given", "settings", "strategies"]
