"""Suite-wide setup: hypothesis fallback registration.

The property tests import ``hypothesis`` unconditionally.  CI and dev
environments install it from requirements-dev.txt; minimal containers (like
the tier-1 verify environment) may not have it.  This conftest runs before
any test module is imported, so when the real package is missing we register
``tests/_hypothesis_shim.py`` under the name ``hypothesis`` and the suite
still collects and runs deterministic samples of every property.
"""

import importlib.util
import os
import sys

try:
    import hypothesis  # noqa: F401  — real package wins when available
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
