"""Adaptive flow control: config validation, the static ramp's transient
bound (paper Sec. 3.4), BDP convergence, fairness, and checkpoint re-seeding.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CassandraLoader, Cluster, ConnectionPool,
                        FlowControlConfig, FlowController, KVStore,
                        LoaderConfig, MultiHostConfig, MultiHostRun,
                        merge_snapshots)
from repro.core.flowctl import FlowControllerGroup
from repro.core.netsim import RouteProfile, VirtualClock, route_bdp_samples
from repro.core.prefetcher import EpochPlan, PrefetchConfig, make_prefetcher
from repro.core.stats import windowed_series
from repro.data.datasets import SyntheticImageDataset, ingest

SAMPLE_BYTES = 115_621          # SyntheticImageDataset mean row size


@pytest.fixture(scope="module")
def store_uuids():
    return _shared_store()


_STORE_CACHE = None


def _shared_store():
    """Fixture-equivalent the @given property tests can call directly (the
    hypothesis shim's wrappers take no named params, so pytest cannot inject
    fixtures into them)."""
    global _STORE_CACHE
    if _STORE_CACHE is None:
        store = KVStore()
        uuids = ingest(store, SyntheticImageDataset(n_samples=30_000,
                                                    seed=11))
        _STORE_CACHE = (store, uuids)
    return _STORE_CACHE


# ---------------------------------------------------------------------------
# Shared windowed-throughput helper (the dedup target)
# ---------------------------------------------------------------------------

def test_windowed_series_buckets_and_gaps():
    events = [(0.1, 10.0), (0.4, 20.0), (1.6, 40.0)]
    out = windowed_series(events, window=0.5)
    # bucket 0: 30 units / 0.5 s; bucket [0.5, 1.5): empty; bucket 3: 40
    assert out == [(0.0, 60.0), (0.5, 0.0), (1.0, 0.0), (1.5, 80.0)]


def test_windowed_series_empty_and_bad_window():
    assert windowed_series([], window=0.5) == []
    with pytest.raises(ValueError, match="window must be positive"):
        windowed_series([(0.0, 1.0)], window=0.0)


def test_loader_and_connection_series_share_the_helper():
    """The three former copies now all route through windowed_series."""
    from repro.core.netsim import SimConnection
    from repro.core.stats import LoaderStats
    import inspect
    for obj in (SimConnection.throughput_series,
                LoaderStats.throughput_windows):
        assert "windowed_series" in inspect.getsource(obj)


# ---------------------------------------------------------------------------
# Config validation (fail at construction, not deep in the loop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,msg", [
    (dict(num_buffers=0), r"num_buffers must be >= 1, got 0"),
    (dict(num_buffers=-3), r"num_buffers must be >= 1, got -3"),
    (dict(ramp_every=0), r"ramp_every must be >= 1, got 0"),
    (dict(batch_size=0), r"batch_size must be >= 1, got 0"),
    (dict(flow_control="auto"), r"unknown flow_control mode 'auto'"),
])
def test_prefetch_config_validates_on_construction(kw, msg):
    with pytest.raises(ValueError, match=msg):
        PrefetchConfig(**kw)


@pytest.mark.parametrize("kw,msg", [
    (dict(floor_batches=0), r"floor_batches must be >= 1"),
    (dict(ceiling_batches=2, floor_batches=4),
     r"ceiling_batches \(2\) must be >= floor_batches \(4\)"),
    (dict(gain=0.0), r"gain must be positive"),
    (dict(beta=1.0), r"beta must be in \(0, 1\)"),
    (dict(rtt_inflation=1.0), r"rtt_inflation must be > 1"),
    (dict(rate_window=0.0), r"rate_window and rtt_window must be positive"),
    (dict(rate_buckets=1), r"rate_buckets must be >= 2"),
])
def test_flow_config_validates_on_construction(kw, msg):
    with pytest.raises(ValueError, match=msg):
        FlowControlConfig(**kw)


def test_loader_config_surfaces_prefetch_validation(store_uuids):
    store, uuids = store_uuids
    with pytest.raises(ValueError, match="num_buffers must be >= 1"):
        CassandraLoader(store, uuids[:100],
                        LoaderConfig(prefetch_buffers=0, route="low"))


# ---------------------------------------------------------------------------
# Static ramp: the paper's +1/ramp_every transient bound (Sec. 3.4)
# ---------------------------------------------------------------------------

def test_static_ramp_transient_bounded(store_uuids):
    """The static ramp's burst above steady state is never more than one
    extra batch per ``ramp_every`` consumed: depth == min(k, 1 + c//r), one
    batch of requests at t=0, and per-consume request bursts of at most 2B
    (1B replacement + 1B ramp step)."""
    store, uuids = store_uuids
    B, k, r = 64, 8, 4
    cfg = LoaderConfig(batch_size=B, prefetch_buffers=k, ramp_every=r,
                       io_threads=4, route="low", seed=7,
                       incremental_ramp=True)
    ld = CassandraLoader(store, uuids[:8000], cfg)
    ld.start()
    assert ld.prefetcher._target_depth() == 1
    assert ld.pool.requests_sent == B          # one batch at t=0, not k
    prev_depth, prev_sent = 1, ld.pool.requests_sent
    for c in range(1, 4 * r * k):
        ld.next_batch()
        depth = ld.prefetcher._target_depth()
        assert depth == min(k, 1 + c // r)     # the exact ramp law
        assert depth - prev_depth <= 1         # never jumps
        burst = ld.pool.requests_sent - prev_sent
        assert burst <= 2 * B                  # replacement + one ramp step
        if depth == prev_depth and depth == k:
            assert burst <= B                  # steady state: replacement only
        prev_depth, prev_sent = depth, ld.pool.requests_sent


# ---------------------------------------------------------------------------
# BDP convergence (property): arbitrary latency/bandwidth pairs
# ---------------------------------------------------------------------------

def _adaptive_prefetcher(store, uuids, profile, B, flow, seed=7):
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, rf=1,
                      seed=seed)
    pool = ConnectionPool(clock, cluster, profile, io_threads=2, seed=seed)
    ctl = pool.attach_flow_control(flow, B)
    plan = EpochPlan(list(uuids), seed=3)
    pf = make_prefetcher(clock, pool, plan,
                         PrefetchConfig(batch_size=B, flow_control="adaptive",
                                        flow=flow))
    pf.controller = ctl
    return pf, ctl


@given(rtt_ms=st.integers(1, 300), conn_mbps=st.integers(20, 500))
@settings(max_examples=10, deadline=None)
def test_budget_converges_to_route_bdp(rtt_ms, conn_mbps):
    """For arbitrary (latency, bandwidth) routes the steady-state budget
    lands within 2x of the true route BDP (clamped to floor/ceiling) and
    never exceeds the configured ceiling."""
    store, uuids = _shared_store()
    B = 64
    flow = FlowControlConfig(floor_batches=1, ceiling_batches=64)
    profile = RouteProfile(f"p{rtt_ms}_{conn_mbps}", rtt=rtt_ms / 1e3,
                           conn_capacity=conn_mbps * 1e6, loss_per_byte=0.0,
                           jitter=0.02)
    pf, ctl = _adaptive_prefetcher(store, uuids[:20_000], profile, B, flow)
    for _ in range(100):
        pf.next_batch(timeout=5000.0)
    # the analytic yardstick (io_threads=2 -> 4 connections)
    bdp = route_bdp_samples(profile, 4, SAMPLE_BYTES)
    expected = min(max(bdp, flow.floor_batches * B),
                   flow.ceiling_batches * B)
    budget = ctl.operating_budget()
    assert budget <= flow.ceiling_batches * B               # hard ceiling
    assert max(b for _, b in ctl.budget_trace) <= flow.ceiling_batches * B
    assert expected / 2 <= budget <= 2 * expected


# ---------------------------------------------------------------------------
# The headline invariants (small-scale twin of benchmarks/bench_ramp.py's
# flowctl section, which asserts the same from results/flowctl_ramp.json)
# ---------------------------------------------------------------------------

def _tput(store, uuids, route, mode, k, n_batches=70, B=256):
    cfg = LoaderConfig(batch_size=B, prefetch_buffers=k, io_threads=8,
                       route=route, seed=2, flow_control=mode)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    for _ in range(n_batches):
        ld.next_batch(timeout=3000.0)
    return ld.stats.throughput(skip=15), ld.flow_controller


def test_adaptive_matches_best_static_on_wan_route(store_uuids):
    """On the simulated 150 ms route the controller reaches >= 90% of the
    best static num_buffers from a sweep — with zero tuning."""
    store, uuids = store_uuids
    static = {k: _tput(store, uuids, "high", "static", k)[0]
              for k in (2, 8, 16, 32)}
    adaptive, ctl = _tput(store, uuids, "high", "adaptive", 8)
    best = max(static.values())
    assert adaptive >= 0.9 * best
    # ...while the shallow static depths are far off the mark (the knob the
    # controller removes really was load-bearing)
    assert static[2] < 0.5 * best


def test_adaptive_does_not_overbuffer_local_route(store_uuids):
    """On the ~0.05 ms local route the steady-state budget stays within 2x
    of the route's true BDP (in batches, floored at the one-batch minimum
    the assembler needs) instead of the static default's 8-16 buffers."""
    store, uuids = store_uuids
    B = 256
    adaptive, ctl = _tput(store, uuids, "local", "adaptive", 8, B=B)
    # the analytic yardstick (io_threads=8 -> 16 connections, NIC-bound)
    bdp_batches = max(1, math.ceil(route_bdp_samples("local", 16,
                                                     SAMPLE_BYTES) / B))
    assert ctl.depth() <= 2 * bdp_batches
    # and the shallow budget still delivers (>= 80% of an eager static-16)
    static, _ = _tput(store, uuids, "local", "static", 16, B=B)
    assert adaptive >= 0.8 * static


# ---------------------------------------------------------------------------
# Checkpoint: controller state rides the multi-host checkpoint
# ---------------------------------------------------------------------------

def _mh_cfg(n_hosts, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=128, io_threads=4,
                    route="med", n_nodes=4, replication_factor=2,
                    hedge_after=None, seed=9, flow_control="adaptive")
    defaults.update(kw)
    return MultiHostConfig(**defaults)


def test_flow_state_roundtrips_same_n(store_uuids):
    store, uuids = store_uuids
    cfg = _mh_cfg(2)
    run = MultiHostRun(store, uuids[:8000], cfg).start()
    rep = run.run(8)
    assert [f["depth_batches"] for f in rep["flow"]]      # reported
    ck = run.checkpoint()
    budgets = [ld.flow_controller.operating_budget()
               for ld in run.loaders]
    assert all("flow" in s for s in ck["shards"])
    assert all(s["flow"]["min_rtt"] > 0 for s in ck["shards"])

    res = MultiHostRun(store, uuids[:8000], cfg).start(ck)
    restored = [ld.flow_controller.operating_budget()
                for ld in res.loaders]
    assert restored == budgets                  # exact re-seed, no slow start
    res.run(2)                                  # and it keeps loading


def test_flow_state_reseeds_across_elastic_resize(store_uuids):
    """N -> M restore conserves the cluster-wide in-flight total: the N
    budgets merge and split M ways, so no host re-slow-starts from the
    floor against a warm cluster."""
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:8000], _mh_cfg(2)).start()
    run.run(8)
    ck = run.checkpoint()
    old = [ld.flow_controller.operating_budget() for ld in run.loaders]
    floor = run.loaders[0].flow_controller.cfg.floor_batches * 128

    run3 = MultiHostRun(store, uuids[:8000], _mh_cfg(3)).start(ck)
    new = [ld.flow_controller.operating_budget() for ld in run3.loaders]
    assert len(set(new)) == 1                   # all seeded from one merge
    assert new[0] > floor                       # not re-slow-starting
    assert abs(sum(new) - sum(old)) <= 3 * 128  # total conserved (+-rounding)
    run3.run(2)


def test_cross_shape_restore_federated_to_plain(store_uuids):
    """A federated checkpoint restored onto a non-federated adaptive run
    collapses the member snapshots (budgets sum, min-RTT mins) instead of
    silently re-starting from the floor."""
    from repro.core import ClusterSpec
    store, uuids = store_uuids
    fed = MultiHostConfig(
        n_hosts=2, batch_size=128, io_threads=4, hedge_after=None, seed=9,
        flow_control="adaptive", placement="cluster_aware",
        clusters=(ClusterSpec("near", route="local", n_nodes=2),
                  ClusterSpec("far", route="high", n_nodes=2)))
    run = MultiHostRun(store, uuids[:8000], fed).start()
    run.run(8)
    ck = run.checkpoint()
    assert "members" in ck["shards"][0]["flow"]

    plain = MultiHostRun(store, uuids[:8000], _mh_cfg(2)).start(ck)
    floor = plain.loaders[0].flow_controller.cfg.floor_batches * 128
    for ld in plain.loaders:
        assert ld.flow_controller.operating_budget() > floor
        assert ld.flow_controller.min_rtt() is not None
    plain.run(2)


def test_cross_shape_restore_plain_to_federated(store_uuids):
    """A single-cluster checkpoint restored onto a federated adaptive run
    splits the budget across the member controllers."""
    from repro.core import ClusterSpec
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:8000], _mh_cfg(2)).start()
    run.run(8)
    ck = run.checkpoint()
    total = sum(ld.flow_controller.operating_budget() for ld in run.loaders)

    fed = MultiHostConfig(
        n_hosts=2, batch_size=128, io_threads=4, hedge_after=None, seed=9,
        flow_control="adaptive", placement="cluster_aware",
        clusters=(ClusterSpec("near", route="local", n_nodes=2),
                  ClusterSpec("far", route="high", n_nodes=2)))
    frun = MultiHostRun(store, uuids[:8000], fed).start(ck)
    seeded = sum(ctl.operating_budget()
                 for ld in frun.loaders
                 for ctl in ld.flow_controller.members.values())
    # extensive quantities split across members; floors may round up
    assert seeded >= total * 0.5
    frun.run(2)


def test_retry_counters_are_per_window(store_uuids):
    """failovers / cluster_failovers report the run() window's delta, so a
    recovered outage stops showing up in later windows (matches the
    window-delta egress accounting and docs/BENCHMARKS.md)."""
    from repro.core import ClusterSpec
    store, uuids = store_uuids
    cfg = MultiHostConfig(
        n_hosts=2, batch_size=100, io_threads=4, hedge_after=1.0, seed=9,
        out_of_order=False, placement="cluster_aware",
        clusters=(ClusterSpec("us", route="low", n_nodes=2),
                  ClusterSpec("eu", route="med", n_nodes=2)))
    run = MultiHostRun(store, uuids[:4000], cfg).start()
    run.run(1)
    run.inject_cluster_outage("eu", after=0.0, recover_after=3.0)
    dark = run.run(4)
    assert dark["cluster_failovers"] > 0
    run.clock.sleep(4.0)                        # let eu recover
    warm = run.run(4)
    assert warm["cluster_failovers"] == 0       # window delta, not cumulative
    assert warm["failovers"] <= dark["failovers"]


def test_merge_snapshots_handles_federation_members():
    merged = merge_snapshots(
        [{"members": {"a": {"budget": 600.0, "probe_cap": 600.0,
                            "min_rtt": 0.1, "rate": 100.0,
                            "avg_bytes": 1e5}},
          },
         {"members": {"a": {"budget": 300.0, "probe_cap": 300.0,
                            "min_rtt": 0.2, "rate": 50.0,
                            "avg_bytes": 1e5}}}], new_count=3)
    a = merged["members"]["a"]
    assert a["budget"] == pytest.approx(450.0 * 2 / 3)
    assert a["min_rtt"] == pytest.approx(0.1)   # min over shards


def test_static_checkpoint_has_no_flow_state(store_uuids):
    """Static mode stays bit-identical to pre-flow-control checkpoints, and
    an adaptive run restores a static (flow-less) checkpoint gracefully."""
    store, uuids = store_uuids
    cfg = _mh_cfg(2, flow_control="static")
    run = MultiHostRun(store, uuids[:8000], cfg).start()
    run.run(2)
    ck = run.checkpoint()
    assert all("flow" not in s for s in ck["shards"])
    assert all(ld.flow_controller is None for ld in run.loaders)

    adaptive = MultiHostRun(store, uuids[:8000], _mh_cfg(2)).start(ck)
    adaptive.run(2)                             # fresh slow start, no crash


# ---------------------------------------------------------------------------
# Federation: one controller per member; shared ingress: fairness cap
# ---------------------------------------------------------------------------

def test_federation_wan_member_ramps_deep_local_stays_shallow(store_uuids):
    from repro.core import ClusterSpec
    store, uuids = store_uuids
    cfg = MultiHostConfig(
        n_hosts=1, batch_size=128, io_threads=4, hedge_after=None, seed=9,
        flow_control="adaptive", placement="cluster_aware",
        clusters=(ClusterSpec("near", route="local", n_nodes=2),
                  ClusterSpec("far", route="high", n_nodes=2)))
    run = MultiHostRun(store, uuids[:20_000], cfg).start()
    rep = run.run(60)
    members = rep["flow"][0]["members"]
    assert isinstance(run.loaders[0].flow_controller, FlowControllerGroup)
    # the 150 ms member needs a deep window; the local member must not copy it
    assert members["far"]["budget_samples"] > 4 * members["near"]["budget_samples"]
    assert members["near"]["depth_batches"] <= 2
    assert members["far"]["min_rtt_s"] > 0.1 > members["near"]["min_rtt_s"]


def test_shared_ingress_fairness_cap(store_uuids):
    """N adaptive hosts behind ONE client NIC converge to ~1/N shares: the
    limiter caps every budget at its fair-share BDP of the shared link."""
    store, uuids = store_uuids
    cfg = _mh_cfg(2, shared_client_ingress=True,
                  client_ingress_bandwidth=2e9, node_egress_bandwidth=6.25e9)
    run = MultiHostRun(store, uuids[:20_000], cfg).start()
    rep = run.run(20)
    assert run.limiter is not None
    assert rep["fairness"] > 0.8                # ~1/N shares
    budgets = [f["budget_samples"] for f in rep["flow"]]
    # every budget obeys the fair-share cap (gain x (bw/N) x min_rtt)
    for ld, b in zip(run.loaders, budgets):
        cap = run.limiter.fair_cap_samples(ld.flow_controller)
        floor = ld.flow_controller.cfg.floor_batches * 128
        assert b <= max(cap, floor) + 1


def test_shared_ingress_drained_host_share_redistributed(store_uuids):
    """Work conservation on the shared NIC: when one host stops pulling
    (drained / blocked on compute), it drops out of the active set after
    ``activity_window`` and the remaining host's fair cap grows to the full
    NIC instead of half — the equal-split blind spot this fixes."""
    store, uuids = store_uuids
    cfg = _mh_cfg(2, shared_client_ingress=True,
                  client_ingress_bandwidth=1e9)
    run = MultiHostRun(store, uuids[:20_000], cfg).start()
    run.run(8)                                   # both hosts loading
    lim = run.limiter
    ctl0 = run.loaders[0].flow_controller
    assert len(lim.active_members()) == 2
    contended_cap = lim.fair_cap_samples(ctl0)

    # host 1 goes idle: wait out the activity window, then only host 0 pulls
    run.clock.sleep(1.5 * lim.activity_window)
    t0, b0 = run.clock.now(), run.loaders[0].pool.bytes_received
    for _ in range(12):
        run.loaders[0].next_batch()
    solo_rate = ((run.loaders[0].pool.bytes_received - b0)
                 / (run.clock.now() - t0))
    assert lim.active_members() == [ctl0]        # host 1 aged out
    # full-NIC cap, exactly the single-member formula
    cap = lim.fair_cap_samples(ctl0)
    assert cap == pytest.approx(
        ctl0.cfg.gain * (lim.bandwidth / ctl0.avg_sample_bytes())
        * ctl0.min_rtt())
    assert cap > 1.6 * contended_cap
    # ...and the surviving host actually uses the freed share: its solo
    # rate clearly beats its half-NIC contended share
    assert solo_rate > 0.7 * lim.bandwidth


def test_shared_ingress_rejected_with_federation(store_uuids):
    from repro.core import ClusterSpec
    store, uuids = store_uuids
    cfg = MultiHostConfig(n_hosts=2, shared_client_ingress=True,
                          clusters=(ClusterSpec("a"),))
    with pytest.raises(ValueError, match="shared_client_ingress"):
        MultiHostRun(store, uuids[:500], cfg)


def test_budget_respects_tiny_ceiling(store_uuids):
    """A ceiling below the route BDP pins the budget at the ceiling."""
    store, uuids = store_uuids
    B = 64
    flow = FlowControlConfig(floor_batches=1, ceiling_batches=3)
    profile = RouteProfile("fat", rtt=0.100, conn_capacity=5e8,
                           loss_per_byte=0.0, jitter=0.02)
    pf, ctl = _adaptive_prefetcher(store, uuids[:20_000], profile, B, flow)
    for _ in range(40):
        pf.next_batch(timeout=5000.0)
    assert ctl.operating_budget() == 3 * B
    assert ctl.depth() == 3
    assert max(b for _, b in ctl.budget_trace) <= 3 * B


# ---------------------------------------------------------------------------
# Hedge accounting: on_hedge only when a duplicate request is actually sent
# ---------------------------------------------------------------------------

def _one_fetch_pool(conns_per_thread: int):
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=4, seed=2))
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, rf=1, seed=3)
    pool = ConnectionPool(clock, cluster, "high", io_threads=1,
                          conns_per_thread=conns_per_thread, seed=5,
                          hedge_after=0.01)
    ctl = pool.attach_flow_control(FlowControlConfig(), batch_size=8)
    done = []
    pool.fetch(uuids[0], done.append)
    assert clock.run_until(lambda: len(done) == 1, timeout=60.0)
    return pool, ctl


def test_hedge_suppressed_without_backup_connection_is_not_counted():
    """Regression: the hedge timer used to feed on_hedge *before* checking
    whether a duplicate could actually be sent, so a pool with no distinct
    backup connection (everything else excluded/dark) AIMD-backed-off the
    budget for a hedge that never happened."""
    pool, ctl = _one_fetch_pool(conns_per_thread=1)
    assert pool.requests_sent == 1          # nothing was duplicated...
    assert ctl.loss_signals == 0            # ...so no congestion signal


def test_hedge_that_fires_is_counted():
    pool, ctl = _one_fetch_pool(conns_per_thread=2)
    assert pool.requests_sent == 2          # duplicate actually sent
    assert ctl.loss_signals == 1
