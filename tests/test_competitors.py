"""Smoke tests for the baseline loader models (core/competitors.py).

These are the paper's Table 2/3 comparison baselines: a MosaicML-SD-style
record-shard streamer and a tf.data-service-style synchronous window.  The
tests pin the behaviours the comparison leans on — delivery, determinism,
degradation with distance, and compatibility with schedule-carrying
``RouteProfile``s (the post-PR-8 dynamic routes).
"""

import dataclasses

import pytest

from repro.core import Cluster, KVStore, VirtualClock
from repro.core.competitors import (RecordShardLoader, SyncWindowLoader,
                                    build_shards)
from repro.core.netsim import TIERS
from repro.data.datasets import SyntheticImageDataset, ingest


@pytest.fixture(scope="module")
def small_store():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=3000, seed=0))
    return store, uuids


def _sd(store, uuids, route, seed=0, batch_size=128):
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, seed=5)
    shards = build_shards(store, uuids, shard_bytes=8 * 2 ** 20)
    return RecordShardLoader(clock, cluster, route, shards,
                             batch_size=batch_size, predownload=4,
                             seed=seed).start()


def _sync(store, uuids, route, seed=0, batch_size=128):
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, seed=5)
    avg = int(sum(store.get_data(u).size for u in uuids) / len(uuids))
    return SyncWindowLoader(clock, cluster, route, avg_sample_bytes=avg,
                            batch_size=batch_size, seed=seed).start()


def test_build_shards_partitions_every_sample(small_store):
    store, uuids = small_store
    shards = build_shards(store, uuids, shard_bytes=4 * 2 ** 20)
    packed = [u for s in shards for u in s.uuids]
    assert packed == list(uuids)                   # storage order, rigid
    assert all(s.nbytes == sum(store.get_data(u).size for u in s.uuids)
               for s in shards)


def test_record_shard_loader_delivers_batches(small_store):
    store, uuids = small_store
    ld = _sd(store, uuids, "med")
    for _ in range(6):
        batch = ld.next_batch(timeout=3000.0)
        assert len(batch) == 128
        assert all(size > 0 for _, size in batch)
    assert ld.throughput(skip=2) > 0


def test_sync_window_loader_delivers_batches(small_store):
    store, uuids = small_store
    ld = _sync(store, uuids, "med")
    for _ in range(6):
        assert ld.next_batch(timeout=3000.0) == 128
    assert ld.throughput(skip=2) > 0


def test_both_baselines_degrade_with_distance(small_store):
    store, uuids = small_store

    def tput(make):
        ld = make()
        for _ in range(8):
            ld.next_batch(timeout=3000.0)
        return ld.throughput(skip=2)

    sd_local = tput(lambda: _sd(store, uuids, "local"))
    sd_high = tput(lambda: _sd(store, uuids, "high"))
    sync_local = tput(lambda: _sync(store, uuids, "local"))
    sync_high = tput(lambda: _sync(store, uuids, "high"))
    assert sd_high < sd_local
    # the sync window collapses with RTT (Table 3), SD merely degrades
    assert sync_high < 0.1 * sync_local


def test_record_shard_loader_is_deterministic(small_store):
    store, uuids = small_store

    def trace():
        ld = _sd(store, uuids, "med", seed=9)
        out = [tuple(ld.next_batch(timeout=3000.0)) for _ in range(4)]
        return out, ld.batch_consume_t

    assert trace() == trace()


def test_capped_route_keeps_schedule_fields(small_store):
    """The S3 stream cap is applied with dataclasses.replace — burst and
    schedule fields must survive (a positional rebuild once dropped them,
    silently pinning competitor runs to a static network)."""
    store, uuids = small_store
    route = dataclasses.replace(TIERS["high"], burst_factor=2.0,
                                burst_on_mean=0.5, burst_off_mean=0.5)
    ld = _sd(store, uuids, route)
    for _ in range(3):
        ld.next_batch(timeout=3000.0)
    assert ld.throughput(skip=1) > 0
