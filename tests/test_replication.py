"""Hot-key replication + bandwidth-aware ownership rebalancing.

Property coverage pins the two contracts the runtime-placement layer adds:

* ``FederatedRing.rebalance`` is a pure function of (weights, spare, step) —
  deterministic, total-weight-conserving, ownership stays disjoint and
  complete (every key has exactly one owner, replicas stay inside it);
* replica invalidation never yields a stale read: the version check at
  serve time holds under arbitrary interleavings of promotion, write and
  invalidation, and end-to-end across cluster-outage injection.

Delivery audits use the in-order/low-latency configuration so exact uuid
streams can be asserted; outage tests use hedging + OOO to cover the
failover machinery under realistic conditions (same split as
``tests/test_federation.py``).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterSpec, KVStore, MultiHostConfig, MultiHostRun,
                        ReplicationConfig, ZipfPlan)
from repro.core.federation import FederatedCluster, FederatedRing
from repro.core.kvstore import DataRow, MetaRow, make_uuid
from repro.core.netsim import VirtualClock
from repro.core.replication import HotKeyTracker, ReplicaCache
from repro.data.datasets import SyntheticImageDataset, ingest

SPECS = (ClusterSpec("onprem", route="local", n_nodes=4,
                     replication_factor=2),
         ClusterSpec("overseas", route="high", n_nodes=4,
                     replication_factor=2))


@pytest.fixture(scope="module")
def store_uuids():
    return _shared_store()


_STORE_CACHE = None


def _shared_store():
    """Fixture-equivalent the @given property tests can call directly."""
    global _STORE_CACHE
    if _STORE_CACHE is None:
        store = KVStore()
        uuids = ingest(store, SyntheticImageDataset(n_samples=6_000, seed=5))
        _STORE_CACHE = (store, uuids)
    return _STORE_CACHE


def _cfg(n_hosts=2, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=100, prefetch_buffers=4,
                    io_threads=4, hedge_after=1.0, seed=13,
                    placement="replication_aware", clusters=SPECS)
    defaults.update(kw)
    return MultiHostConfig(**defaults)


def _uuids(n, seed=7):
    rng = np.random.default_rng(seed)
    return [make_uuid(rng) for _ in range(n)]


def _meta(w_a, w_b):
    return [{"name": "a", "n_nodes": 4, "ring_seed": 1, "rf": 2,
             "weight": w_a},
            {"name": "b", "n_nodes": 4, "ring_seed": 2, "rf": 2,
             "weight": w_b}]


# ---------------------------------------------------------------------------
# FederatedRing.rebalance: deterministic, conserving, disjoint + complete
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(w_a=st.integers(min_value=1, max_value=8),
       w_b=st.integers(min_value=1, max_value=8),
       spare_a=st.integers(min_value=0, max_value=1000),
       spare_b=st.integers(min_value=0, max_value=1000),
       step_pct=st.integers(min_value=0, max_value=100))
def test_rebalance_deterministic_and_conserving(w_a, w_b, spare_a, spare_b,
                                                step_pct):
    ring = FederatedRing.from_metadata(_meta(w_a, w_b))
    spare = {"a": float(spare_a), "b": float(spare_b)}
    step = step_pct / 100.0
    r1 = ring.rebalance(spare, step=step)
    r2 = ring.rebalance(spare, step=step)
    assert r1.weights == r2.weights                 # pure function
    assert all(w >= 1 for w in r1.weights.values())
    if r1 is not ring:                              # an actual shift
        grain = FederatedRing.REBALANCE_GRAIN
        assert sum(r1.weights.values()) == (w_a + w_b) * grain
    # metadata() -> from_metadata() roundtrips the emitted map exactly
    rebuilt = FederatedRing.from_metadata(r1.metadata())
    assert rebuilt.weights == r1.weights


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       spare_a=st.integers(min_value=0, max_value=1000))
def test_rebalance_ownership_disjoint_complete(seed, spare_a):
    ring = FederatedRing.from_metadata(_meta(2, 2))
    shifted = ring.rebalance({"a": float(spare_a), "b": 0.0}, step=0.5)
    keys = _uuids(300, seed=seed)
    counts = {"a": 0, "b": 0}
    for u in keys:
        owner = shifted.owner_of(u)
        assert owner in ("a", "b")                  # complete
        counts[owner] += 1
        reps = shifted.replicas(u)
        assert reps and all(r.startswith(f"{owner}/") for r in reps)
    if spare_a > 0:
        # all the spare sits on "a": ownership must not shift *away* from it
        base = sum(1 for u in keys if ring.owner_of(u) == "a")
        assert counts["a"] >= base


def test_rebalance_validates_inputs():
    ring = FederatedRing.from_metadata(_meta(1, 1))
    with pytest.raises(ValueError, match="step must be in"):
        ring.rebalance({"a": 1.0}, step=1.5)
    assert ring.rebalance({"a": 0.0, "b": 0.0}, step=0.5) is ring
    assert ring.rebalance({"a": 5.0}, step=0.0) is ring


def test_rebalance_needs_adaptive_flow(store_uuids):
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids, _cfg()).start()
    with pytest.raises(ValueError, match="adaptive"):
        run.rebalance()


# ---------------------------------------------------------------------------
# HotKeyTracker: O(k) memory, windowed hotness
# ---------------------------------------------------------------------------

def test_tracker_space_saving_bound_and_hotness():
    clock = VirtualClock()
    cfg = ReplicationConfig(track_k=8, window=2.0, hot_rate=4.0, min_count=8)
    tr = HotKeyTracker(cfg, clock)
    cold = _uuids(100, seed=1)
    for u in cold:
        tr.record(u)
    assert len(tr) <= 8                     # space-saving memory bound
    hot = cold[0]
    for _ in range(50):
        tr.record(hot)
    assert tr.is_hot(hot)
    assert not tr.is_hot(cold[50])
    # hotness is windowed: once the accesses age out, the key cools off
    clock.schedule(10.0, lambda: None)
    clock.drain()
    tr.record(_uuids(1, seed=2)[0])         # roll the buckets forward
    assert tr.rate(hot) == 0.0
    assert not tr.is_hot(hot)
    # ...but the space-saving count survives (top-k is lifetime state)
    assert dict((str(k), c) for k, c, _ in tr.top(3))[str(hot)] >= 50


def test_tracker_snapshot_roundtrip():
    clock = VirtualClock()
    tr = HotKeyTracker(ReplicationConfig(track_k=4), clock)
    keys = _uuids(3, seed=9)
    for u in keys:
        for _ in range(5):
            tr.record(u)
    tr2 = HotKeyTracker(ReplicationConfig(track_k=4), clock)
    tr2.restore(tr.snapshot())
    assert tr2.snapshot() == tr.snapshot()


# ---------------------------------------------------------------------------
# ReplicaCache: promotion lifecycle, version guard, capacity
# ---------------------------------------------------------------------------

def test_cache_promotion_commit_and_version_guard():
    cache = ReplicaCache(capacity=2)
    k = _uuids(1)[0]
    tok = cache.begin_promotion(k, "onprem", version=0, now=0.0)
    assert tok is not None
    assert cache.serving_cluster(k, 0, now=0.1) is None     # not live yet
    cache.commit_promotion(k, tok)
    assert cache.serving_cluster(k, 0, now=0.2) == "onprem"
    # a write bumped the version: the entry must not serve, and is dropped
    assert cache.serving_cluster(k, 1, now=0.3) is None
    assert cache.stale_blocked == 1
    assert cache.get(k) is None


def test_cache_reservation_token_guards_races():
    cache = ReplicaCache(capacity=4)
    k = _uuids(1)[0]
    t1 = cache.begin_promotion(k, "onprem", version=0, now=0.0)
    cache.invalidate(k)                     # write-through won the race
    t2 = cache.begin_promotion(k, "onprem", version=1, now=0.1)
    cache.commit_promotion(k, t1)           # stale copy lands: must no-op
    assert cache.serving_cluster(k, 1, now=0.2) is None
    cache.commit_promotion(k, t2)
    assert cache.serving_cluster(k, 1, now=0.3) == "onprem"
    cache.release(k, t1)                    # stale abort: must no-op too
    assert cache.serving_cluster(k, 1, now=0.4) == "onprem"


def test_cache_capacity_evicts_coldest_live():
    cache = ReplicaCache(capacity=2)
    a, b, c = _uuids(3, seed=3)
    for key, t in ((a, 0.0), (b, 1.0)):
        cache.commit_promotion(key, cache.begin_promotion(key, "onprem", 0,
                                                          now=t))
    cache.serving_cluster(b, 0, now=2.0)    # b is warm, a is coldest
    assert cache.begin_promotion(c, "onprem", 0, now=3.0) is not None
    assert cache.get(a) is None and cache.get(b) is not None
    assert cache.evictions == 1


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_cache_never_serves_stale_version_under_random_ops(seed):
    """Model-checked invariant: whatever the interleaving of promotions,
    writes (version bumps + invalidation) and serves, a serve only ever
    succeeds at the key's current version."""
    rng = np.random.default_rng(seed)
    cache = ReplicaCache(capacity=4)
    keys = _uuids(6, seed=11)
    version = {k: 0 for k in keys}          # the model's source of truth
    pending = {}                            # key -> (token, version at begin)
    for t in range(120):
        k = keys[rng.integers(len(keys))]
        op = rng.integers(4)
        if op == 0:
            tok = cache.begin_promotion(k, "onprem", version[k], now=float(t))
            if tok is not None:
                pending[k] = (tok, version[k])
        elif op == 1 and k in pending:
            cache.commit_promotion(k, pending.pop(k)[0])
        elif op == 2:                       # write-through: bump + invalidate
            version[k] += 1
            cache.invalidate(k)
        else:
            got = cache.serving_cluster(k, version[k], now=float(t))
            if got is not None:
                e = cache.get(k)
                assert e is not None and e.version == version[k]


# ---------------------------------------------------------------------------
# ZipfPlan: the skewed workload class
# ---------------------------------------------------------------------------

def test_zipf_plan_deterministic_and_skewed():
    uuids = _uuids(500, seed=21)
    plan = ZipfPlan(uuids, seed=3, shard_id=0, num_shards=2, s=1.3)
    assert len(plan) == 250                 # uniform strip size
    assert plan.permutation(0) == plan.permutation(0)
    assert plan.permutation(0) != plan.permutation(1)
    # shards draw distinct streams over the SAME rank->key map
    other = ZipfPlan(uuids, seed=3, shard_id=1, num_shards=2, s=1.3)
    assert other.permutation(0) != plan.permutation(0)
    assert other._uuids == plan._uuids
    # skew: the top-ranked key dominates any mid-ranked one
    sample = plan.permutation(0) + plan.permutation(1) + other.permutation(0)
    top = plan._uuids[0]
    mid = plan._uuids[250]
    assert sample.count(top) > 10 * max(sample.count(mid), 1) \
        or sample.count(mid) == 0


def test_zipf_plan_advance_and_overrides():
    plan = ZipfPlan(_uuids(100, seed=2), seed=0, shard_id=0, num_shards=4)
    assert plan.advance(1, 20, 30) == (3, 0)        # 25-sample epochs
    with pytest.raises(ValueError, match="negative cursor"):
        plan.advance(0, -1)
    with pytest.raises(ValueError, match="overrides"):
        plan.install_overrides({0: []})
    assert plan.pending_overrides(0) == {}


def test_zipf_checkpoint_resumes_exactly(store_uuids):
    store, uuids = store_uuids
    fast = dict(out_of_order=False, hedge_after=None, sampling="zipf",
                zipf_s=1.2, placement="cluster_aware")
    a = MultiHostRun(store, uuids, _cfg(**fast)).start()
    a.run(4)
    ck = a.checkpoint()
    tail_a, tail_b = [], []
    a.run(3, on_batch=lambda h, b: tail_a.extend(str(u) for u in b.uuids))
    b = MultiHostRun(store, uuids, _cfg(**fast)).start(ck)
    b.run(3, on_batch=lambda h, b: tail_b.extend(str(u) for u in b.uuids))
    assert tail_a == tail_b                 # bit-identical resume


def test_zipf_elastic_restore_restarts_at_epoch_boundary(store_uuids):
    store, uuids = store_uuids
    fast = dict(out_of_order=False, hedge_after=None, sampling="zipf",
                zipf_s=1.2, placement="cluster_aware")
    a = MultiHostRun(store, uuids, _cfg(n_hosts=2, **fast)).start()
    a.run(4)
    ck = a.checkpoint()
    b = MultiHostRun(store, uuids, _cfg(n_hosts=3, **fast)).start(ck)
    rep = b.run(4)
    assert rep["rounds"] == 4               # all batches delivered on 3 hosts


# ---------------------------------------------------------------------------
# End to end: serving, promotion, reports, checkpoints, outages
# ---------------------------------------------------------------------------

def test_replication_serves_hot_keys_and_reports(store_uuids):
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids, _cfg(sampling="zipf", zipf_s=1.3))
    rep = run.run(10)
    assert rep["replica_hit_frac"] > 0.1
    assert rep["wan_bytes_saved"] > 0
    assert rep["replication"]["promotions"] > 0
    assert rep["replication"]["cached_keys"] > 0
    # promotion has a real WAN cost, visible in the accounting
    assert rep["replication"]["promotion_wan_bytes"] > 0


def test_replication_requires_federation(store_uuids):
    store, uuids = store_uuids
    with pytest.raises(ValueError, match="needs a federation"):
        MultiHostRun(store, uuids,
                     MultiHostConfig(n_hosts=2, placement="replication_aware"))
    with pytest.raises(ValueError, match="needs a federation"):
        MultiHostRun(store, uuids,
                     MultiHostConfig(n_hosts=2,
                                     replication=ReplicationConfig()))


def test_exactly_once_preserved_with_replication_and_outage(store_uuids):
    """Uniform sampling + replica serving: epoch 0 still delivers every
    uuid exactly once while the region cluster (the one holding the
    replicas) goes dark mid-run — replica-served fetches fail over to the
    home cluster under the same once-guard as everything else.  Replicas
    are pre-promoted so the uniform (once-per-epoch) access pattern
    actually serves through the cache from the first round."""
    store, uuids = store_uuids
    subset = uuids[:1200]
    # in-order assembly: batch.epoch labels are exact, so the audit can
    # assert set equality (the once-guard under test is in the pools and
    # identical for both prefetchers)
    run = MultiHostRun(store, subset, _cfg(
        replication=ReplicationConfig(capacity=2000),
        placement="cluster_aware", out_of_order=False))
    fed = run.federation
    promoted = 0
    for u in subset:
        if fed.owner_of(u) == "overseas":
            tok = fed.replication.cache.begin_promotion(
                u, "onprem", fed.version_of(u), now=0.0)
            fed.replication.cache.commit_promotion(u, tok)
            promoted += 1
    assert promoted > 300                   # ~half the keyspace is cached
    run.start()
    delivered = {}

    def on_batch(host_id, batch):
        delivered.setdefault(batch.epoch, []).extend(
            str(u) for u in batch.uuids)

    run.run(1, on_batch=on_batch)
    run.inject_cluster_outage("onprem", after=0.0, recover_after=1.5)
    run.run(5, on_batch=on_batch)           # finishes epoch 0 (6x2x100)
    assert fed.replication.cache.hits > 0   # replica serving participated
    assert len(delivered[0]) == len(set(delivered[0])) == 1200


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1_000))
def test_no_stale_read_across_outage_and_writes(seed):
    """The satellite property: replica invalidation never yields a stale
    read, across cluster-outage injection.  Writes bump key versions
    mid-run while the home cluster flaps; at every point any cache entry
    that serves must hold the key's current version."""
    store, uuids = _shared_store()
    run = MultiHostRun(store, uuids, _cfg(
        seed=seed, sampling="zipf", zipf_s=1.3,
        replication=ReplicationConfig(hot_rate=1.0, min_count=2))).start()
    fed = run.federation
    run.run(4)
    run.inject_cluster_outage("overseas", after=0.0, recover_after=2.0)
    # write through every currently-replicated key (and a few cold ones):
    # versions bump, replicas must drop
    targets = fed.replication.cache.keys()[:8] + uuids[:2]
    for u in targets:
        row = store.get_data(u)
        fed.write_through(DataRow(u, row.label, row.size),
                          MetaRow(u, entity_id="w", label=row.label))
        assert fed.replication.cache.get(u) is None
    run.run(4)
    # whatever got (re-)promoted since serves the *current* version
    for u in fed.replication.cache.keys():
        entry = fed.replication.cache.get(u)
        if entry.live:
            assert entry.version == fed.version_of(u)
    rep = run.run(2)
    assert rep["rounds"] == 2               # still delivering after all that


def test_replication_snapshot_rides_elastic_checkpoint(store_uuids):
    store, uuids = store_uuids
    a = MultiHostRun(store, uuids, _cfg(sampling="zipf", zipf_s=1.3))
    a.run(10)
    ck = a.checkpoint()
    assert ck["replication"]["cache"]       # something was promoted
    b = MultiHostRun(store, uuids, _cfg(n_hosts=3, sampling="zipf",
                                        zipf_s=1.3)).start(ck)
    restored = b.federation.replication.cache
    assert sorted(restored.snapshot()) == sorted(ck["replication"]["cache"])
    rep = b.run(4)
    assert rep["replica_hit_frac"] > 0.0    # restored replicas serve at once


def test_rebalanced_ownership_rides_checkpoint(store_uuids):
    store, uuids = store_uuids
    cfg = _cfg(placement="cluster_aware", flow_control="adaptive",
               hedge_after=None)
    a = MultiHostRun(store, uuids, cfg).start()
    a.run(6)
    weights = a.rebalance(step=0.3)
    assert a.federation.routing_ring.weights == weights
    ck = a.checkpoint()
    assert ck["ownership"]
    b = MultiHostRun(store, uuids, cfg).start(ck)
    assert b.federation.routing_ring.weights == weights
    # the declared ring (strip metadata) is untouched by the rebalance
    assert ck["federation"] == b.federation.ring.metadata()
    rep = b.run(2)
    assert rep["ownership_weights"] == weights
