"""Prefetcher behaviour: ordering, coverage, ramp, checkpointing."""

import numpy as np
import pytest

from repro.core import (CassandraLoader, KVStore, LoaderConfig, EpochPlan)
from repro.data.datasets import SyntheticImageDataset, ingest


@pytest.fixture(scope="module")
def small_store():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=4096, seed=3))
    return store, uuids


def _loader(store, uuids, **kw):
    defaults = dict(batch_size=64, prefetch_buffers=4, io_threads=4,
                    route="low", backend="scylla", seed=7)
    defaults.update(kw)
    return CassandraLoader(store, uuids, LoaderConfig(**defaults))


def test_epoch_plan_is_uniform_permutation():
    rng = np.random.default_rng(0)
    from repro.core.kvstore import make_uuid
    uuids = [make_uuid(rng) for _ in range(100)]
    plan = EpochPlan(uuids, seed=1)
    p0, p1 = plan.permutation(0), plan.permutation(1)
    assert sorted(map(str, p0)) == sorted(map(str, uuids))
    assert p0 != p1                    # reshuffled across epochs
    assert plan.permutation(0) == p0   # deterministic


def test_epoch_plan_sharding_partitions():
    rng = np.random.default_rng(0)
    from repro.core.kvstore import make_uuid
    uuids = [make_uuid(rng) for _ in range(100)]
    shards = [EpochPlan(uuids, seed=1, shard_id=i, num_shards=4) for i in range(4)]
    all_ids = [u for s in shards for u in s._uuids]
    assert sorted(map(str, all_ids)) == sorted(map(str, uuids))


def test_in_order_delivers_plan_order(small_store):
    store, uuids = small_store
    ld = _loader(store, uuids, out_of_order=False, batch_size=32)
    ld.start()
    plan = ld.plan.permutation(0)
    got = []
    for _ in range(4):
        got.extend(ld.next_batch().uuids)
    assert got == plan[:len(got)]


def test_ooo_covers_issued_prefix(small_store):
    """OOO delivers exactly the issued samples, just reordered by arrival."""
    store, uuids = small_store
    ld = _loader(store, uuids, out_of_order=True, batch_size=32, route="high")
    ld.start()
    got = []
    for _ in range(8):
        got.extend(str(u) for u in ld.next_batch().uuids)
    plan = [str(u) for u in ld.plan.permutation(0)]
    # everything delivered was issued from the plan prefix (no dupes, no inventions)
    assert len(set(got)) == len(got)
    prefix = set(plan[:len(got) + ld.cfg.prefetch_buffers * 32 + 64])
    assert set(got) <= prefix


def test_ooo_batches_are_full_size(small_store):
    store, uuids = small_store
    ld = _loader(store, uuids, out_of_order=True, batch_size=48)
    ld.start()
    for _ in range(5):
        assert len(ld.next_batch().samples) == 48


def test_incremental_ramp_limits_initial_burst(small_store):
    store, uuids = small_store
    ld_eager = _loader(store, uuids, incremental_ramp=False, prefetch_buffers=8)
    ld_ramp = _loader(store, uuids, incremental_ramp=True, prefetch_buffers=8)
    ld_eager.start()
    ld_ramp.start()
    # before any consumption: eager has k batches in flight, ramped has 1
    assert ld_eager.pool.requests_sent == 8 * 64
    assert ld_ramp.pool.requests_sent == 1 * 64


def test_ramp_reaches_full_depth(small_store):
    store, uuids = small_store
    ld = _loader(store, uuids, incremental_ramp=True, prefetch_buffers=4)
    ld.start()
    for _ in range(20):
        ld.next_batch()
    # after ramp_every*k consumes the target depth must be k
    assert ld.prefetcher._target_depth() == 4


def test_labels_travel_with_features(small_store):
    store, uuids = small_store
    ld = _loader(store, uuids)
    ld.start()
    batch = ld.next_batch()
    for s in batch.samples:
        assert s.label == store.get_data(s.uuid).label


def test_checkpoint_state_roundtrip(small_store):
    store, uuids = small_store
    ld = _loader(store, uuids, batch_size=32)
    ld.start()
    for _ in range(10):
        ld.next_batch()
    st = ld.state()
    assert st["consumed"] == 10
    assert st["epoch"] == 0 and st["cursor"] == 320
    # restart from the recorded position: first delivered batch continues the plan
    ld2 = _loader(store, uuids, batch_size=32, out_of_order=False)
    ld2.start(epoch=st["epoch"], cursor=st["cursor"])
    nxt = ld2.next_batch().uuids
    assert nxt == ld2.plan.permutation(0)[320:352]


def test_epoch_rollover(small_store):
    store, uuids = small_store
    few = uuids[:128]
    ld = _loader(store, few, batch_size=32, out_of_order=False)
    ld.start()
    for _ in range(4):
        ld.next_batch()
    b = ld.next_batch()           # first batch of epoch 1
    assert b.epoch == 1
    assert ld.state()["epoch"] == 1


def test_throughput_ooo_beats_inorder_at_high_latency():
    # paper-scale config (Fig. 4/5): 32 connections, 16 buffers, B=512
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=100000, seed=5))
    from repro.core import tight_loop
    res = {}
    for ooo in (True, False):
        cfg = LoaderConfig(batch_size=512, prefetch_buffers=16, io_threads=16,
                           out_of_order=ooo, route="high", backend="scylla", seed=2)
        res[ooo] = tight_loop(CassandraLoader(store, uuids, cfg), n_batches=150)
    assert res[True]["throughput_Bps"] > 1.3 * res[False]["throughput_Bps"]
    # and OOO batch times are far more stable (paper Fig. 4)
    assert res[True]["batch_times"].max() < res[False]["batch_times"].max()
