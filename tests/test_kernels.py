"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,S,D,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),      # MHA
    (2, 8, 2, 256, 64, 128, 128),    # GQA
    (1, 4, 2, 96, 32, 64, 64),       # padded (non-multiple) seq
    (1, 2, 1, 128, 128, 64, 32),     # rectangular blocks
])
def test_flash_attention_sweep(dtype, B, H, K, S, D, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, K, S, D), dtype)
    v = jax.random.normal(ks[2], (B, K, S, D), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 100])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,K,G,T,D,bk", [
    (2, 2, 2, 256, 64, 128),
    (1, 4, 1, 100, 32, 64),          # padded T
    (3, 1, 8, 512, 128, 256),
])
def test_flash_decode_sweep(dtype, B, K, G, T, D, bk):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, K, G, D), dtype)
    k = jax.random.normal(ks[1], (B, K, T, D), dtype)
    v = jax.random.normal(ks[2], (B, K, T, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    got = ops.flash_decode(q, k, v, lengths, block_k=bk)
    want = ref.decode_reference(q.reshape(B, K * G, D), k, v, lengths)
    np.testing.assert_allclose(np.asarray(got.reshape(B, K * G, D), np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@given(oy=st.integers(0, 15), ox=st.integers(0, 15),
       mirror=st.booleans(), out_h=st.integers(4, 17), out_w=st.integers(4, 17))
@settings(max_examples=20, deadline=None)
def test_crop_mirror_normalize_property(oy, ox, mirror, out_h, out_w):
    img = jax.random.randint(jax.random.PRNGKey(3), (2, 32, 32, 3), 0, 256
                             ).astype(jnp.uint8)
    oys = jnp.array([oy, (oy + 5) % 16])
    oxs = jnp.array([ox, (ox + 3) % 16])
    mir = jnp.array([mirror, not mirror])
    mean = jnp.array([120.0, 115.0, 100.0])
    std = jnp.array([60.0, 61.0, 62.0])
    got = ops.crop_mirror_normalize(img, oys, oxs, mir, mean, std,
                                    out_h=out_h, out_w=out_w)
    want = ref.crop_mirror_normalize_reference(img, oys, oxs, mir, mean, std,
                                               out_h, out_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2 ** 16), mirror=st.booleans(),
       out_h=st.integers(4, 24), out_w=st.integers(4, 24))
@settings(max_examples=20, deadline=None)
def test_crop_mirror_normalize_matches_numpy_ref(seed, mirror, out_h, out_w):
    """Kernel == pure-NumPy reference on uint8 data that includes the edge
    values 0 and 255 (where a uint8->f32 conversion bug would show)."""
    rng = np.random.default_rng(seed)
    B, H, W, C = 3, 24, 24, 3
    img = rng.integers(0, 256, size=(B, H, W, C)).astype(np.uint8)
    img[0, 0, 0, :] = 0
    img[0, -1, -1, :] = 255
    img[1] = 255                                   # saturated frame
    oy = rng.integers(0, H - out_h + 1, size=B).astype(np.int32)
    ox = rng.integers(0, W - out_w + 1, size=B).astype(np.int32)
    mir = np.array([mirror, not mirror, mirror], dtype=np.int32)
    mean = np.array([120.0, 115.0, 100.0], dtype=np.float32)
    std = np.array([60.0, 61.0, 62.0], dtype=np.float32)
    got = ops.crop_mirror_normalize(
        jnp.asarray(img), jnp.asarray(oy), jnp.asarray(ox), jnp.asarray(mir),
        jnp.asarray(mean), jnp.asarray(std), out_h=out_h, out_w=out_w)
    want = ref.crop_mirror_normalize_np(img, oy, ox, mir, mean, std,
                                        out_h, out_w)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)


def test_crop_mirror_normalize_clamps_offsets():
    """Out-of-range crop offsets degrade to edge crops in BOTH the kernel
    and the NumPy reference (same clamping semantics)."""
    rng = np.random.default_rng(7)
    img = rng.integers(0, 256, size=(2, 16, 16, 3)).astype(np.uint8)
    oy = np.array([100, -5], dtype=np.int32)       # way past both edges
    ox = np.array([-3, 99], dtype=np.int32)
    mir = np.zeros(2, dtype=np.int32)
    mean = np.zeros(3, dtype=np.float32)
    std = np.ones(3, dtype=np.float32)
    got = ops.crop_mirror_normalize(
        jnp.asarray(img), jnp.asarray(oy), jnp.asarray(ox), jnp.asarray(mir),
        jnp.asarray(mean), jnp.asarray(std), out_h=8, out_w=8)
    want = ref.crop_mirror_normalize_np(img, oy, ox, mir, mean, std, 8, 8)
    clamped = ref.crop_mirror_normalize_np(
        img, np.array([8, 0]), np.array([0, 8]), mir, mean, std, 8, 8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(want, clamped, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,d,f,bc,bf,bd", [
    (4, 64, 96, 64, 32, 32, 32),
    (2, 100, 64, 48, 64, 16, 64),    # padded C/f
    (8, 32, 128, 128, 32, 128, 128),
])
def test_grouped_matmul_sweep(dtype, E, C, d, f, bc, bf, bd):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, f), dtype)
    got = ops.grouped_matmul(x, w, block_c=bc, block_f=bf, block_d=bd)
    want = ref.gmm_reference(x, w)
    tol = dict(rtol=5e-2, atol=5e-1) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_flash_attention_matches_model_chunked_path():
    """Kernel and the XLA chunked path implement the same math."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, H, K, D = 1, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    xla = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    pallas = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(pallas.transpose(0, 2, 1, 3)),
                               np.asarray(xla), rtol=2e-5, atol=2e-5)
