"""build_stack facade + the normalized kwarg surface (PR 10).

Two properties matter:

* **equivalence** — a ``build_stack`` stack is bit-identical to the
  hand-wired chain it replaces (same constructors, same seeds, nothing
  added), so porting the benches/examples to the facade moved no numbers;
* **validation up front** — bad feed kinds, missing feed parameters and
  config/kwarg combinations the stack cannot serve raise at construction,
  not deep inside the first ``next_batch``.
"""

import warnings

import pytest

from repro.core import (CassandraLoader, ConnectionPool, Cluster, KVStore,
                        LoaderConfig, MultiHostConfig, MultiHostRun, Stack,
                        VirtualClock, build_stack)
from repro.core import connection as _connection
from repro.core.wirefmt import get_codec
from repro.data.datasets import SyntheticImageDataset, ingest


@pytest.fixture(scope="module")
def small_store():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=2500, seed=0))
    return store, uuids


def _cfg(**kw):
    defaults = dict(batch_size=64, prefetch_buffers=4, io_threads=4,
                    route="low", seed=3)
    defaults.update(kw)
    return LoaderConfig(**defaults)


# -- equivalence ------------------------------------------------------------

def test_single_host_stack_is_bit_identical_to_hand_wiring(small_store):
    store, uuids = small_store

    def consume(loader):
        loader.start()
        for _ in range(8):
            loader.next_batch()
        return list(loader.stats.batch_times(skip=0)), loader.clock.now()

    hand = consume(CassandraLoader(store, uuids, _cfg()))
    stacked = consume(build_stack(store=store, uuids=uuids,
                                  config=_cfg()).loader)
    assert hand == stacked              # every float, exactly


def test_stack_exposes_every_layer(small_store):
    store, uuids = small_store
    stack = build_stack(store=store, uuids=uuids, config=_cfg(), start=True)
    assert isinstance(stack, Stack)
    assert stack.loader is stack.loaders[0]
    assert stack.pool is stack.loader.pool
    assert stack.cluster is stack.loader.cluster
    assert stack.run is None and stack.feed is None
    batch = stack.next_batch()
    assert len(batch.samples) == 64
    stack.close()


def test_shared_clock_cluster_ingress_passthrough(small_store):
    store, uuids = small_store
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=2, seed=8)
    s1 = build_stack(store=store, uuids=uuids, config=_cfg(shard_id=0,
                                                           num_shards=2),
                     clock=clock, cluster=cluster)
    s2 = build_stack(store=store, uuids=uuids, config=_cfg(shard_id=1,
                                                           num_shards=2),
                     clock=clock, cluster=cluster)
    assert s1.clock is clock and s2.clock is clock
    assert s1.cluster is cluster and s2.cluster is cluster
    s1.loader.start(), s2.loader.start()
    b1, b2 = s1.next_batch(), s2.next_batch()
    assert not set(b1.uuids) & set(b2.uuids)      # disjoint shards


def test_multihost_stack_builds_run(small_store):
    store, uuids = small_store
    cfg = MultiHostConfig(n_hosts=2, batch_size=64, prefetch_buffers=2,
                          io_threads=2, route="low", n_nodes=2, seed=4)
    stack = build_stack(store=store, uuids=uuids, config=cfg, start=True)
    assert isinstance(stack.run, MultiHostRun)
    assert len(stack.loaders) == 2
    rep = stack.run.run(2)
    assert rep["aggregate_Bps"] > 0
    with pytest.raises(RuntimeError, match="single-host convenience"):
        stack.next_batch()


# -- validation up front ----------------------------------------------------

def test_unknown_feed_kind_rejected(small_store):
    store, uuids = small_store
    with pytest.raises(ValueError, match="unknown feed kind"):
        build_stack(store=store, uuids=uuids, config=_cfg(), feed="tfrecord")


def test_feed_needs_materialize(small_store):
    store, uuids = small_store
    with pytest.raises(ValueError, match="materialize=True"):
        build_stack(store=store, uuids=uuids, config=_cfg(), feed="device",
                    seq_len=16)


def test_device_feed_needs_seq_len(small_store):
    store, uuids = small_store
    with pytest.raises(ValueError, match="seq_len"):
        build_stack(store=store, uuids=uuids,
                    config=_cfg(materialize=True), feed="device")


def test_image_feed_needs_shapes(small_store):
    store, uuids = small_store
    with pytest.raises(ValueError, match="image_shape"):
        build_stack(store=store, uuids=uuids,
                    config=_cfg(materialize=True), feed="image")


def test_multihost_rejects_feed_and_external_pieces(small_store):
    store, uuids = small_store
    cfg = MultiHostConfig(n_hosts=2, batch_size=64, route="low", n_nodes=2)
    with pytest.raises(ValueError, match="MultiHostConfig"):
        build_stack(store=store, uuids=uuids, config=cfg, feed="device",
                    seq_len=16)
    with pytest.raises(ValueError, match="single-host only"):
        build_stack(store=store, uuids=uuids, config=cfg,
                    clock=VirtualClock())


def test_unknown_config_type_rejected(small_store):
    store, uuids = small_store
    with pytest.raises(TypeError, match="LoaderConfig or MultiHostConfig"):
        build_stack(store=store, uuids=uuids, config={"route": "high"})


# -- normalized kwarg surface ----------------------------------------------

def test_connection_pool_codec_alias_warns_once(small_store):
    store, _ = small_store
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, seed=1)
    _connection._codec_alias_warned = False       # isolate from test order
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        pool = ConnectionPool(clock, cluster, "low", io_threads=1,
                              codec="byteshuffle")
        ConnectionPool(clock, cluster, "low", io_threads=1, codec="int8")
    deprecations = [x for x in w if issubclass(x.category,
                                               DeprecationWarning)]
    assert len(deprecations) == 1                 # warn-once per process
    assert "wire_codec" in str(deprecations[0].message)
    assert pool.codec.name == get_codec("byteshuffle").name


def test_connection_pool_rejects_both_codec_spellings(small_store):
    store, _ = small_store
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", n_nodes=1, seed=1)
    with pytest.raises(TypeError, match="deprecated alias"):
        ConnectionPool(clock, cluster, "low", io_threads=1,
                       wire_codec="byteshuffle", codec="byteshuffle")


def test_multihost_kwarg_validation(small_store):
    store, uuids = small_store

    def mh(**kw):
        defaults = dict(n_hosts=2, batch_size=64, route="low", n_nodes=2)
        defaults.update(kw)
        return MultiHostRun(store, uuids, MultiHostConfig(**defaults))

    with pytest.raises(ValueError, match="wire_codec="):
        mh(wire_codec="auto")                     # needs a federation
    with pytest.raises(ValueError, match="io_scaling"):
        mh(io_scaling=True)                       # needs adaptive flow
    with pytest.raises(ValueError, match="use_arena"):
        mh(use_arena=True)                        # needs materialize
