"""Automatic split creation — hypothesis property tests."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (KVStore, MetaRow, SplitSpec, check_entity_independence,
                        create_splits, make_uuid)


def _meta_rows(n_samples, n_entities, n_classes, seed):
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(n_samples):
        rows.append(MetaRow(make_uuid(rng), f"e{int(rng.integers(n_entities))}",
                            int(rng.integers(n_classes))))
    return rows


@given(n_samples=st.integers(200, 800),
       n_entities=st.integers(20, 120),
       n_classes=st.integers(2, 10),
       seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_entity_independence_always_holds(n_samples, n_entities, n_classes, seed):
    rows = _meta_rows(n_samples, n_entities, n_classes, seed)
    splits = create_splits(rows, SplitSpec(fractions=(0.8, 0.1, 0.1), seed=seed))
    assert check_entity_independence(rows, splits)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_all_samples_assigned_exactly_once(seed):
    rows = _meta_rows(500, 60, 4, seed)
    splits = create_splits(rows, SplitSpec(fractions=(0.7, 0.3), seed=seed))
    assigned = [u for us in splits.values() for u in us]
    assert len(assigned) == len(rows)
    assert len(set(assigned)) == len(rows)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_split_fractions_approximately_met(seed):
    # many small entities => fractions achievable within entity granularity
    rows = _meta_rows(2000, 500, 5, seed)
    spec = SplitSpec(fractions=(0.8, 0.1, 0.1), seed=seed)
    splits = create_splits(rows, spec)
    for frac, name in zip(spec.fractions, spec.names):
        got = len(splits[name]) / len(rows)
        assert abs(got - frac) < 0.05


def test_class_mix_approximately_uniform_across_splits():
    rows = _meta_rows(3000, 600, 3, seed=0)
    splits = create_splits(rows, SplitSpec(fractions=(0.5, 0.5), seed=0))
    by_uuid = {r.uuid: r for r in rows}
    mixes = []
    for name, us in splits.items():
        counts = np.zeros(3)
        for u in us:
            counts[by_uuid[u].label] += 1
        mixes.append(counts / counts.sum())
    assert np.abs(mixes[0] - mixes[1]).max() < 0.06


def test_deterministic_given_seed():
    rows = _meta_rows(400, 50, 4, seed=1)
    a = create_splits(rows, SplitSpec(fractions=(0.8, 0.2), seed=9))
    b = create_splits(rows, SplitSpec(fractions=(0.8, 0.2), seed=9))
    assert a == b
