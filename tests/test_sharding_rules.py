"""Sharding rules engine: pure-logic tests with a stub mesh."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.sharding.rules import rules_for_profile, spec_for


class StubMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH = StubMesh((16, 16), ("data", "model"))
POD_MESH = StubMesh((2, 16, 16), ("pod", "data", "model"))
TP = rules_for_profile("tp")
FSDP = rules_for_profile("fsdp_tp")


def test_embedding_vocab_sharded():
    spec = spec_for(("vocab", "d_model"), (151936, 2560), MESH, TP)
    assert spec == PartitionSpec("model", None)


def test_embedding_fsdp_both_axes():
    spec = spec_for(("vocab", "d_model"), (151936, 5120), MESH, FSDP)
    assert spec == PartitionSpec("model", "data")


def test_heads_sharded_when_divisible():
    spec = spec_for(("d_model", "heads", "head_dim"), (2560, 32, 128),
                    MESH, TP)
    assert spec == PartitionSpec(None, "model", None)


def test_nondivisible_heads_fall_back():
    # 25 heads on a 16-way axis: heads replicate, head_dim gets the
    # last-resort model rule only if divisible (64 % 16 == 0 -> sharded)
    spec = spec_for(("d_model", "heads", "head_dim"), (1600, 25, 64),
                    MESH, TP)
    assert spec == PartitionSpec(None, None, "model")


def test_batch_over_pod_and_data():
    spec = spec_for(("batch", "seq"), (256, 4096), POD_MESH, TP)
    assert spec == PartitionSpec(("pod", "data"), None)


def test_batch_fallback_to_data_only():
    # batch=8 cannot shard over 32 pods*data but can over 16? 8 < 16 -> no;
    # candidate list tries (pod,data)=32 then (data,)=16; 8 fails both
    spec = spec_for(("batch", "d_model"), (8, 64), POD_MESH, TP)
    assert spec[0] is None


def test_kv_cache_prefers_heads_then_seq():
    # kv_heads=32 divisible -> heads win, kv_seq stays unsharded
    spec = spec_for(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                    (24, 128, 32768, 32, 64), MESH, TP)
    assert spec == PartitionSpec(None, "data", None, "model", None)
    # kv_heads=8 not divisible -> kv_seq takes the model axis
    spec = spec_for(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                    (60, 128, 32768, 8, 128), MESH, TP)
    assert spec == PartitionSpec(None, "data", "model", None, None)


def test_experts_shard_model():
    spec = spec_for(("experts", "d_model", "d_ff"), (384, 7168, 2048),
                    MESH, FSDP)
    assert spec == PartitionSpec("model", "data", None)


def test_experts_nondivisible_dff_takes_model():
    spec = spec_for(("experts", "d_model", "d_ff"), (8, 6144, 32768),
                    MESH, FSDP)
    assert spec == PartitionSpec(None, "data", "model")


def test_no_axis_used_twice():
    # every rule assignment must keep mesh axes disjoint within one tensor
    spec = spec_for(("heads", "d_ff"), (32, 9728), MESH, TP)
    used = [p for p in spec if p is not None]
    assert len(used) == len(set(used)) == 1  # model only once


def test_scalar_spec():
    assert spec_for((), (), MESH, TP) == PartitionSpec()
