"""MoE dispatch correctness vs a naive dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_apply, moe_spec
from repro.models.params import init_params


def _naive_moe(params, x, top_k):
    """Dense reference: every expert on every token, gate-weighted top-k."""
    B, S, d = x.shape
    E = params["router"].shape[-1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    # all experts densely
    g = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u,
                       params["w_down"])
    out = jnp.zeros_like(x)
    for j in range(top_k):
        sel = jnp.take_along_axis(
            y_all, expert_idx[..., j][..., None, None], axis=2)[:, :, 0]
        out = out + sel * gate_vals[..., j][..., None].astype(x.dtype)
    return out


@pytest.mark.parametrize("B,S,E,k", [(2, 16, 4, 2), (1, 32, 8, 2),
                                     (3, 8, 4, 1)])
def test_moe_matches_dense_reference_no_drops(B, S, E, k):
    d, f = 16, 32
    params = init_params(moe_spec(d, f, E), jax.random.PRNGKey(0),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
    # capacity factor high enough that nothing drops
    out, metrics = moe_apply(params, x, top_k=k, capacity_factor=float(E))
    want = _naive_moe(params, x, k)
    assert float(metrics["moe_dropped_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_seq_chunking_consistent():
    d, f, E, k = 16, 32, 4, 2
    params = init_params(moe_spec(d, f, E), jax.random.PRNGKey(2),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, d)) * 0.5
    a, _ = moe_apply(params, x, top_k=k, capacity_factor=float(E),
                     seq_chunk=16)
    b, _ = moe_apply(params, x, top_k=k, capacity_factor=float(E),
                     seq_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    d, f, E, k = 8, 16, 4, 2
    params = init_params(moe_spec(d, f, E), jax.random.PRNGKey(4),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, d))
    _, metrics = moe_apply(params, x, top_k=k, capacity_factor=0.25)
    assert float(metrics["moe_dropped_frac"]) > 0.1


def test_moe_aux_loss_uniform_router_is_one():
    """With near-uniform routing, E * sum(me*ce) ~= 1 (balanced)."""
    d, f, E = 8, 16, 4
    params = init_params(moe_spec(d, f, E), jax.random.PRNGKey(6),
                         jnp.float32)
    params["router"] = jnp.zeros_like(params["router"])   # uniform gates
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 128, d))
    _, metrics = moe_apply(params, x, top_k=2, capacity_factor=4.0)
    assert float(metrics["moe_aux_loss"]) == pytest.approx(1.0, rel=0.15)


def test_moe_gradients_flow():
    d, f, E = 8, 16, 4
    params = init_params(moe_spec(d, f, E), jax.random.PRNGKey(8),
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 32, d))

    def loss(p):
        out, m = moe_apply(p, x, top_k=2, capacity_factor=2.0)
        return jnp.sum(out ** 2) + 0.01 * m["moe_aux_loss"]

    g = jax.grad(loss)(params)
    norms = {k: float(jnp.abs(v).max()) for k, v in jax.tree.leaves_with_path(g) if True} \
        if False else [float(jnp.abs(l).max()) for l in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert max(norms) > 0
