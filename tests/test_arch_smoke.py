"""Per-architecture smoke tests: reduced same-family config, one forward +
one optimizer train step + one decode step on CPU; shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, get_arch
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.step import init_state, make_train_step


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_loads(arch_id):
    cfg = get_arch(arch_id)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab > 0
    model = build_model(cfg)
    from repro.models.params import count_params
    n = count_params(model.param_specs())
    assert n > 1e6  # full configs are real-sized


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id, key):
    cfg = get_arch(arch_id).smoke_config()
    model = build_model(cfg)
    opt = OptimizerConfig(total_steps=10, peak_lr=1e-3)
    state = init_state(model, opt, key)
    shape = SHAPES["train_4k"].smoke()
    batch = model.make_batch(key, shape)
    step = jax.jit(make_train_step(model, opt))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], state2["params"]))
    assert max(delta) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id, key):
    cfg = get_arch(arch_id).smoke_config()
    model = build_model(cfg)
    params = model.init(key)
    shape = SHAPES["decode_32k"].smoke()
    batch = model.make_batch(key, shape)
    logits, cache = model.decode_step(params, batch["cache"], batch["tokens"])
    B = shape.global_batch
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # second step advances
    logits2, cache2 = model.decode_step(params, cache, batch["tokens"])
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_loss_decreases(arch_id, key):
    """A few steps on a repeated batch must reduce the loss (learnable)."""
    cfg = get_arch(arch_id).smoke_config()
    model = build_model(cfg)
    opt = OptimizerConfig(total_steps=20, peak_lr=3e-3, warmup_steps=2)
    state = init_state(model, opt, key)
    shape = SHAPES["train_4k"].smoke()
    batch = model.make_batch(key, shape)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["xent"]))
    assert losses[-1] < losses[0], losses


def test_long_500k_only_for_subquadratic():
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        shapes = applicable_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_input_specs_match_make_batch():
    key = jax.random.PRNGKey(1)
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id).smoke_config()
        model = build_model(cfg)
        for shape_name in applicable_shapes(cfg):
            shape = SHAPES[shape_name].smoke()
            specs = model.input_specs(shape)
            batch = model.make_batch(key, shape)
            spec_leaves = jax.tree.leaves(specs)
            batch_leaves = jax.tree.leaves(batch)
            assert len(spec_leaves) == len(batch_leaves), (arch_id, shape_name)
            for s, b in zip(spec_leaves, batch_leaves):
                assert tuple(s.shape) == tuple(b.shape), (arch_id, shape_name)
                assert s.dtype == b.dtype, (arch_id, shape_name)


def test_input_logical_axes_match_specs_structure():
    import jax.tree_util as jtu
    key = jax.random.PRNGKey(1)
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id).smoke_config()
        model = build_model(cfg)
        for shape_name in applicable_shapes(cfg):
            shape = SHAPES[shape_name].smoke()
            specs = model.input_specs(shape)
            axes = model.input_logical_axes(shape)
            leaves, treedef = jtu.tree_flatten(specs)
            axes_leaves = treedef.flatten_up_to(axes)
            for s, a in zip(leaves, axes_leaves):
                assert len(a) == len(s.shape), (arch_id, shape_name, a, s.shape)
