"""Multi-cluster federation: ownership, cluster-aware placement, WAN
accounting, cluster-level outage degradation, checkpoint/restore across
federations.

Delivery-audit tests use the in-order/low-latency configuration so exact
uuid streams can be asserted; the outage tests use hedging + OOO to cover
the failover machinery under realistic conditions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterSpec, FederatedCluster, FederatedRing,
                        KVStore, MultiHostConfig, MultiHostRun,
                        federated_preferred_subsets)
from repro.core.kvstore import make_uuid
from repro.core.netsim import VirtualClock
from repro.core.placement import replica_local_fraction, split_strips
from repro.data.datasets import SyntheticImageDataset, ingest

SPECS = (ClusterSpec("us", route="local", n_nodes=4, replication_factor=2),
         ClusterSpec("eu", route="high", n_nodes=4, replication_factor=2))


@pytest.fixture(scope="module")
def store_uuids():
    store = KVStore()
    uuids = ingest(store, SyntheticImageDataset(n_samples=8_000, seed=5))
    return store, uuids


def _fed_cfg(n_hosts, **kw):
    defaults = dict(n_hosts=n_hosts, batch_size=100, prefetch_buffers=4,
                    io_threads=4, hedge_after=1.0, seed=13,
                    placement="cluster_aware", clusters=SPECS)
    defaults.update(kw)
    return MultiHostConfig(**defaults)


def _fast_cfg(n_hosts, **kw):
    """In-order + no hedging: delivery order == plan order, auditable."""
    fast = dict(out_of_order=False, hedge_after=None)
    fast.update(kw)
    return _fed_cfg(n_hosts, **fast)


def _collector(delivered):
    def on_batch(host_id, batch):
        delivered.setdefault(batch.epoch, []).extend(
            str(u) for u in batch.uuids)
    return on_batch


def _uuids(n, seed=7):
    rng = np.random.default_rng(seed)
    return [make_uuid(rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# Ownership map + federated ring
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 300), w1=st.integers(1, 4), w2=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_ownership_is_deterministic_and_weighted(n, w1, w2):
    """Every key has exactly one owner; shares follow the declared weights;
    the map is a pure function of the ring metadata (checkpoint-rebuildable)."""
    meta = [{"name": "a", "n_nodes": 2, "ring_seed": 3, "rf": 1, "weight": w1},
            {"name": "b", "n_nodes": 3, "ring_seed": 4, "rf": 2, "weight": w2}]
    ring = FederatedRing.from_metadata(meta)
    rebuilt = FederatedRing.from_metadata(ring.metadata())
    uuids = _uuids(n)
    owners = [ring.owner_of(u) for u in uuids]
    assert all(o in ("a", "b") for o in owners)
    assert owners == [rebuilt.owner_of(u) for u in uuids]
    assert [ring.replicas(u) for u in uuids] == [rebuilt.replicas(u)
                                                for u in uuids]
    if n >= 200:       # md5 tokens are uniform: shares track the weights
        frac_a = owners.count("a") / n
        assert abs(frac_a - w1 / (w1 + w2)) < 0.15


def test_replicas_stay_in_owning_cluster_with_member_rf():
    meta = [{"name": "us", "n_nodes": 4, "ring_seed": 1, "rf": 2, "weight": 1},
            {"name": "eu", "n_nodes": 3, "ring_seed": 2, "rf": 1, "weight": 1}]
    ring = FederatedRing.from_metadata(meta)
    rf_by = {"us": 2, "eu": 1}
    for u in _uuids(80):
        owner = ring.owner_of(u)
        reps = ring.replicas(u, rf=3)       # rf arg ignored: member rf rules
        assert len(reps) == rf_by[owner]
        assert all(r.startswith(f"{owner}/") for r in reps)


def test_federation_validation():
    clock, store = VirtualClock(), KVStore()
    with pytest.raises(ValueError):
        FederatedCluster(clock, store, ())                     # empty
    with pytest.raises(ValueError):
        FederatedCluster(clock, store, (ClusterSpec("a"), ClusterSpec("a")))
    with pytest.raises(ValueError):
        FederatedCluster(clock, store, (ClusterSpec("a/b"),))  # reserved '/'
    with pytest.raises(ValueError):
        FederatedRing.from_metadata([{"name": "a", "n_nodes": 1,
                                      "ring_seed": 0, "rf": 1, "weight": 0}])


def test_cluster_aware_placement_requires_federation(store_uuids):
    store, uuids = store_uuids
    cfg = MultiHostConfig(n_hosts=2, placement="cluster_aware")
    with pytest.raises(ValueError):
        MultiHostRun(store, uuids[:200], cfg)


def test_federated_preferred_subsets_span_every_cluster():
    by_cluster = {"us": [f"us/node{i}" for i in range(4)],
                  "eu": [f"eu/node{i}" for i in range(3)]}
    for n_hosts in (1, 2, 3, 5, 8):
        subsets = federated_preferred_subsets(by_cluster, n_hosts)
        assert len(subsets) == n_hosts
        # every host prefers at least one node in every member cluster, so
        # no host ends up with an all-WAN or all-local strip
        for s in subsets:
            assert any(n.startswith("us/") for n in s)
            assert any(n.startswith("eu/") for n in s)
        # and jointly the hosts prefer every node somewhere
        assert set().union(*map(set, subsets)) == \
            set(by_cluster["us"]) | set(by_cluster["eu"])


def test_cluster_aware_split_balanced_and_replica_local():
    meta = [{"name": "us", "n_nodes": 4, "ring_seed": 1, "rf": 2, "weight": 1},
            {"name": "eu", "n_nodes": 4, "ring_seed": 2, "rf": 2, "weight": 1}]
    ring = FederatedRing.from_metadata(meta)
    uuids = _uuids(400)
    pref = federated_preferred_subsets(
        {m["name"]: [f"{m['name']}/node{i}" for i in range(m["n_nodes"])]
         for m in meta}, 4)
    strips = split_strips(uuids, 4, "cluster_aware", ring=ring, rf=0,
                          preferred=pref)
    sizes = [len(s) for s in strips]
    assert sum(sizes) == 400 and max(sizes) - min(sizes) <= 1
    flat = [str(u) for s in strips for u in s]
    assert len(flat) == len(set(flat)) == 400
    assert replica_local_fraction(strips, ring, 0, pref) > 0.9


def test_cluster_aware_split_rejects_plain_ring():
    from repro.core.cluster import TokenRing
    ring = TokenRing(["node0", "node1"])
    with pytest.raises(ValueError):
        split_strips(_uuids(10), 2, "cluster_aware", ring=ring,
                     rf=1, preferred=[("node0",), ("node1",)])


# ---------------------------------------------------------------------------
# Federated runs: delivery, checkpoints, elasticity
# ---------------------------------------------------------------------------

def test_federated_run_exactly_once_per_epoch(store_uuids):
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run = MultiHostRun(store, small, _fast_cfg(2)).start()
    run.run(12, on_batch=_collector(delivered))          # 2 full epochs
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe


def test_federated_report_breaks_out_clusters(store_uuids):
    store, uuids = store_uuids
    rep = MultiHostRun(store, uuids[:2000], _fast_cfg(2)).run(4)
    share = rep["per_cluster_egress_share"]
    assert set(share) == {"us", "eu"}
    assert sum(share.values()) == pytest.approx(1.0)
    assert 0.0 < rep["wan_bytes_share"] < 1.0
    assert rep["wan_bytes_share"] == pytest.approx(share["eu"])
    assert rep["cluster_failovers"] == 0                 # no outage
    crep = rep["cluster_report"]
    assert crep["us"]["wan"] == 0.0 and crep["eu"]["wan"] == 1.0
    assert crep["eu"]["route"] == "high"
    # replica-local routing: cluster-aware placement concentrates each
    # host's traffic on its preferred nodes
    assert rep["replica_local_hit_frac"] > 0.9
    # per-node report uses qualified names across both clusters
    assert set(rep["cluster_load"]) == set(
        f"{c}/node{i}" for c in ("us", "eu") for i in range(4))


def test_federated_checkpoint_roundtrip_same_n(store_uuids):
    """Same-N restore of a federated checkpoint is bit-identical to the
    uninterrupted continuation (M == N bit-identity across a federation)."""
    store, uuids = store_uuids
    small = uuids[:1500]
    cfg = _fast_cfg(3)
    unbroken: dict = {}
    run = MultiHostRun(store, small, cfg).start()
    run.run(2, on_batch=_collector(unbroken))
    ck = run.checkpoint()
    assert ck["federation"] == run.federation.ring.metadata()
    continued: dict = {}
    run.run(3, on_batch=_collector(continued))

    resumed: dict = {}
    MultiHostRun(store, small, cfg).start(ck).run(
        3, on_batch=_collector(resumed))
    assert resumed == continued


@pytest.mark.parametrize("old_n,new_n", [(2, 4), (3, 2)])
def test_federated_elastic_restore_exactly_once(store_uuids, old_n, new_n):
    # parametrizations keep reflowed strip sizes divisible by the batch
    # size: the audit attributes whole batches to batch.epoch, so a batch
    # must never straddle an epoch boundary
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run = MultiHostRun(store, small, _fast_cfg(old_n)).start()
    run.run(2, on_batch=_collector(delivered))
    ck = run.checkpoint()

    restore = MultiHostRun(store, small, _fast_cfg(new_n)).start(ck)
    remaining = 1200 - old_n * 2 * 100
    rounds = -(-(remaining + 1200) // (new_n * 100))     # rest of e0 + all e1
    restore.run(rounds, on_batch=_collector(delivered))
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe


def test_federation_change_triggers_reshard_not_stale_cursors(store_uuids):
    """Same host count but a *different federation* (extra member, different
    weights): cursors must not be applied to different strips — the restore
    reflows, and exactly-once still holds."""
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    run = MultiHostRun(store, small, _fast_cfg(2)).start()
    run.run(2, on_batch=_collector(delivered))
    ck = run.checkpoint()

    other_specs = SPECS + (ClusterSpec("ap", route="med", n_nodes=2,
                                       replication_factor=1),)
    restore = MultiHostRun(store, small,
                           _fast_cfg(2, clusters=other_specs)).start(ck)
    restore.run(4 + 6, on_batch=_collector(delivered))   # rest of e0 + e1
    universe = {str(u) for u in small}
    for epoch in (0, 1):
        assert len(delivered[epoch]) == 1200
        assert set(delivered[epoch]) == universe


def test_contiguous_federated_checkpoint_restores_on_plain_cluster(store_uuids):
    """Contiguous strips don't depend on the topology at all, so a federated
    contiguous checkpoint resumes cursor-exact on a single-cluster run."""
    store, uuids = store_uuids
    cfg = _fast_cfg(2, placement="contiguous")
    run = MultiHostRun(store, uuids[:1000], cfg).start()
    run.run(2)
    ck = run.checkpoint()
    plain = MultiHostConfig(n_hosts=2, batch_size=100, prefetch_buffers=4,
                            io_threads=4, seed=13, out_of_order=False,
                            hedge_after=None, route="low")
    restored = MultiHostRun(store, uuids[:1000], plain).start(ck)
    for ld, s in zip(restored.loaders, ck["shards"]):
        assert ld.state() == {"epoch": s["epoch"], "cursor": s["cursor"],
                              "consumed": 0}


# ---------------------------------------------------------------------------
# Cluster-level outage: degradation to the replica cluster
# ---------------------------------------------------------------------------

def test_cluster_outage_degrades_to_replica_cluster(store_uuids):
    store, uuids = store_uuids
    small = uuids[:1200]
    delivered: dict = {}
    # in-order so the delivery audit can attribute batches to epochs (the
    # OOO window legitimately blurs epoch boundaries); hedging + the
    # cluster-failover path are still fully exercised
    run = MultiHostRun(store, small, _fed_cfg(2, out_of_order=False)).start()
    run.run(1, on_batch=_collector(delivered))
    served_before = sum(n.requests_served
                       for n in run.federation.clusters["eu"].nodes.values())
    run.inject_cluster_outage("eu", after=0.0)
    rep = run.run(5, on_batch=_collector(delivered))     # finishes epoch 0
    # all reads now come from the surviving cluster...
    assert rep["cluster_failovers"] > 0
    assert all(v["down"] == 1.0 for name, v in rep["cluster_load"].items()
               if name.startswith("eu/"))
    served_after = sum(n.requests_served
                      for n in run.federation.clusters["eu"].nodes.values())
    assert served_after == served_before
    # ...and delivery is still exactly-once for the epoch
    assert len(delivered[0]) == len(set(delivered[0])) == 1200


def test_cluster_outage_recovery_restores_owner_routing(store_uuids):
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:2000],
                       _fed_cfg(2, out_of_order=True)).start()
    run.inject_cluster_outage("eu", after=0.1, recover_after=1.0)
    # step_time stretches virtual time past the recovery point (pure
    # tight-loop rounds complete in well under a virtual second)
    run.run(8, step_time=0.25)
    rep = run.run(4, step_time=0.25)   # well past recovery: owner routing is back
    assert all(v["down"] == 0.0 for v in rep["cluster_load"].values())
    assert rep["per_cluster_egress_share"]["eu"] > 0.2


def test_outage_failover_does_not_double_count(store_uuids):
    """When the exhausted-hook hands a request to the replica cluster, the
    owner pool's fetch is marked done — the hedge timer must not re-issue it
    into the dead cluster and complete it a second time (regression: the
    once-guard ate the duplicate delivery but bytes/requests/failovers were
    double-counted, inflating the degraded-window throughput reports)."""
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:1200],
                       _fed_cfg(2, out_of_order=True)).start()
    run.run(1)
    run.inject_cluster_outage("eu", after=0.0)
    # step_time stretches virtual time past the hedge timers of the fetches
    # that were in flight at the outage — the cascade that used to re-issue
    # them into the dead cluster (pre-fix this scenario shows ~96 duplicates)
    run.run(5, step_time=0.5)
    assert sum(ld.pool.duplicates_suppressed for ld in run.loaders) == 0


def test_total_blackout_times_out_not_hangs(store_uuids):
    # tiny config: every stuck fetch retries each backoff interval, so the
    # in-flight count times the virtual timeout bounds the event volume
    store, uuids = store_uuids
    run = MultiHostRun(store, uuids[:100],
                       _fed_cfg(1, out_of_order=True, batch_size=20,
                                prefetch_buffers=1, io_threads=1)).start()
    run.inject_cluster_outage("us", after=0.0)
    run.inject_cluster_outage("eu", after=0.0)
    with pytest.raises(TimeoutError):
        run.run(3, timeout=2.0)
