"""Attention equivalences: chunked(custom-VJP) == dense; decode cache paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models.params import init_params


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 19), (False, 0)])
@pytest.mark.parametrize("S,qc,kc", [(128, 32, 32), (96, 64, 32)])
def test_chunked_matches_dense_forward(causal, window, S, qc, kc):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, K, D = 2, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    pos = jnp.arange(S)
    want = attn.dense_attention(q, k, v, pos, pos, causal=causal,
                                window=window)
    got = attn.chunked_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 23)])
def test_chunked_matches_dense_gradients(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, K, D = 2, 96, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    pos = jnp.arange(S)

    def loss_c(q, k, v):
        o = attn.chunked_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=32, kv_chunk=32)
        return jnp.sum(o * o)

    def loss_d(q, k, v):
        o = attn.dense_attention(q, k, v, pos, pos, causal=causal,
                                 window=window)
        return jnp.sum(o * o)

    gc = jax.grad(loss_c, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_d, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_decode_attention_matches_full_forward():
    """Prefill-by-decode: step-by-step cache attention == full causal attn."""
    c = {"d": 32, "H": 4, "K": 2, "Dh": 8}
    spec = attn.gqa_spec(c["d"], c["H"], c["K"], c["Dh"])
    params = init_params(spec, jax.random.PRNGKey(0), jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, c["d"])) * 0.3

    # full-sequence path (with rope)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = attn.project_qkv(params, x)
    q = attn.apply_rope(q, pos, 10_000.0)
    k = attn.apply_rope(k, pos, 10_000.0)
    o = attn.dense_attention(q, k, v, pos[0], pos[0], causal=True)
    want = attn.project_out(params, o)

    # decode path token by token
    cache = attn.init_kv_cache(B, S, c["K"], c["Dh"], jnp.float32)
    outs = []
    for t in range(S):
        o_t, cache = attn.decode_attention(params, cache, x[:, t:t + 1])
        outs.append(o_t)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_ring_buffer_matches_sliding_window():
    """SWA ring cache == full attention with window mask."""
    c = {"d": 32, "H": 4, "K": 2, "Dh": 8}
    W = 5
    spec = attn.gqa_spec(c["d"], c["H"], c["K"], c["Dh"])
    params = init_params(spec, jax.random.PRNGKey(2), jnp.float32)
    B, S = 1, 14
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, c["d"])) * 0.3

    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = attn.project_qkv(params, x)
    q = attn.apply_rope(q, pos, 10_000.0)
    k = attn.apply_rope(k, pos, 10_000.0)
    o = attn.dense_attention(q, k, v, pos[0], pos[0], causal=True, window=W)
    want = attn.project_out(params, o)

    cache = attn.init_kv_cache(B, W, c["K"], c["Dh"], jnp.float32)
    outs = []
    for t in range(S):
        o_t, cache = attn.decode_attention(params, cache, x[:, t:t + 1],
                                           window=W)
        outs.append(o_t)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_qk_norm_applied():
    spec = attn.gqa_spec(16, 2, 2, 8, qk_norm=True)
    params = init_params(spec, jax.random.PRNGKey(4), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 16))
    q, k, _ = attn.project_qkv(params, x)
    # per-head rmsnorm => unit rms rows
    rms = np.sqrt(np.mean(np.asarray(q) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones_like(rms), rtol=1e-3)
