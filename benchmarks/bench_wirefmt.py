"""Wire-codec + zero-copy arena bench: the row hot path, end to end.

Two sections, both deterministic where it matters (virtual clock + seeded
RNGs; only the host-CPU ratio is wall-clock and is gated as a boolean with a
2x margin, not as a ±15% metric):

**A. codec x route** — the CPU-vs-bandwidth trade per route tier.  Lazy
rows (the codecs' calibrated ``encoded_size`` model) stream through the
adaptive flow controller on the 150 ms ``high`` route and the ``local``
route at equal NIC bandwidth.  Steady-state (post-ramp window) payload
throughput is the headline.  Checks:

* ``high_codec_gain``     — byteshuffle effective MB/s on the high route
  >= 1.3x the no-codec run: the wire carries ~0.55x the bytes, so the
  loss-limited AIMD streams deliver proportionally more payload;
* ``codec_deepens_budget`` — the flow controller *measures* the gain: its
  converged budget (BDP in samples) under the codec is >= 1.1x no-codec;
* ``local_codec_no_gain`` — on the local route the single node's encode
  pool (``NODE_CODEC_CORES`` x codec rate < NIC rate) caps the run: the
  codec buys <= 10% — WAN: compress, local: don't;
* ``none_bit_identical``  — ``wire_codec="none"`` bills wire == payload
  bytes, burns zero encode/decode CPU, and produces *exactly* the batch
  timeline of a pool constructed with no codec argument at all.

**B. arena + fused device decode** — real pixel rows
(``SyntheticPixelDataset``) through ``materialize=True`` loaders.  The
arena path uploads each batch as ONE contiguous uint8 slab and runs the
Pallas fused crop/mirror/normalize on device; the materialize path is the
classic CPU pipeline (per-sample frombuffer -> f32 -> crop/mirror ->
normalize -> transpose -> upload).  Checks:

* ``arena_matches_materialize`` — both paths produce identical tensors
  (same seeded augmentation draws);
* ``arena_halves_host_cpu``     — per-batch host prep time on the arena
  path <= 0.5x the materialize path (wall clock, after JAX warmup);
* ``arena_reuses_slabs``        — the pinned pool stays at its steady-state
  size (2 slabs) instead of allocating per batch.

Results land in ``results/wirefmt.json`` (quick runs gated against
``benchmarks/baselines/wirefmt.json`` by ``tools/bench_check.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import (CassandraLoader, ConnectionPool, KVStore,
                        LoaderConfig)
from repro.data.datasets import (SyntheticImageDataset, SyntheticPixelDataset,
                                 ingest)

from .common import RESULTS_DIR, make_store

SEED = 13
BATCH = 256


# ---------------------------------------------------------------------------
# Section A: codec x route
# ---------------------------------------------------------------------------


def _codec_cfg(route: str, codec: str, n_nodes: int) -> LoaderConfig:
    return LoaderConfig(batch_size=BATCH, route=route, wire_codec=codec,
                        flow_control="adaptive", seed=SEED, n_nodes=n_nodes,
                        replication_factor=min(2, n_nodes))


def _run_cell(store, uuids, cfg: LoaderConfig, n_batches: int,
              skip: int) -> dict:
    loader = CassandraLoader(store, uuids, cfg)
    loader.start()
    for _ in range(n_batches):
        loader.next_batch()
    st = loader.stats
    pool = loader.pool
    return {
        "MBps": st.throughput(skip=skip) / 1e6,
        "wire_MB": pool.bytes_received / 1e6,
        "payload_MB": pool.payload_bytes_received / 1e6,
        "budget_samples": loader.flow_controller.budget(),
        "encode_cpu_s": sum(n.encode_cpu_seconds
                            for n in loader.cluster.nodes.values()),
        "decode_cpu_s": pool.decode_cpu_seconds,
        "batch_ready_t": list(st.batch_ready_t),
    }


def _identity_cell(store, uuids, n_batches: int) -> dict:
    """wire_codec="none" vs a pool constructed with NO codec argument:
    identical batch timeline, wire == payload, zero codec CPU."""
    runs = {}
    for tag in ("explicit_none", "default"):
        cfg = LoaderConfig(batch_size=BATCH, route="high",
                           flow_control="adaptive", seed=SEED, n_nodes=2,
                           replication_factor=2)
        if tag == "explicit_none":
            cfg.wire_codec = "none"
            loader = CassandraLoader(store, uuids, cfg)
        else:
            # Bypass LoaderConfig's codec plumbing entirely: the pool is
            # built exactly as pre-codec callers build it.
            from repro.core.netsim import VirtualClock

            from repro.core import Cluster

            clock = VirtualClock()
            cluster = Cluster(clock, store, backend=cfg.backend,
                              n_nodes=cfg.n_nodes, rf=cfg.replication_factor,
                              seed=cfg.seed + 5)
            pool = ConnectionPool(clock, cluster, cfg.route,
                                  io_threads=cfg.io_threads,
                                  conns_per_thread=cfg.conns_per_thread,
                                  seed=cfg.seed + 11)
            loader = CassandraLoader(store, uuids, cfg, clock=clock,
                                     cluster=cluster, pool=pool)
        loader.start()
        for _ in range(n_batches):
            loader.next_batch()
        runs[tag] = {
            "ready_t": list(loader.stats.batch_ready_t),
            "wire": loader.pool.bytes_received,
            "payload": loader.pool.payload_bytes_received,
            "encode_cpu_s": sum(n.encode_cpu_seconds
                                for n in loader.cluster.nodes.values()),
            "decode_cpu_s": loader.pool.decode_cpu_seconds,
        }
    a, b = runs["explicit_none"], runs["default"]
    return {
        "timeline_equal": a["ready_t"] == b["ready_t"],
        "wire_eq_payload": (a["wire"] == a["payload"]
                            and b["wire"] == b["payload"]),
        "zero_codec_cpu": (a["encode_cpu_s"] == 0.0 == a["decode_cpu_s"]
                           and b["encode_cpu_s"] == 0.0 == b["decode_cpu_s"]),
    }


def run_codec_section(quick: bool) -> dict:
    n_samples = 20_000 if quick else 50_000
    n_batches = 150 if quick else 300
    skip = 100 if quick else 200
    store, uuids = make_store(n_samples=n_samples, seed=3)

    cells = {"high": {}, "local": {}}
    codecs = ["none", "byteshuffle"] if quick else ["none", "byteshuffle",
                                                    "int8"]
    for codec in codecs:
        # high: 4 nodes — the AIMD wire is the only bottleneck, compression
        # converts straight to payload throughput.
        cells["high"][codec] = _run_cell(
            store, uuids, _codec_cfg("high", codec, n_nodes=4),
            n_batches, skip)
    for codec in ("none", "byteshuffle"):
        # local: ONE node — its encode pool (cores x codec rate) sits just
        # below the NIC rate, so compression cannot pay here by design.
        cells["local"][codec] = _run_cell(
            store, uuids, _codec_cfg("local", codec, n_nodes=1),
            max(40, n_batches // 3), 2)

    identity = _identity_cell(store, uuids, n_batches=40)

    gain_high = (cells["high"]["byteshuffle"]["MBps"]
                 / cells["high"]["none"]["MBps"])
    gain_local = (cells["local"]["byteshuffle"]["MBps"]
                  / cells["local"]["none"]["MBps"])
    budget_ratio = (cells["high"]["byteshuffle"]["budget_samples"]
                    / cells["high"]["none"]["budget_samples"])
    for route in cells:
        for codec in cells[route]:
            cells[route][codec].pop("batch_ready_t")
    return {
        "cells": cells,
        "gain_high": gain_high,
        "gain_local": gain_local,
        "budget_ratio": budget_ratio,
        "identity": identity,
        "checks": {
            "high_codec_gain": gain_high >= 1.3,
            "codec_deepens_budget": budget_ratio >= 1.1,
            "local_codec_no_gain": gain_local <= 1.1,
            "none_bit_identical": all(identity.values()),
        },
    }


# ---------------------------------------------------------------------------
# Section B: pinned arena + fused on-device decode
# ---------------------------------------------------------------------------


def _pixel_feed(store, uuids, ds, use_arena: bool, batch_size: int,
                out_hw: int):
    from repro.data.pipeline import ImageFeed

    cfg = LoaderConfig(batch_size=batch_size, route="local",
                       materialize=True, use_arena=use_arena,
                       arena_slot_bytes=ds.nbytes, seed=SEED)
    loader = CassandraLoader(store, uuids, cfg)
    feed = ImageFeed(loader, ds.h, ds.w, ds.c, out_h=out_hw, out_w=out_hw,
                     seed=SEED + 1)
    return loader, feed


def run_arena_section(quick: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kernel_ops

    batch_size = 32 if quick else 64
    n_batches = 8 if quick else 24
    hw = 64
    out_hw = 56
    ds = SyntheticPixelDataset(n_samples=1024 if quick else 4096,
                               h=hw, w=hw, c=3, seed=5)
    store = KVStore()
    uuids = ingest(store, ds)

    # Warm up JAX (backend init + kernel compile) so neither path's timed
    # window pays first-call costs.
    warm = jnp.zeros((batch_size, hw, hw, 3), jnp.uint8)
    zero = jnp.zeros((batch_size,), jnp.int32)
    kernel_ops.crop_mirror_normalize(
        warm, zero, zero, zero, jnp.zeros(3), jnp.ones(3),
        out_h=out_hw, out_w=out_hw).block_until_ready()
    jax.device_put(np.zeros((batch_size, 3, out_hw, out_hw),
                            np.float32)).block_until_ready()

    out = {}
    first_images = {}
    for mode, use_arena in (("materialize", False), ("arena", True)):
        loader, feed = _pixel_feed(store, uuids, ds, use_arena, batch_size,
                                   out_hw)
        t0 = time.perf_counter()
        for i in range(n_batches):
            dev, _meta = next(feed)
            if i == 0:
                first_images[mode] = np.asarray(dev["images"])
        wall = time.perf_counter() - t0
        out[mode] = {
            "host_prep_s": feed.host_prep_s,
            "host_prep_ms_per_batch": feed.host_prep_s / feed.batches * 1e3,
            "wall_s": wall,
            "loader_MBps": loader.stats.throughput(skip=2) / 1e6,
        }
        if use_arena:
            out[mode]["arena"] = loader.arena.stats()

    ratio = out["arena"]["host_prep_s"] / out["materialize"]["host_prep_s"]
    max_diff = float(np.abs(first_images["arena"]
                            - first_images["materialize"]).max())
    stats = out["arena"]["arena"]
    return {
        "modes": out,
        "host_cpu_ratio": ratio,
        "max_abs_diff": max_diff,
        "checks": {
            "arena_matches_materialize": max_diff <= 1e-5,
            "arena_halves_host_cpu": ratio <= 0.5,
            "arena_reuses_slabs": (stats["slabs_created"] <= 3
                                   and stats["reuses"] > 0),
        },
    }


# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI sizing (smaller dataset, fewer batches)")
    args = ap.parse_args(argv)

    print(f"== bench_wirefmt ({'quick' if args.quick else 'full'}) ==")
    t0 = time.time()
    codec = run_codec_section(args.quick)
    print(f"  codec: high gain {codec['gain_high']:.2f}x "
          f"(budget {codec['budget_ratio']:.2f}x deeper), "
          f"local gain {codec['gain_local']:.2f}x "
          f"[{time.time() - t0:.1f}s]")
    t1 = time.time()
    arena = run_arena_section(args.quick)
    print(f"  arena: host CPU {arena['host_cpu_ratio']:.2f}x materialize, "
          f"max|diff| {arena['max_abs_diff']:.1e} "
          f"[{time.time() - t1:.1f}s]")

    results = {
        "quick": args.quick,
        "batch_size": BATCH,
        "n_samples": 20_000 if args.quick else 50_000,
        "n_batches": 150 if args.quick else 300,
        "seed": SEED,
        "codec": codec,
        "arena": arena,
        "checks": {**{f"codec.{k}": v for k, v in codec["checks"].items()},
                   **{f"arena.{k}": v for k, v in arena["checks"].items()}},
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "wirefmt.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"  wrote {os.path.relpath(path)}")

    # Assert the acceptance criteria from the *written* results file, so a
    # hand-edited file can't diverge from what the gate saw.
    written = json.load(open(path))
    failed = [k for k, ok in written["checks"].items() if not ok]
    if failed:
        print(f"bench_wirefmt FAILED checks: {failed}")
        return 1
    print("bench_wirefmt: all checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
