"""Paper Table 3: tight-loop reading throughput at varying latencies.

Compares Cassandra-DALI (ours, OOO prefetching, ScyllaDB backend) against the
MosaicML-SD and tf.data-service loader models, all over the same simulated
network.  Paper targets (MB/s): ours 6066/5957/4081, SD 326/308/203,
tf.data 437/57/12 for low/med/high.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, KVStore, VirtualClock, tight_loop
from repro.core.competitors import (RecordShardLoader, SyncWindowLoader,
                                    build_shards)

from .common import (BATCH_SIZE, make_loader, make_store, mean_std, write_csv)

PAPER = {
    "cassandra-dali": {"low": 6066, "med": 5957, "high": 4081},
    "mosaicml-sd": {"low": 326, "med": 308, "high": 203},
    "tfdata-service": {"low": 437, "med": 57, "high": 12},
}


def run_ours(route: str, seeds=(1, 2, 3), n_batches=200) -> list:
    store, uuids = make_store()
    out = []
    for seed in seeds:
        ld = make_loader(store, uuids, route, seed=seed)
        res = tight_loop(ld, n_batches=n_batches)
        out.append(res["throughput_Bps"] / 1e6)
    return out


def run_sd(route: str, seeds=(1, 2), n_batches=150) -> list:
    store, uuids = make_store()
    shards = build_shards(store, uuids)
    out = []
    for seed in seeds:
        clock = VirtualClock()
        cluster = Cluster(clock, store, backend="scylla", seed=seed)
        ld = RecordShardLoader(clock, cluster, route, shards,
                               batch_size=BATCH_SIZE, seed=seed).start()
        for _ in range(n_batches):
            ld.next_batch()
        out.append(ld.throughput() / 1e6)
    return out


def run_tfdata(route: str, seeds=(1, 2), n_batches=60) -> list:
    store, uuids = make_store()
    avg = store.total_bytes() // len(store)
    out = []
    for seed in seeds:
        clock = VirtualClock()
        cluster = Cluster(clock, store, backend="scylla", seed=seed)
        ld = SyncWindowLoader(clock, cluster, route, avg,
                              batch_size=BATCH_SIZE, seed=seed).start()
        for _ in range(n_batches):
            ld.next_batch(timeout=20000.0)
        out.append(ld.throughput() / 1e6)
    return out


def run() -> str:
    rows, lines = [], []
    lines.append(f"{'loader':16s} {'tier':5s} {'ours (MB/s)':>14s} "
                 f"{'paper (MB/s)':>13s}")
    for name, fn in [("cassandra-dali", run_ours), ("mosaicml-sd", run_sd),
                     ("tfdata-service", run_tfdata)]:
        for route in ("low", "med", "high"):
            vals = fn(route)
            lines.append(f"{name:16s} {route:5s} {mean_std(vals):>14s} "
                         f"{PAPER[name][route]:>13d}")
            rows.append(f"{name},{route},{np.mean(vals):.1f},"
                        f"{np.std(vals):.1f},{PAPER[name][route]}")
    write_csv("table3_tightloop.csv",
              "loader,tier,throughput_MBps,std,paper_MBps", rows)
    return "\n".join(lines)


def main() -> None:
    print("# Table 3 — tight-loop reading throughput")
    print(run())


if __name__ == "__main__":
    main()
