"""Paper Table 4: multi-GPU training throughput at varying latencies.

Reproduces the experiment shape: 8 consumers ("GPUs") each with its own
loader shard, sharing the client NIC and the storage node; each consumer
takes a batch then "trains" for the no-I/O step time.  The no-I/O upper
bound (paper: 11199 img/s for 8xA100 ResNet-50) sets the step time; the
metric is aggregate samples/s vs that bound.

Paper targets (img/s): no-I/O 11199; ours 10608/10587/10485 (94-96%);
MosaicML SD 6209/5424/3992 (57/49/33%).
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, KVStore, LoaderConfig, VirtualClock
from repro.core.connection import ConnectionPool
from repro.core.competitors import RecordShardLoader, build_shards
from repro.core.netsim import TIERS, RateResource, NIC_BANDWIDTH
from repro.core.prefetcher import EpochPlan, PrefetchConfig, make_prefetcher

from .common import make_store, mean_std, write_csv

N_GPUS = 8
NO_IO_IMGS_PER_S = 11199.0          # paper's fixed-tensor upper bound
BATCH = 512
STEP_TIME = BATCH / (NO_IO_IMGS_PER_S / N_GPUS)   # per-GPU step seconds

PAPER = {"cassandra-dali": {"low": 10608, "med": 10587, "high": 10485},
         "mosaicml-sd": {"low": 6209, "med": 5424, "high": 3992}}


def run_ours(route: str, seed: int = 1, n_batches: int = 60) -> float:
    """8 loaders (one per GPU) sharing one cluster + client NIC."""
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shared_ingress = RateResource("client/ingress", NIC_BANDWIDTH)
    loaders = []
    for g in range(N_GPUS):
        cfg = LoaderConfig(batch_size=BATCH, prefetch_buffers=8, io_threads=4,
                           route=route, seed=seed + g, shard_id=g,
                           num_shards=N_GPUS)
        pool = ConnectionPool(clock, cluster, TIERS[route],
                              io_threads=cfg.io_threads, seed=seed + 31 * g)
        pool.ingress = shared_ingress          # all GPUs share the NIC
        for c in pool.connections:
            c._client_ingress = shared_ingress
        plan = EpochPlan(uuids, seed=seed, shard_id=g, num_shards=N_GPUS)
        pf = make_prefetcher(clock, pool, plan,
                             PrefetchConfig(batch_size=BATCH))
        pf.start()
        loaders.append(pf)

    # round-robin consumers with per-GPU step time
    t_next = [0.0] * N_GPUS
    done = [0] * N_GPUS
    t0 = None
    while min(done) < n_batches:
        g = int(np.argmin(t_next))
        if clock.now() < t_next[g]:
            clock.sleep(t_next[g] - clock.now())
        loaders[g].next_batch()
        if t0 is None:
            t0 = clock.now()
        done[g] += 1
        t_next[g] = max(clock.now(), t_next[g]) + STEP_TIME
    total = sum(done) * BATCH
    return total / max(clock.now() - t0, 1e-9)


def run_sd(route: str, seed: int = 1, n_batches: int = 40) -> float:
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shards = build_shards(store, uuids)
    per = len(shards) // N_GPUS
    # per-rank SD keeps only a small shard lookahead (library default);
    # aggregate supply across 8 ranks is what the paper's Table 4 measures
    loaders = [RecordShardLoader(clock, cluster, route,
                                 shards[g * per:(g + 1) * per],
                                 batch_size=BATCH, predownload=2,
                                 seed=seed + g).start()
               for g in range(N_GPUS)]
    t_next = [0.0] * N_GPUS
    done = [0] * N_GPUS
    t0 = None
    while min(done) < n_batches:
        g = int(np.argmin(t_next))
        if clock.now() < t_next[g]:
            clock.sleep(t_next[g] - clock.now())
        loaders[g].next_batch(timeout=5000.0)
        if t0 is None:
            t0 = clock.now()
        done[g] += 1
        t_next[g] = max(clock.now(), t_next[g]) + STEP_TIME
    return sum(done) * BATCH / max(clock.now() - t0, 1e-9)


def run() -> str:
    lines = [f"{'loader':16s} {'tier':5s} {'img/s':>8s} {'% of bound':>10s} "
             f"{'paper':>7s}"]
    rows = []
    for name, fn in [("cassandra-dali", run_ours), ("mosaicml-sd", run_sd)]:
        for route in ("low", "med", "high"):
            v = fn(route)
            pct = 100.0 * v / NO_IO_IMGS_PER_S
            lines.append(f"{name:16s} {route:5s} {v:8.0f} {pct:9.1f}% "
                         f"{PAPER[name][route]:>7d}")
            rows.append(f"{name},{route},{v:.0f},{pct:.1f},"
                        f"{PAPER[name][route]}")
    write_csv("table4_training.csv",
              "loader,tier,img_per_s,pct_of_bound,paper_img_per_s", rows)
    return "\n".join(lines)


def main() -> None:
    print("# Table 4 — training throughput (8 consumers, no-I/O bound "
          f"{NO_IO_IMGS_PER_S:.0f} img/s)")
    print(run())


if __name__ == "__main__":
    main()
