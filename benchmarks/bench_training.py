"""Training-side benchmarks: the simulated Table-4 sweep and the real
loader -> DeviceFeed -> jitted-step goodput sweep.

**Table 4** (``--table4``) reproduces the paper's experiment shape: 8
consumers ("GPUs") each with its own loader shard, sharing the client NIC
and the storage node; each consumer takes a batch then "trains" for the
no-I/O step time.  The no-I/O upper bound (paper: 11199 img/s for 8xA100
ResNet-50) sets the step time; the metric is aggregate samples/s vs that
bound.

Paper targets (img/s): no-I/O 11199; ours 10608/10587/10485 (94-96%);
MosaicML SD 6209/5424/3992 (57/49/33%).

**Goodput** (``--goodput [--quick]``) closes the loader->training loop:
it drives the repo's *real* path — ``CassandraLoader`` (materialized token
payloads) -> ``DeviceFeed`` (double-buffered device queue) -> a jitted
train step of a tiny LM via ``run_training`` — and measures what the
accelerator actually sees: per-step data-stall fraction and goodput
(``core.stats.StepStats``), swept over route x flow_control.  Compute is
pinned per step (``TrainLoopConfig.charge_step_time``) on the loader's
virtual clock, so the numbers are bit-deterministic and CI-gateable: the
headline check asserts the adaptive 150 ms route holds steady-state
data-stall below 5% for this compute-bound config, and an in-order
checkpoint->restore through ``DeviceFeed.state()`` is exactly-once (no
sample skipped or duplicated).  Results land in
``results/training_goodput.json`` and are gated by ``tools/bench_check.py``
against ``benchmarks/baselines/training_goodput.json``.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import (Cluster, KVStore, LoaderConfig, VirtualClock,
                        build_stack)
from repro.core.competitors import RecordShardLoader, build_shards
from repro.core.netsim import RateResource, NIC_BANDWIDTH
from repro.data.datasets import SyntheticTokenDataset, ingest

from .common import RESULTS_DIR, make_store, write_csv

# ---------------------------------------------------------------------------
# Table 4 — simulated 8-GPU sweep
# ---------------------------------------------------------------------------

N_GPUS = 8
NO_IO_IMGS_PER_S = 11199.0          # paper's fixed-tensor upper bound
BATCH = 512
STEP_TIME = BATCH / (NO_IO_IMGS_PER_S / N_GPUS)   # per-GPU step seconds

PAPER = {"cassandra-dali": {"low": 10608, "med": 10587, "high": 10485},
         "mosaicml-sd": {"low": 6209, "med": 5424, "high": 3992}}


def _consume_round_robin(clock, loaders, n_batches: int, step_time: float,
                         timeout: float = 600.0) -> float:
    """The Table-4 consumer model: round-robin over per-GPU loaders, one
    fixed-cost step per batch.  Returns aggregate samples/s."""
    t_next = [0.0] * len(loaders)
    done = [0] * len(loaders)
    t0 = None
    while min(done) < n_batches:
        g = int(np.argmin(t_next))
        if clock.now() < t_next[g]:
            clock.sleep(t_next[g] - clock.now())
        loaders[g].next_batch(timeout=timeout)
        if t0 is None:
            t0 = clock.now()
        done[g] += 1
        t_next[g] = max(clock.now(), t_next[g]) + step_time
    return sum(done) * BATCH / max(clock.now() - t0, 1e-9)


def run_ours(route: str, seed: int = 1, n_batches: int = 60) -> float:
    """8 loaders (one per GPU) sharing one cluster + client NIC.

    Each GPU's stack comes from one ``build_stack`` call; the shared clock,
    cluster, and client-NIC ``RateResource`` are passed through, so all
    eight loaders contend on the same simulated machine — the facade
    spelling of what this bench used to hand-wire from pool + plan +
    prefetcher parts.
    """
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shared_ingress = RateResource("client/ingress", NIC_BANDWIDTH)
    loaders = []
    for g in range(N_GPUS):
        # one shared plan seed (every shard computes the same global
        # shuffle); pool randomness decorrelates per shard_id inside the
        # loader
        cfg = LoaderConfig(batch_size=BATCH, prefetch_buffers=8, io_threads=4,
                           route=route, seed=seed, shard_id=g,
                           num_shards=N_GPUS)
        stack = build_stack(store=store, uuids=uuids, config=cfg,
                            clock=clock, cluster=cluster,
                            ingress=shared_ingress, start=True)
        loaders.append(stack.loader)
    return _consume_round_robin(clock, loaders, n_batches, STEP_TIME)


def run_sd(route: str, seed: int = 1, n_batches: int = 40) -> float:
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shards = build_shards(store, uuids)
    per = len(shards) // N_GPUS
    # per-rank SD keeps only a small shard lookahead (library default);
    # aggregate supply across 8 ranks is what the paper's Table 4 measures
    loaders = [RecordShardLoader(clock, cluster, route,
                                 shards[g * per:(g + 1) * per],
                                 batch_size=BATCH, predownload=2,
                                 seed=seed + g).start()
               for g in range(N_GPUS)]
    return _consume_round_robin(clock, loaders, n_batches, STEP_TIME,
                                timeout=5000.0)


def run_table4() -> str:
    lines = [f"{'loader':16s} {'tier':5s} {'img/s':>8s} {'% of bound':>10s} "
             f"{'paper':>7s}"]
    rows = []
    for name, fn in [("cassandra-dali", run_ours), ("mosaicml-sd", run_sd)]:
        for route in ("low", "med", "high"):
            v = fn(route)
            pct = 100.0 * v / NO_IO_IMGS_PER_S
            lines.append(f"{name:16s} {route:5s} {v:8.0f} {pct:9.1f}% "
                         f"{PAPER[name][route]:>7d}")
            rows.append(f"{name},{route},{v:.0f},{pct:.1f},"
                        f"{PAPER[name][route]}")
    write_csv("table4_training.csv",
              "loader,tier,img_per_s,pct_of_bound,paper_img_per_s", rows)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Goodput — real loader -> DeviceFeed -> jitted step
# ---------------------------------------------------------------------------

GOODPUT_ROUTES = ("local", "med", "high")
GOODPUT_FLOW = ("static", "adaptive")
GOODPUT_BATCH = 32
GOODPUT_SEQ = 64
GOODPUT_VOCAB = 2048
# pinned compute per step: demand = batch_bytes / step_time, a few hundred
# kB/s against >= 0.5 GB/s routes -> compute-bound by construction, the
# regime of the paper's headline claim
GOODPUT_STEP_TIME = 0.05
# steady-state stall: skip the jit/warm-up steps, as the paper's epoch
# accounting skips the first batches
GOODPUT_SKIP = 8
STALL_BOUND = 0.05


def _goodput_sizes(quick: bool) -> dict:
    return {"n_steps": 60 if quick else 150,
            "n_samples": 2048 if quick else 4096}


def _tiny_model():
    from repro.configs.base import ArchConfig
    from repro.models import build_model
    cfg = ArchConfig(name="bench-goodput-lm", family="dense", n_layers=2,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab=GOODPUT_VOCAB, head_dim=32, dtype="float32",
                     remat=False)
    return build_model(cfg)


def _token_store(n_samples: int, seed: int = 0):
    store = KVStore()
    uuids = ingest(store, SyntheticTokenDataset(
        n_samples=n_samples, seq_len=GOODPUT_SEQ, vocab=GOODPUT_VOCAB,
        seed=seed))
    return store, uuids


def run_goodput_cell(model, store, uuids, route: str, flow_control: str,
                     n_steps: int, seed: int = 0) -> dict:
    from repro.train.loop import TrainLoopConfig, run_training
    from repro.train.optimizer import OptimizerConfig

    loader_cfg = LoaderConfig(batch_size=GOODPUT_BATCH, prefetch_buffers=8,
                              io_threads=4, route=route, materialize=True,
                              flow_control=flow_control, seed=seed)
    loop_cfg = TrainLoopConfig(total_steps=n_steps, seq_len=GOODPUT_SEQ,
                               log_every=n_steps,
                               charge_step_time=GOODPUT_STEP_TIME)
    res = run_training(model, store, uuids, loader_cfg, loop_cfg,
                       OptimizerConfig(peak_lr=3e-3, warmup_steps=5,
                                       total_steps=n_steps))
    ss = res["step_stats"]
    nexts = ss.buffer_hits + ss.blocked
    return {
        "stall_frac": ss.stall_frac(skip=GOODPUT_SKIP),
        "stall_frac_all": ss.stall_frac(skip=1),
        "goodput_sps": ss.goodput_sps(GOODPUT_BATCH, skip=GOODPUT_SKIP),
        "wait_p99_ms": 1e3 * res["stats"]["wait_s"]["p99"],
        "buffer_hit_frac": ss.buffer_hits / max(nexts, 1),
        "steps": ss.steps,
        "loss_final": res["history"][-1]["loss"],
    }


def check_exactly_once(store, uuids, route: str = "med",
                       seed: int = 0) -> bool:
    """Checkpoint->restore through ``DeviceFeed.state()`` is exactly-once.

    In-order delivery makes the property exact: phase 1 consumes k batches
    and checkpoints the *feed's* position (loader cursor rewound by the
    device-queued batches); phase 2 restores and consumes the rest of the
    epoch.  Together they must deliver the epoch-0 permutation prefix with
    no sample skipped or duplicated — checkpointing ``loader.state()``
    instead would skip the queued batches.
    """
    cfg = LoaderConfig(batch_size=GOODPUT_BATCH, prefetch_buffers=4,
                       io_threads=4, route=route, out_of_order=False,
                       materialize=True, seed=seed)
    n_total = len(uuids) // GOODPUT_BATCH
    k = 5
    seen = []
    stack = build_stack(store=store, uuids=uuids, config=cfg,
                        feed="device", seq_len=GOODPUT_SEQ)
    feed = stack.feed
    for _ in range(k):
        _, meta = next(feed)
        seen.extend(str(s.uuid) for s in meta.samples)
    pos = feed.state()
    stack.close()

    stack2 = build_stack(store=store, uuids=uuids, config=cfg,
                         feed="device", seq_len=GOODPUT_SEQ)
    loader2 = stack2.loader
    loader2.start(epoch=pos["epoch"], cursor=pos["cursor"])
    feed2 = stack2.feed
    for _ in range(n_total - k):
        _, meta = next(feed2)
        seen.extend(str(s.uuid) for s in meta.samples)
    loader2.close()

    want = [str(u) for u in
            loader2.plan.permutation(0)[:n_total * GOODPUT_BATCH]]
    return sorted(seen) == sorted(want) and len(seen) == len(set(seen))


def run_goodput(quick: bool = False, seed: int = 0) -> dict:
    sizes = _goodput_sizes(quick)
    store, uuids = _token_store(sizes["n_samples"], seed=seed)
    model = _tiny_model()
    cells: dict = {}
    for route in GOODPUT_ROUTES:
        cells[route] = {}
        for flow in GOODPUT_FLOW:
            cells[route][flow] = run_goodput_cell(
                model, store, uuids, route, flow, sizes["n_steps"],
                seed=seed)

    adaptive_high = cells["high"]["adaptive"]
    compute_bound_sps = GOODPUT_BATCH / GOODPUT_STEP_TIME
    exactly_once = check_exactly_once(store, uuids, seed=seed)
    checks = {
        # the headline: the 150 ms route keeps the accelerator fed
        "adaptive_high_stall_lt_5pct":
            adaptive_high["stall_frac"] < STALL_BOUND,
        # sanity: a slower route can only stall more
        "stall_monotone_vs_route":
            cells["high"]["adaptive"]["stall_frac"]
            >= cells["local"]["adaptive"]["stall_frac"],
        # goodput can never exceed the pinned-compute bound
        "goodput_below_compute_bound": all(
            cells[r][f]["goodput_sps"] <= compute_bound_sps * 1.001
            for r in GOODPUT_ROUTES for f in GOODPUT_FLOW),
        # checkpoint->restore through DeviceFeed skips/duplicates nothing
        "restore_exactly_once_through_device_feed": exactly_once,
    }
    results = {
        "quick": quick,
        "n_steps": sizes["n_steps"],
        "n_samples": sizes["n_samples"],
        "batch_size": GOODPUT_BATCH,
        "step_time_s": GOODPUT_STEP_TIME,
        "skip": GOODPUT_SKIP,
        "compute_bound_sps": compute_bound_sps,
        "cells": cells,
        "checks": checks,
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR, "training_goodput.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    return results


def print_goodput(results: dict) -> None:
    print(f"# goodput — real loader -> DeviceFeed -> jitted step "
          f"(B={results['batch_size']}, step {results['step_time_s']*1e3:.0f} ms, "
          f"bound {results['compute_bound_sps']:.0f} samples/s)")
    print(f"{'route':6s} {'flow':9s} {'stall%':>7s} {'goodput':>8s} "
          f"{'wait p99':>9s} {'hit%':>6s}")
    for route in GOODPUT_ROUTES:
        for flow in GOODPUT_FLOW:
            c = results["cells"][route][flow]
            print(f"{route:6s} {flow:9s} {100*c['stall_frac']:6.2f}% "
                  f"{c['goodput_sps']:8.0f} {c['wait_p99_ms']:7.1f}ms "
                  f"{100*c['buffer_hit_frac']:5.1f}%")
    for name, ok in results["checks"].items():
        print(f"  check {name}: {'PASS' if ok else 'FAIL'}")
    if not all(results["checks"].values()):
        raise SystemExit("bench_training goodput checks FAILED")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--table4", action="store_true",
                    help="only the simulated Table-4 sweep")
    ap.add_argument("--goodput", action="store_true",
                    help="only the real-path goodput sweep")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized goodput sweep (fewer steps, smaller set)")
    args = ap.parse_args()
    run_all = not (args.table4 or args.goodput)
    if args.table4 or run_all:
        print("# Table 4 — training throughput (8 consumers, no-I/O bound "
              f"{NO_IO_IMGS_PER_S:.0f} img/s)")
        print(run_table4())
    if args.goodput or run_all:
        print_goodput(run_goodput(quick=args.quick))


if __name__ == "__main__":
    main()
