"""Roofline analysis (brief §Roofline): three terms per (arch x shape x mesh).

Reads the dry-run JSONL (results/dryrun_full.jsonl by default, or regenerates
single cells on demand) and derives, per cell:

    compute term    = HLO_FLOPs_total / (chips x 197e12 FLOP/s)
    memory term     = HLO_bytes_total / (chips x 819e9 B/s)
    collective term = collective_bytes_total / (chips x 50e9 B/s per link)

cost_analysis() on the SPMD executable reports *per-device* numbers, so
totals are per-device x chips; the three terms are therefore equivalently
per-device quantities over per-chip peaks, which is how they're computed
below.  The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures
how much compiled compute is 'useful' (remat/dispatch overhead shows here —
remat targets ~1/ (1+recompute) ~ 0.75 for a 1-recompute policy).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 FLOP/s per v5e chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link; 2D torus: ~4 usable links/chip,
                             # but collectives serialize per axis — we charge
                             # the conservative single-link rate.

DEFAULT_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                               "dryrun_full.jsonl")


def _score_traffic_bytes_per_dev(rec: Dict) -> float:
    """Modeled HBM traffic of materialized attention score tiles in the XLA
    chunked-attention path — the traffic the Pallas flash kernel keeps in
    VMEM on real hardware.  ~passes x B x H x S x T x 4 bytes / devices
    (passes: fwd writes+reads s and p ~4; bwd recompute ~4 more)."""
    from repro.configs.base import SHAPES, get_arch

    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    if shape.kind == "decode" or cfg.family == "ssm":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    T = min(cfg.window, S) if cfg.window else S
    passes = 8.0 if shape.kind == "train" else 4.0
    total = passes * B * cfg.n_heads * S * T * 4.0
    if cfg.family == "audio":   # decoder-only self-attn portion
        total *= cfg.n_layers / max(cfg.n_layers + cfg.enc_layers, 1)
    return total / rec["devices"]


def roofline_terms(rec: Dict) -> Dict:
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_per_device"]
    coll_dev = rec["collective_bytes_per_device"]["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops_dev = rec["model_flops_total"] / rec["devices"]
    useful = model_flops_dev / max(flops_dev, 1e-9)
    # roofline fraction: useful model FLOPs per second achievable if the
    # dominant term were the only cost, vs chip peak
    frac = (model_flops_dev / max(bound, 1e-12)) / PEAK_FLOPS
    # memory term under a Pallas-flash deployment (score tiles in VMEM)
    kern_mem = max(bytes_dev - _score_traffic_bytes_per_dev(rec), 0) / HBM_BW
    kern_bound = max(t_compute, kern_mem, t_coll)
    kern_frac = (model_flops_dev / max(kern_bound, 1e-12)) / PEAK_FLOPS
    mem = rec["memory"]
    fit_bytes = (mem["argument_bytes"] + mem["temp_bytes"]
                 + mem["output_bytes"] - max(mem["alias_bytes"], 0))
    return {**terms, "dominant": dominant, "useful_flops_frac": useful,
            "roofline_frac": frac, "kern_memory": kern_mem,
            "kern_roofline_frac": kern_frac,
            "hbm_gib": fit_bytes / 2 ** 30,
            "fits_16g": fit_bytes <= 16 * 2 ** 30}


def load_results(path: str = DEFAULT_RESULTS) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    return out


def format_table(records: List[Dict], mesh: Optional[str] = "16x16") -> str:
    rows = []
    header = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'comp(ms)':>9s} "
              f"{'mem(ms)':>9s} {'kern-mem':>9s} {'coll(ms)':>9s} "
              f"{'bound':>10s} {'useful':>7s} {'roof%':>6s} {'kern%':>6s} "
              f"{'HBM GiB':>8s} fit")
    rows.append(header)
    rows.append("-" * len(header))
    for rec in records:
        if mesh and rec["mesh"] != mesh:
            continue
        t = roofline_terms(rec)
        rows.append(
            f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"{t['compute']*1e3:9.2f} {t['memory']*1e3:9.2f} "
            f"{t['kern_memory']*1e3:9.2f} "
            f"{t['collective']*1e3:9.2f} {t['dominant']:>10s} "
            f"{t['useful_flops_frac']:7.2f} {t['roofline_frac']*100:5.1f}% "
            f"{t['kern_roofline_frac']*100:5.1f}% "
            f"{t['hbm_gib']:8.2f} {'Y' if t['fits_16g'] else 'OVER'}")
    return "\n".join(rows)


def run(out_csv: Optional[str] = None) -> str:
    records = load_results()
    lines = ["# Roofline — single-pod 16x16 (roofline table)",
             format_table(records, "16x16"),
             "", "# Multi-pod 2x16x16 (runnability pass)",
             format_table(records, "2x16x16")]
    text = "\n".join(lines)
    if out_csv:
        with open(out_csv, "w") as f:
            f.write("arch,shape,mesh,compute_s,memory_s,collective_s,"
                    "dominant,useful_frac,roofline_frac,hbm_gib,fits\n")
            for rec in records:
                t = roofline_terms(rec)
                f.write(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                        f"{t['compute']:.6f},{t['memory']:.6f},"
                        f"{t['collective']:.6f},{t['dominant']},"
                        f"{t['useful_flops_frac']:.3f},"
                        f"{t['roofline_frac']:.4f},{t['hbm_gib']:.2f},"
                        f"{int(t['fits_16g'])}\n")
    return text


def inject_into_experiments(text: str) -> None:
    """Replace the <!-- ROOFLINE_TABLE --> marker block in EXPERIMENTS.md."""
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    if not os.path.exists(path):
        return
    with open(path) as f:
        doc = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker not in doc:
        return
    block = marker + "\n```\n" + text + "\n```"
    start = doc.index(marker)
    end = doc.find("\n\nReading the table:", start)
    if end == -1:
        end = start + len(marker)
    doc = doc[:start] + block + doc[end:]
    with open(path, "w") as f:
        f.write(doc)


def main() -> None:
    text = run(out_csv=os.path.join(os.path.dirname(DEFAULT_RESULTS),
                                    "roofline.csv"))
    print(text)
    inject_into_experiments(text)


if __name__ == "__main__":
    main()
