"""Paper Fig. 4: batch loading times, in-order vs out-of-order (high RTT).

The in-order series shows cyclical multi-hundred-ms stalls when a congested
connection gates a batch; OOO stays flat.  Emits the full time series CSV
and prints summary stats.
"""

from __future__ import annotations

import numpy as np

from repro.core import tight_loop
from .common import make_loader, make_store, write_csv


def run(n_batches: int = 300, seed: int = 2) -> str:
    store, uuids = make_store()
    lines = [f"{'mode':10s} {'mean(ms)':>9s} {'p50':>7s} {'p99':>8s} "
             f"{'max':>8s}"]
    rows = []
    for ooo in (False, True):
        ld = make_loader(store, uuids, "high", out_of_order=ooo, seed=seed)
        res = tight_loop(ld, n_batches=n_batches)
        bt = res["batch_times"][20:] * 1e3
        mode = "ooo" if ooo else "in-order"
        lines.append(f"{mode:10s} {bt.mean():9.1f} "
                     f"{np.percentile(bt, 50):7.1f} "
                     f"{np.percentile(bt, 99):8.1f} {bt.max():8.1f}")
        for i, v in enumerate(bt):
            rows.append(f"{mode},{i},{v:.3f}")
    write_csv("fig4_batch_times.csv", "mode,batch,gap_ms", rows)
    return "\n".join(lines)


def main() -> None:
    print("# Fig. 4 — batch loading time, in-order vs out-of-order (high)")
    print(run())


if __name__ == "__main__":
    main()
