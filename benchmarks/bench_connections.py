"""Paper Figs. 5/6: per-connection transfer rates, in-order vs OOO.

In-order: per-connection throughputs correlate (everything waits for the
slowest) and the aggregate oscillates.  OOO: connections proceed
independently; aggregate is high and steady.
"""

from __future__ import annotations

import numpy as np

from repro.core import tight_loop
from .common import make_loader, make_store, write_csv


def run(n_batches: int = 300, seed: int = 2, window: float = 0.5) -> str:
    store, uuids = make_store()
    lines = [f"{'mode':9s} {'agg mean':>9s} {'agg min':>9s} {'agg max':>9s} "
             f"{'conn spread(max/min)':>21s}  (MB/s)"]
    rows = []
    for ooo in (False, True):
        ld = make_loader(store, uuids, "high", out_of_order=ooo, seed=seed)
        tight_loop(ld, n_batches=n_batches)
        mode = "ooo" if ooo else "in-order"
        traces = ld.pool.throughput_traces(window)
        # aggregate per window
        n_windows = max(len(t) for t in traces.values() if t)
        agg = np.zeros(n_windows)
        per_conn_mean = []
        for cid, series in traces.items():
            vals = np.zeros(n_windows)
            for i, (t, bps) in enumerate(series):
                vals[i] = bps / 1e6
                rows.append(f"{mode},{cid},{t:.1f},{bps/1e6:.1f}")
            agg[:len(vals)] += vals
            if vals[2:-2].size:
                per_conn_mean.append(vals[2:-2].mean())
        steady = agg[3:-2] if agg.size > 6 else agg
        spread = (max(per_conn_mean) / max(min(per_conn_mean), 1e-9)
                  if per_conn_mean else 0)
        lines.append(f"{mode:9s} {steady.mean():9.0f} {steady.min():9.0f} "
                     f"{steady.max():9.0f} {spread:21.1f}")
    write_csv("fig56_connections.csv", "mode,conn,t,MBps", rows)
    return "\n".join(lines)


def main() -> None:
    print("# Figs. 5/6 — 32 connection transfer rates (high latency)")
    print(run())


if __name__ == "__main__":
    main()
