"""Paper Fig. 7: Cassandra vs ScyllaDB backends (tight-loop, high latency).

Paper: ScyllaDB ~4.0 GB/s net; Cassandra ~1.6 GB/s net with ~3.6 GB/s disk
reads (block-read amplification ~2.25x).
"""

from __future__ import annotations

from repro.core import tight_loop
from .common import make_loader, make_store, write_csv

PAPER = {"scylla": (4081, 1.0), "cassandra": (1600, 2.25)}


def run(n_batches: int = 250, seed: int = 1) -> str:
    store, uuids = make_store()
    lines = [f"{'backend':10s} {'net MB/s':>9s} {'disk MB/s':>10s} "
             f"{'disk/net':>9s} {'paper net':>10s} {'paper amp':>10s}"]
    rows = []
    for backend in ("scylla", "cassandra"):
        ld = make_loader(store, uuids, "high", backend=backend, seed=seed)
        res = tight_loop(ld, n_batches=n_batches)
        net = res["throughput_Bps"] / 1e6
        # measure disk/net over the same consumed bytes window
        amp = res["disk_bytes"] / max(res["net_bytes"], 1)
        disk = net * amp
        lines.append(f"{backend:10s} {net:9.0f} {disk:10.0f} {amp:9.2f} "
                     f"{PAPER[backend][0]:>10d} {PAPER[backend][1]:>10.2f}")
        rows.append(f"{backend},{net:.0f},{disk:.0f},{amp:.2f}")
    write_csv("fig7_backends.csv", "backend,net_MBps,disk_MBps,amplification",
              rows)
    return "\n".join(lines)


def main() -> None:
    print("# Fig. 7 — Cassandra vs ScyllaDB (tight-loop, high latency)")
    print(run())


if __name__ == "__main__":
    main()
