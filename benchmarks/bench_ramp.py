"""Paper Sec. 3.4 ablation: eager vs incremental prefetch-buffer filling.

The burst matters at the *node* scale: 8 consumers x 8 buffers x 512 samples
posted at t=0 put several GB into the network at once; bufferbloat-induced
losses crash the per-connection AIMD rates exactly when the pipeline is
trying to fill (paper: "unstable throughput during buffer filling").  The
incremental ramp (+1 buffer per 4 consumed) bounds the transient to +25%.

Metrics: throughput over the first warmup window and the time to deliver the
first 8x16 batches, eager vs incremental.
"""

from __future__ import annotations

import numpy as np

from repro.core import Cluster, LoaderConfig, VirtualClock
from repro.core.connection import ConnectionPool
from repro.core.netsim import NIC_BANDWIDTH, RateResource, TIERS
from repro.core.prefetcher import EpochPlan, PrefetchConfig, make_prefetcher

from .common import make_store, write_csv

N_GPUS = 8
BATCH = 512
WARMUP_BATCHES = 16           # per consumer


def _run(ramp: bool, seed: int = 3) -> dict:
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shared = RateResource("client/ingress", NIC_BANDWIDTH)
    pfs = []
    for g in range(N_GPUS):
        pool = ConnectionPool(clock, cluster, TIERS["high"], io_threads=4,
                              seed=seed + 31 * g)
        pool.ingress = shared
        for c in pool.connections:
            c._client_ingress = shared
        plan = EpochPlan(uuids, seed=seed, shard_id=g, num_shards=N_GPUS)
        pf = make_prefetcher(clock, pool, plan,
                             PrefetchConfig(batch_size=BATCH, num_buffers=8,
                                            incremental_ramp=ramp))
        pf.start()
        pfs.append(pf)
    initial_reqs = sum(p.pool.requests_sent for p in pfs)

    done = [0] * N_GPUS
    while min(done) < WARMUP_BATCHES:
        g = int(np.argmin(done))
        pfs[g].next_batch(timeout=3000.0)
        done[g] += 1
    t_warm = clock.now()
    total_bytes = sum(sum(p.stats.batch_nbytes) for p in pfs)
    gaps = np.concatenate([p.stats.batch_times()[1:] for p in pfs]) * 1e3
    return {"t_warmup_s": t_warm,
            "warmup_MBps": total_bytes / t_warm / 1e6,
            "p99_gap_ms": float(np.percentile(gaps, 99)),
            "initial_requests": initial_reqs}


def run() -> str:
    lines = [f"{'ramp':12s} {'warmup time(s)':>14s} {'warmup MB/s':>12s} "
             f"{'p99 gap(ms)':>12s} {'initial reqs':>13s}"]
    rows = []
    for ramp in (False, True):
        r = _run(ramp)
        name = "incremental" if ramp else "eager"
        lines.append(f"{name:12s} {r['t_warmup_s']:14.2f} "
                     f"{r['warmup_MBps']:12.0f} {r['p99_gap_ms']:12.1f} "
                     f"{r['initial_requests']:13d}")
        rows.append(f"{name},{r['t_warmup_s']:.2f},{r['warmup_MBps']:.0f},"
                    f"{r['p99_gap_ms']:.1f},{r['initial_requests']}")
    write_csv("ramp_ablation.csv",
              "ramp,warmup_time_s,warmup_MBps,p99_gap_ms,initial_requests",
              rows)
    return "\n".join(lines)


def main() -> None:
    print("# Sec. 3.4 — incremental vs eager prefetch ramp "
          "(8 consumers, high latency)")
    print(run())


if __name__ == "__main__":
    main()
