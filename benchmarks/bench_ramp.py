"""Paper Sec. 3.4 ablation: eager vs incremental prefetch-buffer filling,
plus the adaptive-flow-control comparison that removes the depth knob.

The burst matters at the *node* scale: 8 consumers x 8 buffers x 512 samples
posted at t=0 put several GB into the network at once; bufferbloat-induced
losses crash the per-connection AIMD rates exactly when the pipeline is
trying to fill (paper: "unstable throughput during buffer filling").  The
incremental ramp (+1 buffer per 4 consumed) bounds the transient to +25%.

Metrics: throughput over the first warmup window and the time to deliver the
first 8x16 batches, eager vs incremental.

The **flow-control section** (``--flowctl`` to run it alone, ``--quick`` for
the CI smoke size) sweeps static prefetch depths against the BDP-tracking
controller (``core/flowctl.py``) on the local / medium / intercontinental
routes plus one federated mixed-route run, writes
``results/flowctl_ramp.json``, and asserts the two headline invariants from
that file: adaptive >= 90% of the *best* static depth on the 150 ms route
with zero tuning, and steady-state depth <= 2x the true route BDP on the
local route (no pointless over-buffering).
"""

from __future__ import annotations

import json
import math
import os
import sys

import numpy as np

from repro.core import (CassandraLoader, Cluster, ClusterSpec, LoaderConfig,
                        MultiHostConfig, MultiHostRun, VirtualClock)
from repro.core.connection import ConnectionPool
from repro.core.netsim import (NIC_BANDWIDTH, RateResource, TIERS,
                               route_bdp_samples)
from repro.core.prefetcher import EpochPlan, PrefetchConfig, make_prefetcher

from .common import RESULTS_DIR, make_store, write_csv

N_GPUS = 8
BATCH = 512
WARMUP_BATCHES = 16           # per consumer


def _run(ramp: bool, seed: int = 3) -> dict:
    store, uuids = make_store()
    clock = VirtualClock()
    cluster = Cluster(clock, store, backend="scylla", seed=seed)
    shared = RateResource("client/ingress", NIC_BANDWIDTH)
    pfs = []
    for g in range(N_GPUS):
        pool = ConnectionPool(clock, cluster, TIERS["high"], io_threads=4,
                              seed=seed + 31 * g)
        pool.ingress = shared
        for c in pool.connections:
            c._client_ingress = shared
        plan = EpochPlan(uuids, seed=seed, shard_id=g, num_shards=N_GPUS)
        pf = make_prefetcher(clock, pool, plan,
                             PrefetchConfig(batch_size=BATCH, num_buffers=8,
                                            incremental_ramp=ramp))
        pf.start()
        pfs.append(pf)
    initial_reqs = sum(p.pool.requests_sent for p in pfs)

    done = [0] * N_GPUS
    while min(done) < WARMUP_BATCHES:
        g = int(np.argmin(done))
        pfs[g].next_batch(timeout=3000.0)
        done[g] += 1
    t_warm = clock.now()
    total_bytes = sum(sum(p.stats.batch_nbytes) for p in pfs)
    gaps = np.concatenate([p.stats.batch_times()[1:] for p in pfs]) * 1e3
    return {"t_warmup_s": t_warm,
            "warmup_MBps": total_bytes / t_warm / 1e6,
            "p99_gap_ms": float(np.percentile(gaps, 99)),
            "initial_requests": initial_reqs}


def run() -> str:
    lines = [f"{'ramp':12s} {'warmup time(s)':>14s} {'warmup MB/s':>12s} "
             f"{'p99 gap(ms)':>12s} {'initial reqs':>13s}"]
    rows = []
    for ramp in (False, True):
        r = _run(ramp)
        name = "incremental" if ramp else "eager"
        lines.append(f"{name:12s} {r['t_warmup_s']:14.2f} "
                     f"{r['warmup_MBps']:12.0f} {r['p99_gap_ms']:12.1f} "
                     f"{r['initial_requests']:13d}")
        rows.append(f"{name},{r['t_warmup_s']:.2f},{r['warmup_MBps']:.0f},"
                    f"{r['p99_gap_ms']:.1f},{r['initial_requests']}")
    write_csv("ramp_ablation.csv",
              "ramp,warmup_time_s,warmup_MBps,p99_gap_ms,initial_requests",
              rows)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Static-depth sweep vs adaptive flow control (core/flowctl.py)
# ---------------------------------------------------------------------------

FLOW_ROUTES = ("local", "med", "high")
STATIC_SWEEP = (2, 4, 8, 16, 32)


def _route_bdp_batches(route: str, batch: int, io_threads: int,
                       sample_bytes: float) -> int:
    """True route BDP in batches (``netsim.route_bdp_samples``, the
    analytic yardstick — not the controller's own estimate)."""
    return max(1, math.ceil(route_bdp_samples(route, io_threads * 2,
                                              sample_bytes) / batch))


def _flow_run(store, uuids, route: str, mode: str, k: int, *, batch: int,
              io_threads: int, n_batches: int, seed: int = 2) -> dict:
    cfg = LoaderConfig(batch_size=batch, prefetch_buffers=k,
                       io_threads=io_threads, route=route, backend="scylla",
                       seed=seed, flow_control=mode)
    ld = CassandraLoader(store, uuids, cfg)
    ld.start()
    for _ in range(n_batches):
        ld.next_batch(timeout=3000.0)
    out = {"MBps": ld.stats.throughput(skip=max(2, n_batches // 5)) / 1e6}
    if ld.flow_controller is not None:
        rep = ld.flow_controller.report()
        out.update(steady_depth=rep["depth_batches"],
                   budget_samples=rep["budget_samples"],
                   bdp_est_samples=rep["bdp_samples"],
                   min_rtt_s=rep["min_rtt_s"],
                   backoffs=rep["backoffs"],
                   loss_signals=rep["loss_signals"])
    return out


def _flow_federated(store, uuids, *, batch: int, io_threads: int,
                    rounds: int, seed: int = 9) -> dict:
    """One run mixing a local member with a 150 ms member: each member's
    controller ramps to its own route's BDP."""
    cfg = MultiHostConfig(
        n_hosts=2, batch_size=batch, io_threads=io_threads,
        hedge_after=None, seed=seed, flow_control="adaptive",
        placement="cluster_aware",
        clusters=(ClusterSpec("near", route="local", n_nodes=2),
                  ClusterSpec("far", route="high", n_nodes=2)))
    run = MultiHostRun(store, uuids, cfg).start()
    rep = run.run(rounds)
    members = {}
    for name in ("near", "far"):
        per_host = [f["members"][name] for f in rep["flow"]]
        members[name] = {
            "depth_batches": [m["depth_batches"] for m in per_host],
            "budget_samples": [m["budget_samples"] for m in per_host],
            "min_rtt_s": [m["min_rtt_s"] for m in per_host],
        }
    return {"aggregate_MBps": rep["aggregate_Bps"] / 1e6,
            "wan_bytes_share": rep["wan_bytes_share"],
            "members": members}


def run_flowctl(quick: bool = False) -> str:
    if quick:
        batch, io_threads, n_batches, n_samples, rounds = 256, 8, 70, 30_000, 30
        sweep = (2, 8, 16, 32)
    else:
        batch, io_threads, n_batches, n_samples, rounds = BATCH, 16, 120, 120_000, 60
        sweep = STATIC_SWEEP
    store, uuids = make_store(n_samples=n_samples)
    sample_bytes = store.total_bytes() / len(uuids)
    lines = [f"{'route':8s} {'config':14s} {'MB/s':>8s} {'depth':>6s} "
             f"{'bdp est':>8s} {'backoffs':>8s}"]
    results = {"batch_size": batch, "io_threads": io_threads,
               "n_batches": n_batches, "static_sweep": list(sweep),
               "routes": {}}
    for route in FLOW_ROUTES:
        static = {}
        for k in sweep:
            r = _flow_run(store, uuids, route, "static", k, batch=batch,
                          io_threads=io_threads, n_batches=n_batches)
            static[k] = r["MBps"]
            lines.append(f"{route:8s} static k={k:<5d} {r['MBps']:8.0f}")
        ad = _flow_run(store, uuids, route, "adaptive", 8, batch=batch,
                       io_threads=io_threads, n_batches=n_batches)
        best_k = max(static, key=static.get)
        bdp_true = _route_bdp_batches(route, batch, io_threads, sample_bytes)
        results["routes"][route] = {
            "static_MBps": {str(k): v for k, v in static.items()},
            "best_static": {"num_buffers": best_k, "MBps": static[best_k]},
            "adaptive": ad,
            "adaptive_over_best_static": ad["MBps"] / max(static[best_k],
                                                          1e-9),
            "bdp_batches_true": bdp_true,
            "depth_over_true_bdp": ad["steady_depth"] / bdp_true,
        }
        lines.append(
            f"{route:8s} {'adaptive':14s} {ad['MBps']:8.0f} "
            f"{ad['steady_depth']:6d} "
            f"{(ad['bdp_est_samples'] or 0.0):8.0f} {ad['backoffs']:8d}  "
            f"(best static k={best_k}: {static[best_k]:.0f} MB/s, "
            f"ratio {results['routes'][route]['adaptive_over_best_static']:.2f}, "
            f"true BDP ~{bdp_true} batches)")
    results["federated"] = _flow_federated(store, uuids, batch=max(batch
                                                                   // 2, 64),
                                           io_threads=io_threads // 2,
                                           rounds=rounds)
    far = results["federated"]["members"]["far"]["budget_samples"]
    near = results["federated"]["members"]["near"]["budget_samples"]
    lines.append(f"{'federated':8s} {'adaptive':14s} "
                 f"{results['federated']['aggregate_MBps']:8.0f} "
                 f"  per-member budgets: far(150ms)={far} "
                 f"near(local)={near}")
    # the two headline invariants, recorded in the file and asserted from it
    results["checks"] = {
        "adaptive_ge_90pct_best_static_on_150ms_route":
            results["routes"]["high"]["adaptive_over_best_static"] >= 0.9,
        "local_steady_depth_le_2x_true_bdp":
            results["routes"]["local"]["depth_over_true_bdp"] <= 2.0,
        "wan_member_ramps_deeper_than_local":
            min(far) > max(near),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "flowctl_ramp.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    with open(path) as f:                      # assert from the artifact
        written = json.load(f)
    failed = [name for name, ok in written["checks"].items() if not ok]
    if failed:
        raise AssertionError(f"flowctl checks failed: {failed} (see {path})")
    lines.append(f"checks: all {len(written['checks'])} passed -> {path}")
    return "\n".join(lines)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    flowctl_only = "--flowctl" in argv
    quick = "--quick" in argv
    if not flowctl_only:
        print("# Sec. 3.4 — incremental vs eager prefetch ramp "
              "(8 consumers, high latency)")
        print(run())
        print()
    print("# Flow control — static depth sweep vs BDP-tracking controller"
          + (" (quick)" if quick else ""))
    print(run_flowctl(quick=quick))


if __name__ == "__main__":
    main()
